"""Roofline table generator: reads results/dryrun/*.json (written by
repro.launch.dryrun) and emits the EXPERIMENTS.md §Roofline table plus
(name, us_per_call, derived) rows for benchmarks.run."""
from __future__ import annotations

import glob
import json
import os
from typing import List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# MODEL_FLOPS: 6*N*D (dense) or 6*N_active*D (MoE), N from the configs
PARAMS_B = {
    "phi3-medium-14b": 14.0e9, "tinyllama-1.1b": 1.1e9, "minicpm3-4b": 4.0e9,
    "phi3-mini-3.8b": 3.8e9, "moonshot-v1-16b-a3b": 16.0e9,
    "arctic-480b": 482e9, "qwen2-vl-72b": 72.7e9, "xlstm-125m": 0.125e9,
    "recurrentgemma-9b": 9.2e9, "whisper-medium": 0.77e9,
}
ACTIVE_B = dict(PARAMS_B, **{"moonshot-v1-16b-a3b": 3.0e9, "arctic-480b": 17e9})
TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
          "decode_32k": 128, "long_500k": 1}
STEP_FACTOR = {"train": 3.0, "prefill": 1.0, "decode": 1.0}  # fwd+bwd = 3x fwd


def load(mesh: str = "single") -> List[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(REPO, "results", "dryrun",
                                           f"*__{mesh}.json"))):
        out.append(json.load(open(p)))
    return out


def model_flops(arch: str, shape: str, kind: str) -> float:
    n = ACTIVE_B[arch]
    return 2.0 * n * TOKENS[shape] * STEP_FACTOR[kind]


def table(mesh: str = "single") -> str:
    rows = []
    hdr = (f"| {'arch':21s} | {'shape':11s} | comp(s) | mem(s) | coll(s) | "
           f"dominant | mem/dev | MODEL/HLO | note |")
    sep = "|" + "---|" * 9
    for r in load(mesh):
        if not r.get("ok"):
            rows.append(f"| {r['arch']:21s} | {r['shape']:11s} | FAIL: {r.get('error','')[:40]} |")
            continue
        rl, c = r["roofline"], r["cost"]
        mf = model_flops(r["arch"], r["shape"], r["kind"])
        hlo_total = c["flops_per_device"] * r["n_chips"]
        ratio = mf / hlo_total if hlo_total else 0.0
        mem = r["memory"]["per_device_bytes_tpu_adjusted"] / 1e9
        fits = "" if r["memory"]["fits_16gb_tpu_adjusted"] else " OVER"
        rows.append(
            f"| {r['arch']:21s} | {r['shape']:11s} | {rl['compute_s']:.4g} | "
            f"{rl['memory_s']:.4g} | {rl['collective_s']:.4g} | "
            f"{rl['dominant'].replace('_s',''):8s} | {mem:.1f}GB{fits} | "
            f"{ratio:.3f} | |"
        )
    return "\n".join([hdr, sep] + rows)


def bench_roofline(full: bool = False) -> List[Tuple]:
    rows = []
    for r in load("single"):
        if not r.get("ok"):
            continue
        rl = r["roofline"]
        dom = rl["dominant"]
        frac = rl["compute_s"] / max(rl[dom], 1e-12)
        rows.append((f"roofline/{r['arch']}/{r['shape']}", rl[dom] * 1e6,
                     f"dominant={dom};compute_frac={frac:.4f}"))
    return rows


if __name__ == "__main__":
    import sys
    print(table(sys.argv[1] if len(sys.argv) > 1 else "single"))
