"""Decode-attention kernel microbenchmark: jnp oracle vs vector-length
flash-decode (split-K Pallas) vs paged flash-decode (block-table gather),
at serving-shaped decode batches with ragged per-row cache lengths.

On TPU the Pallas kernels compile to Mosaic; elsewhere they run in
interpret mode (plain XLA), which is a *correctness* vehicle — it pays
per-grid-program overhead, so on the CPU container the oracle usually
wins and ``kernels/ops`` resolves ``impl="auto"`` to it.  The point of
recording both is exactly that dispatch decision: the numbers in
``results/bench/decode_kernel.json`` document where each path pays off
(and every row re-asserts kernel/oracle parity before timing).

Run standalone:

  PYTHONPATH=src python benchmarks/decode_kernel.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.results_io import bench_json, merge_record

RESULTS_JSON = bench_json("decode_kernel")

# (label, B, H, KV, S, D, block_k, page_size)
SHAPES = [
    ("gqa_4x512", 4, 8, 2, 512, 64, 128, 64),
    ("gqa_8x1024", 8, 8, 2, 1024, 64, 256, 64),
    ("mha_4x512", 4, 8, 8, 512, 64, 128, 64),
]
QUICK_SHAPES = [("gqa_4x256", 4, 8, 2, 256, 32, 128, 64)]


def _time_us(fn, *args, iters=30):
    import jax

    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _bench_shape(label, B, H, KV, S, D, block_k, page_size, iters):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.ops import _resolve_decode

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    lens = jnp.asarray(rng.integers(1, S + 1, B), jnp.int32)

    num_pages = B * (S // page_size)
    max_pages = S // page_size
    bt = jnp.asarray(
        rng.permutation(num_pages)[:B * max_pages].reshape(B, max_pages),
        jnp.int32)
    kp = jnp.asarray(
        rng.standard_normal((num_pages, page_size, KV, D)), jnp.float32)
    vp = jnp.asarray(
        rng.standard_normal((num_pages, page_size, KV, D)), jnp.float32)

    # kernel mode: real Pallas on TPU, interpret-mode Pallas elsewhere
    kmode = _resolve_decode("auto")
    if kmode == "ref":
        kmode = "interpret"

    f_ref = jax.jit(lambda q, k, v, l: ops.decode_attention(
        q, k, v, l, impl="ref"))
    f_vec = jax.jit(lambda q, k, v, l: ops.decode_attention(
        q, k, v, l, impl=kmode, block_k=block_k))
    f_pref = jax.jit(lambda q, kp, vp, bt, l: ops.decode_attention_paged(
        q, kp, vp, bt, l, impl="ref"))
    f_pag = jax.jit(lambda q, kp, vp, bt, l: ops.decode_attention_paged(
        q, kp, vp, bt, l, impl=kmode))

    # parity gate before timing: the kernels must match the oracles
    np.testing.assert_allclose(
        np.asarray(f_vec(q, k, v, lens)), np.asarray(f_ref(q, k, v, lens)),
        atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(
        np.asarray(f_pag(q, kp, vp, bt, lens)),
        np.asarray(f_pref(q, kp, vp, bt, lens)), atol=2e-5, rtol=2e-5)

    return {
        "shape": dict(B=B, H=H, KV=KV, S=S, D=D, block_k=block_k,
                      page_size=page_size),
        "kernel_mode": kmode,
        "ref_us": round(_time_us(f_ref, q, k, v, lens, iters=iters), 1),
        "veclen_us": round(_time_us(f_vec, q, k, v, lens, iters=iters), 1),
        "paged_ref_us": round(
            _time_us(f_pref, q, kp, vp, bt, lens, iters=iters), 1),
        "paged_us": round(
            _time_us(f_pag, q, kp, vp, bt, lens, iters=iters), 1),
    }


def bench_decode_kernel(quick: bool = False, full: bool = False):
    shapes = QUICK_SHAPES if quick else SHAPES
    iters = 5 if quick else 30
    rows = []
    results = {}
    for spec in shapes:
        r = _bench_shape(*spec, iters=iters)
        label = spec[0]
        results[label] = r
        rows.append((f"decode_kernel/{label}_ref", r["ref_us"],
                     f"us={r['ref_us']}"))
        rows.append((f"decode_kernel/{label}_veclen", r["veclen_us"],
                     f"us={r['veclen_us']};mode={r['kernel_mode']};"
                     f"vs_ref={r['ref_us'] / max(r['veclen_us'], 1e-9):.2f}x"))
        rows.append((f"decode_kernel/{label}_paged", r["paged_us"],
                     f"us={r['paged_us']};mode={r['kernel_mode']};"
                     f"vs_ref={r['paged_ref_us'] / max(r['paged_us'], 1e-9):.2f}x"))
    if not quick:
        merge_record(RESULTS_JSON, results)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for name, val, derived in bench_decode_kernel(quick=args.quick):
        print(f"{name},{val:.2f},{derived}")
    print("decode kernel microbench OK (kernel/oracle parity asserted "
          + ("; --quick prints only)" if args.quick
             else "; recorded to results/bench/decode_kernel.json)"))
