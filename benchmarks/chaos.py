"""Chaos benchmark: the fleet + transport under a seeded fault schedule.

Replays the resilience layer's whole fault model end-to-end, driven by a
single seeded :class:`~repro.core.resilience.faults.FaultPlan` — every
fault fires at a *logical* point (engine X's Nth working step, worker
N's first dispatch, checkpoint step K), so the schedule is reproducible
run-to-run with no kill-timing flakes:

- **fleet scenario**: a disaggregated prefill/decode fleet serves the
  seeded workload while the plan crashes a decode engine mid-stream and
  fails a KV-page handoff delivery.  The router's circuit breaker
  ejects the crashed member, re-routes its recovered work, and
  re-admits it after a probationary probe; the benchmark records the
  crash→re-admission **recovery latency**, the **goodput retained** vs
  an undisturbed run of the identical workload, and the number of
  **requests lost — asserted zero** (every request completes).
- **train scenario**: a 2-worker ``SubprocessTransport`` runs
  checkpoint-writing tasks while the plan kills one worker at dispatch,
  stalls the other's heartbeats past the timeout backstop, and tears a
  checkpoint file post-rename (the fault plan rides into the workers
  through the transport's ``env=`` hook).  Both tasks must complete
  after respawn-and-resubmit (zero lost), the stalled task must resume
  from its on-disk checkpoints instead of replaying finished steps, and
  the torn step must be detected and skipped by
  ``latest_step(verify=True)``/``restore``.

``--quick`` is the CI smoke (tiny workload, structural asserts only);
the full run additionally records to ``results/bench/chaos.json``.

Run standalone:

  PYTHONPATH=src python benchmarks/chaos.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.results_io import bench_json, merge_record
from benchmarks.workload import poisson_workload

RESULTS_JSON = bench_json("chaos")


# ---------------------------------------------------------------------------
# worker-side task bodies (picklable by reference: module-level)
# ---------------------------------------------------------------------------


def _ckpt_train_task(ckpt_dir: str, steps: int, sleep_s: float = 0.0):
    """Checkpoint-per-step 'training' loop: resumes from the newest
    *intact* step on disk, so a killed-and-resubmitted attempt continues
    instead of replaying.  Returns the first step this attempt ran."""
    import jax.numpy as jnp

    from repro.checkpoint.store import latest_step, save

    start = latest_step(ckpt_dir)
    first = 1 if start is None else start + 1
    for s in range(first, steps + 1):
        save(ckpt_dir, s, {"step": jnp.asarray(s),
                           "w": jnp.full((8,), float(s))})
        if sleep_s:
            time.sleep(sleep_s)
    return first


# ---------------------------------------------------------------------------
# fleet scenario
# ---------------------------------------------------------------------------


def _run_fleet(cfg, params, workload, *, n_engines, plan=None,
               policy=None, probe_deadline_s=30.0):
    """Serve ``workload`` through a disaggregated fleet, optionally under
    an armed fault plan.  Returns (requests, wall_s, stats, trace)."""
    import numpy as np

    from repro.core.resilience import faults as rfaults
    from repro.serve import Request, build_fleet

    router = build_fleet(
        cfg, num_engines=n_engines, disaggregate=True, num_prefill=1,
        params=params, max_slots=2, max_len=96, page_size=16,
        name_prefix="chaos", router_kwargs={"policy": policy})
    inj = plan.injector() if plan is not None else None
    rfaults.set_fault_injector(inj)
    try:
        with router:
            t0 = time.time()
            reqs = [router.submit(Request(p, max_new_tokens=int(g)))
                    for _, p, g in workload]
            assert router.drain(timeout=300), "fleet did not drain"
            wall = time.time() - t0
            # the probationary probe is a real request: feed small ones
            # until every ejected member has been re-admitted
            rng = np.random.default_rng(99)
            t1 = time.time()
            while time.time() - t1 < probe_deadline_s:
                st = router.stats()
                if st.get("readmissions", 0) >= st.get("ejections", 0):
                    break
                reqs.append(router.submit(Request(
                    rng.integers(1, 250, 5).astype(np.int32),
                    max_new_tokens=2)))
                router.drain(timeout=60)
                time.sleep(0.05)
            stats = router.stats()
    finally:
        rfaults.set_fault_injector(None)
    return reqs, wall, stats, (inj.trace() if inj is not None else [])


def _fleet_scenario(quick: bool):
    import jax

    from repro.common.params import init_params
    from repro.configs import get_config
    from repro.core.resilience import FailurePolicy, FaultPlan
    from repro.serve import RequestState
    from repro.train.state import model_specs

    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), model_specs(cfg))
    n_engines = 2 if quick else 3
    workload = poisson_workload(8 if quick else 24, seed=11)

    # undisturbed baseline of the identical workload (goodput reference)
    _, wall_clean, _, _ = _run_fleet(cfg, params, workload,
                                     n_engines=n_engines)

    plan = (FaultPlan(seed=42)
            .crash_engine(engine="chaos.dec1", at_step=5)
            .fail_handoff(nth=2))
    policy = FailurePolicy(eject_after=1, probation_s=0.3)
    reqs, wall, st, trace = _run_fleet(cfg, params, workload,
                                       n_engines=n_engines, plan=plan,
                                       policy=policy)

    lost = [r.rid for r in reqs if r.state is not RequestState.DONE]
    assert not lost, f"requests lost under chaos: {lost}"
    assert st.get("engine_crashes", 0) == 1, st
    assert st.get("handoff_faults", 0) == 1, st
    assert st.get("ejections", 0) == 1 and st.get("readmissions", 0) >= 1, (
        f"breaker must eject and re-admit: {st.get('ejections')}/"
        f"{st.get('readmissions')}")
    recoveries = st.get("recoveries", [])
    assert recoveries, "re-admission must record a recovery latency"
    return {
        "engines": n_engines,
        "requests": len(reqs),
        "requests_lost": 0,
        "engine_crashes": st["engine_crashes"],
        "handoff_faults": st["handoff_faults"],
        "requests_recovered": st.get("requests_recovered", 0),
        "recovery_latency_s": round(recoveries[0]["recovery_s"], 3),
        "wall_clean_s": round(wall_clean, 3),
        "wall_chaos_s": round(wall, 3),
        "goodput_retained": round(wall_clean / max(wall, 1e-9), 3),
        "fault_trace": [list(e[:3]) for e in trace],
    }


# ---------------------------------------------------------------------------
# train scenario
# ---------------------------------------------------------------------------


def _train_scenario(quick: bool, tmp_root: str):
    import importlib

    from repro.core.exec.transport import SubprocessTransport, WorkerCrashed
    from repro.core.resilience import FaultPlan
    from repro.core.resilience.faults import PLAN_ENV
    from repro.checkpoint.store import latest_step, verify_step

    # resolve the task fn through its importable module so it satisfies
    # the picklable-task contract even when this file runs as a script
    task_fn = importlib.import_module("benchmarks.chaos")._ckpt_train_task
    dir_crash = os.path.join(tmp_root, "crash")
    dir_stall = os.path.join(tmp_root, "stall")
    plan = (FaultPlan(seed=42)
            .crash_worker(worker=0, at_task=1)
            .stall_heartbeat(for_s=2.0, worker=1, at_task=1)
            .tear_checkpoint(at_byte=32, step=4))
    sub = SubprocessTransport(
        max_workers=2, worker_devices=1, heartbeat_s=0.05,
        heartbeat_timeout_s=0.4,
        env=dict(os.environ, **{PLAN_ENV: plan.to_json()}))
    recoveries = {}
    try:
        from repro.core.resilience import faults as rfaults
        with rfaults.inject(plan) as inj:
            # task 1 -> worker 0 (killed at dispatch); its retry writes
            # steps 1..4 and the worker-side plan tears step 4.
            # task 2 -> worker 1 (heartbeats stalled past the 0.4s
            # backstop mid-run); its retry RESUMES from the intact steps
            # the first attempt already checkpointed.
            jobs = {
                "worker_crash": (sub.submit(task_fn, dir_crash, 4,
                                            label="ckpt-crash"), dir_crash, 4),
                "heartbeat_stall": (sub.submit(task_fn, dir_stall, 3,
                                               0.35, label="ckpt-stall"),
                                    dir_stall, 3),
            }
            for name, (fut, d, steps) in jobs.items():
                t0 = time.time()
                try:
                    fut.result(timeout=180)
                    raise AssertionError(f"{name}: fault did not fire")
                except WorkerCrashed:
                    pass
                retry = sub.submit(task_fn, d, steps,
                                   label=f"retry-{name}")
                first = retry.result(timeout=180)
                recoveries[name] = {
                    "recovery_s": round(time.time() - t0, 3),
                    "resumed_from_step": first,
                }
            trace = inj.trace()
        tstats = sub.stats()
    finally:
        sub.shutdown(wait=True)
    # the stalled task's retry must have resumed, not replayed step 1
    # (its first attempt had >= 1 checkpoint on disk before the kill)
    assert recoveries["heartbeat_stall"]["resumed_from_step"] > 1, recoveries
    # torn-checkpoint detection: step 4 of the crash dir was torn
    # post-rename by the worker-side plan; verified recovery skips it
    assert not verify_step(dir_crash, 4), "step 4 must be torn"
    newest = latest_step(dir_crash, verify=True)
    assert newest == 3, f"recovery must fall back to step 3, got {newest}"
    assert latest_step(dir_stall, verify=True) == 3
    assert tstats.get("respawns", 0) >= 2, tstats
    return {
        "tasks": 2,
        "tasks_lost": 0,
        "respawns": tstats["respawns"],
        "respawn_log": tstats.get("respawn_log", []),
        "recoveries": recoveries,
        "torn_step_detected": 4,
        "intact_fallback_step": newest,
        "fault_trace": [list(e[:3]) for e in trace],
    }


# ---------------------------------------------------------------------------
# entry
# ---------------------------------------------------------------------------


def bench_chaos(quick: bool = False, full: bool = False):
    import tempfile

    rows = []
    fleet = _fleet_scenario(quick)
    rows.append(("chaos/fleet", fleet["recovery_latency_s"] * 1e6,
                 f"recovery={fleet['recovery_latency_s']}s;"
                 f"goodput={fleet['goodput_retained']};"
                 f"lost={fleet['requests_lost']}"))
    with tempfile.TemporaryDirectory(prefix="chaos-ckpt-") as tmp:
        train = _train_scenario(quick, tmp)
    rows.append(("chaos/train",
                 train["recoveries"]["worker_crash"]["recovery_s"] * 1e6,
                 f"respawns={train['respawns']};"
                 f"fallback_step={train['intact_fallback_step']};"
                 f"lost={train['tasks_lost']}"))
    if not quick:
        # quick mode is the CI smoke — it must never overwrite the
        # committed full-run numbers
        merge_record(RESULTS_JSON, {"fleet": fleet, "train": train,
                                    "plan_seed": 42})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for name, val, derived in bench_chaos(quick=args.quick):
        print(f"{name},{val:.2f},{derived}")
    print("chaos benchmark OK (seeded fault schedule: engine crash, "
          "handoff failure, worker kill, heartbeat stall, torn checkpoint "
          "— zero requests/tasks lost, crashed engine ejected and "
          "re-admitted after probation, stalled task resumed from intact "
          "checkpoints)")
