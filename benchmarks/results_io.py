"""Shared merge-preserving writer for results/bench/*.json.

Several benchmarks record different sections of the same file (e.g.
``multi_pipeline.json`` carries both the paper-tables Table-4 numbers and
the concurrent-scheduler multi-pilot scenario), so a whole-file overwrite
would clobber sibling results.  One implementation lives here; every
bench writer goes through it.
"""
from __future__ import annotations

import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_DIR = os.path.join(REPO, "results", "bench")


def merge_record(path: str, update: dict) -> None:
    """Merge ``update`` into the JSON file at ``path`` (created if absent;
    a corrupt/truncated file is treated as empty, never a crash)."""
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError):
            data = {}
    data.update(update)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)


def bench_json(name: str) -> str:
    return os.path.join(BENCH_DIR, f"{name}.json")
