"""Benchmarks reproducing each Deep RC paper artifact (Tables 1-4, Fig 4).

Sizes default to container scale (1 CPU core); ``--full`` approaches paper
scale.  Every function returns rows of (name, us_per_call, derived) for the
CSV contract of benchmarks.run.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.bridge.loader import window_batches
from repro.core.agent import RemoteAgent
from repro.core.bridge import cylon_stage, dl_stage
from repro.core.pilot import PilotDescription, PilotManager
from repro.core.pipeline import Pipeline, run_pipelines
from repro.core.task import TaskDescription
from repro.models import forecasting as F
from repro.models import hydrology as Hy

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# shared training loop (bare-metal path)
# ---------------------------------------------------------------------------


def _train_forecaster(name: str, steps: int, W=96, Hz=24, batch=128,
                      lr=1e-3, seed=0):
    init, apply = F.MODELS[name](W, Hz)
    params = init(jax.random.PRNGKey(seed))
    series = F.make_ett_series(4096, seed=seed)
    split = 3 * len(series) // 4

    @jax.jit
    def step(params, key):
        starts = jax.random.randint(key, (batch,), 0, split - W - Hz)
        idx = starts[:, None] + jnp.arange(W + Hz)[None, :]
        data = series[idx]
        x, y = data[:, :W], data[:, W:]

        def loss_fn(p):
            return jnp.mean((apply(p, x) - y) ** 2)

        l, g = jax.value_and_grad(loss_fn)(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        return params, l

    key = jax.random.PRNGKey(seed + 1)
    t0 = time.time()
    for i in range(steps):
        key, sub = jax.random.split(key)
        params, l = step(params, sub)
    l.block_until_ready()
    train_s = time.time() - t0
    # eval on held-out suffix
    starts = np.arange(split, len(series) - W - Hz, Hz)
    idx = starts[:, None] + np.arange(W + Hz)[None, :]
    data = np.asarray(series)[idx]
    x, y = jnp.asarray(data[:, :W]), data[:, W:]
    pred = np.asarray(apply(params, x))
    mae = float(np.mean(np.abs(pred - y)))
    mse = float(np.mean((pred - y) ** 2))
    mape = float(np.mean(np.abs(pred - y) / np.maximum(np.abs(y), 0.5))) * 100
    return {"train_s": train_s, "MAE": mae, "MSE": mse, "MAPE": mape,
            "loss": float(l)}


# ---------------------------------------------------------------------------
# Table 3 — 11 forecasting models, bare-metal vs Deep RC
# ---------------------------------------------------------------------------


def bench_forecasting(full: bool = False) -> List[Tuple]:
    steps = 400 if full else 60
    rows = []
    results = {}
    pm = PilotManager()
    agent = RemoteAgent(pm.submit_pilot(PilotDescription()), max_workers=1)
    for name in F.MODELS:
        bm = _train_forecaster(name, steps)

        def task_fn(comm, nm=name):
            return _train_forecaster(nm, steps)

        t0 = time.time()
        task, = agent.submit([TaskDescription(name=name, fn=task_fn, kind="train")])
        rc_total = time.time() - t0
        rc = task.result
        overhead = rc_total - rc["train_s"]
        results[name] = {"bm": bm, "rc": rc, "overhead_s": overhead}
        rows.append((f"forecast/{name}/bm_train", bm["train_s"] * 1e6 / steps,
                     f"mae={bm['MAE']:.3f};mse={bm['MSE']:.3f};mape={bm['MAPE']:.2f}"))
        rows.append((f"forecast/{name}/rc_train", rc_total * 1e6 / steps,
                     f"overhead_s={overhead:.3f}"))
    _dump("forecasting", results)
    return rows


# ---------------------------------------------------------------------------
# Tables 1-2 — hydrology LSTM: accuracy + overhead decomposition
# ---------------------------------------------------------------------------


def bench_hydrology(full: bool = False) -> List[Tuple]:
    steps = 2000 if full else 150
    window = 64
    feats, targets = Hy.make_camels_like(5000 if full else 2000)
    x_all, y_all = Hy.window_dataset(feats, targets, window)
    n = x_all.shape[0]
    split = 3 * n // 4
    params = Hy.lstm_init(jax.random.PRNGKey(0))

    @jax.jit
    def step(params, key):
        idx = jax.random.randint(key, (64,), 0, split)
        x, y = x_all[idx], y_all[idx]

        def loss_fn(p):
            return jnp.mean((Hy.lstm_apply(p, x) - y) ** 2)

        l, g = jax.value_and_grad(loss_fn)(params)
        return jax.tree.map(lambda p, gg: p - 3e-3 * gg, params, g), l

    def run_train(comm=None):
        p = params
        key = jax.random.PRNGKey(1)
        t0 = time.time()
        for _ in range(steps):
            key, sub = jax.random.split(key)
            p, l = step(p, sub)
        l.block_until_ready()
        return p, time.time() - t0

    # bare metal
    p_bm, bm_s = run_train()
    # Deep RC
    pm = PilotManager()
    agent = RemoteAgent(pm.submit_pilot(PilotDescription()), max_workers=1)
    t0 = time.time()
    task, = agent.submit([TaskDescription(
        name="hydrology", fn=lambda comm: run_train(comm), kind="train")])
    rc_total = time.time() - t0
    _, rc_train_s = task.result
    overhead = rc_total - rc_train_s

    # Table-1 metrics per target
    pred_tr = np.asarray(Hy.lstm_apply(p_bm, x_all[:split]))
    pred_va = np.asarray(Hy.lstm_apply(p_bm, x_all[split:]))
    y_tr, y_va = np.asarray(y_all[:split]), np.asarray(y_all[split:])
    metrics = {}
    for i, t in enumerate(Hy.TARGETS):
        metrics[t] = {
            "train_mse": float(np.mean((pred_tr[:, i] - y_tr[:, i]) ** 2)),
            "val_mse": float(np.mean((pred_va[:, i] - y_va[:, i]) ** 2)),
            "train_nnse": float(Hy.nnse(jnp.asarray(pred_tr[:, i]), jnp.asarray(y_tr[:, i]))),
            "val_nnse": float(Hy.nnse(jnp.asarray(pred_va[:, i]), jnp.asarray(y_va[:, i]))),
        }
    out = {"bm_train_s": bm_s, "rc_train_s": rc_train_s,
           "rc_total_s": rc_total, "overhead_s": overhead,
           "task_overheads": task.overhead_s, "metrics": metrics}
    _dump("hydrology", out)
    rows = [("hydrology/bm_train", bm_s * 1e6 / steps, f"steps={steps}"),
            ("hydrology/rc_overhead", overhead * 1e6, "constant-vs-scale")]
    for t, m in metrics.items():
        rows.append((f"hydrology/{t}", 0.0,
                     f"val_mse={m['val_mse']:.4f};val_nnse={m['val_nnse']:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 4 — sort/join strong+weak scaling (subprocess per worker count)
# ---------------------------------------------------------------------------

_SCALING_SNIPPET = r"""
import os, sys, time, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(workers)d"
sys.path.insert(0, %(src)r)
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.dataframe.table import Table
from repro.dataframe import ops_dist as D
W = %(workers)d
rows = %(rows)d
mesh = make_mesh((W,), ("data",))
rng = np.random.default_rng(0)
t = Table.from_columns({"k": rng.integers(0, rows, rows).astype(np.int32),
                        "v": rng.normal(size=rows).astype(np.float32)}, mesh)
r = Table.from_columns({"k": np.arange(rows//2).astype(np.int32),
                        "w": np.ones(rows//2, np.float32)}, mesh)
out = {}
for op in ("sort", "join"):
    fn = (lambda: D.sort(t, "k")) if op == "sort" else (lambda: D.join(t, r, "k"))
    fn()  # warmup/compile
    t0 = time.time(); res, dropped = fn()
    jax.block_until_ready(res.columns)
    out[op] = {"s": time.time() - t0, "dropped": dropped}
print("RESULT::" + json.dumps(out))
"""


def bench_scaling_ops(full: bool = False) -> List[Tuple]:
    worker_counts = [1, 2, 4, 8]
    base_rows = 200_000 if full else 40_000
    results: Dict = {"strong": {}, "weak": {}}
    for mode in ("strong", "weak"):
        for w in worker_counts:
            rows_n = base_rows if mode == "strong" else base_rows // 4 * w
            code = _SCALING_SNIPPET % {
                "workers": w, "rows": rows_n, "src": os.path.join(REPO, "src")}
            r = subprocess.run([sys.executable, "-c", code],
                               capture_output=True, text=True, timeout=600)
            if r.returncode != 0:
                results[mode][w] = {"error": r.stderr[-500:]}
                continue
            line = [l for l in r.stdout.splitlines() if l.startswith("RESULT::")][0]
            results[mode][w] = json.loads(line[8:])
    _dump("scaling_ops", results)
    rows = []
    for mode, per_w in results.items():
        for w, ops in per_w.items():
            for op, d in ops.items():
                if isinstance(d, dict) and "s" in d:
                    rows.append((f"scaling/{mode}/{op}/w{w}", d["s"] * 1e6,
                                 f"dropped={d['dropped']}"))
    return rows


# ---------------------------------------------------------------------------
# Table 4 — multi-pipeline: shared pilot vs bare-metal sequential
# ---------------------------------------------------------------------------


def bench_multi_pipeline(full: bool = False) -> List[Tuple]:
    n_pipelines = 11
    steps = 30 if not full else 200
    names = list(F.MODELS)[:n_pipelines]

    def infer_fn(comm, upstream, nm):
        # inference task: forward pass over a fresh batch, many repeats
        init, apply = F.MODELS[nm](96, 24)
        params = init(jax.random.PRNGKey(0))
        x = jnp.zeros((256, 96))
        f = jax.jit(lambda p, x: apply(p, x))
        f(params, x).block_until_ready()
        t0 = time.time()
        for _ in range(10):
            y = f(params, x)
        y.block_until_ready()
        return time.time() - t0

    def cylon_fn(comm, upstream):
        import numpy as np
        from repro.dataframe.table import Table
        from repro.dataframe import ops_local as L
        rng = np.random.default_rng(0)
        n = 20_000
        t = Table.from_columns({"k": rng.integers(0, n, n).astype(np.int32),
                                "v": rng.normal(size=n).astype(np.float32)})
        cols, valid = L.sort_by_key(t.columns, t.valid, "k")
        return float(jnp.sum(jnp.where(valid, cols["v"], 0)))

    # bare metal: run everything sequentially, re-"acquiring" per pipeline
    t0 = time.time()
    for nm in names:
        cylon_fn(None, None)
        infer_fn(None, None, nm)
    bm_s = time.time() - t0

    # Deep RC: one pilot, one shared data-eng task + N overlapped inference
    pipes = []
    for nm in names:
        pipes.append(Pipeline(f"pipe-{nm}", [
            cylon_stage("join", cylon_fn),
            dl_stage("infer", lambda c, u, nm=nm: infer_fn(c, u, nm),
                     deps=("join",), kind="inference"),
        ]))
    t0 = time.time()
    out = run_pipelines(pipes, max_workers=4)
    rc_s = time.time() - t0
    failures = {p.name: out[p.name]["_error"] for p in pipes
                if "_error" in out[p.name]}
    if failures:  # fault isolation records failures; a benchmark must not
        # publish a speedup computed from pipelines that never ran
        raise RuntimeError(f"multi_pipeline: {len(failures)} pipeline(s) "
                           f"failed: {failures}")
    res = {"bm_s": bm_s, "rc_s": rc_s, "saved_s": bm_s - rc_s,
           "n_pipelines": n_pipelines}
    _dump("multi_pipeline", res)
    return [("multi_pipeline/bm", bm_s * 1e6, f"n={n_pipelines}"),
            ("multi_pipeline/deep_rc", rc_s * 1e6, f"saved_s={bm_s - rc_s:.2f}")]


def _dump(name: str, obj) -> None:
    """Merge ``obj`` into results/bench/<name>.json — several benchmarks
    record different sections of the same file (e.g. multi_pipeline.json
    also carries the concurrent_pipelines multi-pilot scenario), so a
    whole-file overwrite would clobber sibling results."""
    from benchmarks.results_io import bench_json, merge_record
    merge_record(bench_json(name), obj)
