"""Fleet serving benchmark: aggregate tokens/s and TTFT through the
EngineRouter at 1/2/4 engines, plus prefill/decode disaggregation vs a
colocated fleet at equal engine count.

Every fleet size replays the IDENTICAL seeded Poisson stream
(``benchmarks/workload.py`` — same prompts, same arrival offsets, same
generation budgets) so the scaling numbers are apples-to-apples with
each other and with the single-engine serving benchmark.  The full run
asserts the tentpole's win: 2 engines must clear >= 1.5x the
single-engine aggregate tokens/s (engines run on independent threads;
jax ops release the GIL, so decode steps genuinely overlap), and the
disaggregated split must improve p95 TTFT on the mixed 80/20
long/short workload vs colocated at the same engine count — dedicated
prefill engines spend every step on prompt chunks instead of
interleaving them between decode steps.

``--quick`` is the CI smoke: sub-second walls are noise, so it asserts
structural invariants only — every engine in a multi-engine fleet
served work, every disaggregated prompt migrated exactly once, and the
handoff moved exactly the pages the request owned (``ceil(prompt_len /
page_size)`` per request, never the pool).

Run standalone:

  PYTHONPATH=src python benchmarks/fleet.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.results_io import bench_json, merge_record
from benchmarks.workload import mixed_workload, percentile, poisson_workload

RESULTS_JSON = bench_json("fleet")


def _drive_router(router, workload, timeout=600.0):
    """Open-loop: submit each request at its arrival offset (the engines
    step themselves on their service threads), then wait for the fleet
    to drain.  Returns (requests, wall_s)."""
    from repro.serve import Request

    pending = [(float(t), Request(p, max_new_tokens=int(g)))
               for t, p, g in workload]
    t0 = time.time()
    for t, req in pending:
        now = time.time() - t0
        if t > now:
            time.sleep(t - now)
        req.submitted_at = time.time()  # latency clock starts at submit
        router.submit(req)
    assert router.drain(timeout=timeout), "fleet did not drain"
    return [r for _, r in pending], time.time() - t0


def _warm_handoff_shapes(eng):
    """Compile the bucketed handoff gather/scatter shapes (one per
    power-of-two page count) before the timed window: page 0's blocks
    are gathered and written back onto page 0, so the pool is bitwise
    unchanged while every XLA shape the migration path can hit gets
    cached."""
    import jax.numpy as jnp
    import numpy as np

    from repro.serve.engine import _map_cache

    b = 1
    while True:
        pids = jnp.asarray(np.zeros(b, np.int32))
        pages = _map_cache(lambda l: np.asarray(l[pids]),
                           lambda l: np.asarray(l[:, pids]), eng.cache)
        eng.cache = _map_cache(
            lambda l, d: l.at[pids].set(jnp.asarray(d, l.dtype)),
            lambda l, d: l.at[:, pids].set(jnp.asarray(d, l.dtype)),
            eng.cache, pages)
        # register in the retrace tracker so the timed window's
        # ``retraces`` stat counts only genuinely cold shapes
        eng._count_retrace("handoff_gather", b)
        eng._count_retrace("handoff_scatter", b)
        if b >= eng.max_pages:
            return
        b = min(b * 2, eng.max_pages)


def _warm_fleet(router):
    """Compile every jit shape the timed window can hit, per engine
    (each engine owns its jit caches), BEFORE the service threads start.
    Warming mutates no serving state — see ``_warm_chunk_shapes``."""
    from benchmarks.serving import _warm_chunk_shapes

    for m in router.members:
        _warm_chunk_shapes(m.engine)
        if m.engine.paged:
            _warm_handoff_shapes(m.engine)
        m.engine.reset_stats()


def _bench_fleet_size(cfg, params, n_engines, workload, *, disaggregate,
                      max_len, quick, num_prefill=None):
    from repro.serve import build_fleet

    router = build_fleet(
        cfg, num_engines=n_engines, disaggregate=disaggregate,
        num_prefill=num_prefill, params=params, max_slots=4,
        max_len=max_len, page_size=16, name_prefix="bench")
    _warm_fleet(router)
    with router:
        reqs, wall = _drive_router(router, workload)
        stats = router.stats()
    assert all(r.done() and r.error is None for r in reqs), "requests failed"
    n_tok = sum(len(r.tokens) for r in reqs)
    ttft = [r.ttft_s for r in reqs]
    lat = [r.latency_s for r in reqs]
    row = {
        "engines": n_engines,
        "disaggregate": disaggregate,
        "requests": len(reqs),
        "generated_tokens": n_tok,
        "wall_s": round(wall, 3),
        "tokens_per_s": round(n_tok / wall, 2),
        "ttft_p50_s": round(percentile(ttft, 0.50), 4),
        "ttft_p95_s": round(percentile(ttft, 0.95), 4),
        "latency_p95_s": round(percentile(lat, 0.95), 4),
        "routed": stats.get("routed", 0),
        "per_engine_routed": {
            k.split("routed_to.")[1]: v for k, v in stats.items()
            if k.startswith("routed_to.")},
        "retraces": sum(e["retraces"] for e in stats["engines"]),
    }
    if disaggregate:
        row.update({
            "handoffs": stats.get("handoffs_routed", 0),
            "handoff_bytes": stats.get("handoff_bytes", 0),
            "handoff_pages": stats.get("handoff_pages", 0),
        })
        # transport invariant: the bytes that crossed engines are exactly
        # the pages the migrating requests owned — never the pool
        page_bytes = router.members[0].engine._page_bytes
        page_size = router.members[0].engine.page_size
        expected = sum(-(-len(p) // page_size) for _, p, _ in workload)
        assert row["handoffs"] == len(reqs), (
            f"every prompt must migrate exactly once: "
            f"{row['handoffs']} handoffs for {len(reqs)} requests")
        assert row["handoff_pages"] == expected, (
            f"handoff must ship exactly the owned pages: "
            f"{row['handoff_pages']} vs {expected}")
        assert row["handoff_bytes"] == expected * page_bytes, (
            "handoff bytes must equal owned pages x page bytes")
    elif n_engines > 1 and not quick:
        # load-aware admission must actually spread a capacity-bound
        # stream (in --quick a tiny stream can drain off one engine)
        assert len(row["per_engine_routed"]) == n_engines, (
            f"all {n_engines} engines must serve: {row['per_engine_routed']}")
    return row


def bench_fleet(quick: bool = False, full: bool = False):
    import jax
    from repro.common.params import init_params
    from repro.configs import get_config
    from repro.train.state import model_specs

    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), model_specs(cfg))
    n_requests = 16 if quick else (96 if full else 48)
    sizes = (1, 2) if quick else (1, 2, 4)

    rows = []
    results = {}

    # -- scaling: the same seeded Poisson stream at every fleet size ------
    stream = poisson_workload(n_requests, seed=7)
    scaling = {}
    for n in sizes:
        r = _bench_fleet_size(cfg, params, n, stream, disaggregate=False,
                              max_len=64, quick=quick)
        scaling[f"engines_{n}"] = r
        rows.append((f"fleet/engines_{n}", r["tokens_per_s"],
                     f"tok_s={r['tokens_per_s']};"
                     f"ttft_p95={r['ttft_p95_s']}s;"
                     f"routed={r['routed']}"))
    base = scaling["engines_1"]["tokens_per_s"]
    for n in sizes[1:]:
        scaling[f"speedup_{n}x"] = round(
            scaling[f"engines_{n}"]["tokens_per_s"] / max(base, 1e-9), 2)
    if not quick:
        # the tentpole's scaling claim — engines overlap on threads (jax
        # releases the GIL), so 2 engines must clear 1.5x one engine
        assert scaling["speedup_2x"] >= 1.5, (
            f"2-engine fleet must reach >=1.5x single-engine tokens/s: "
            f"{scaling['speedup_2x']}x "
            f"({scaling['engines_2']['tokens_per_s']} vs {base})")
    results["scaling"] = scaling

    # -- disaggregation: prefill/decode split vs colocated, equal count ---
    n_disagg = 2 if quick else 4
    mixed = mixed_workload(n_requests, seed=23)
    colo = _bench_fleet_size(cfg, params, n_disagg, mixed,
                             disaggregate=False, max_len=256, quick=quick)
    # size the pools to the workload: the mixed stream is ~2:1 prefill
    # tokens to decode tokens, so at 4 engines the split is 3 prefill +
    # 1 decode — a 50/50 split would starve prefill (TTFT) of exactly
    # the capacity that disaggregation is supposed to dedicate to it
    disagg = _bench_fleet_size(cfg, params, n_disagg, mixed,
                               disaggregate=True, max_len=256, quick=quick,
                               num_prefill=3 if n_disagg == 4 else None)
    improvement = round(
        colo["ttft_p95_s"] / max(disagg["ttft_p95_s"], 1e-9), 2)
    if not quick:
        # dedicated prefill engines spend every step on prompt chunks
        # instead of interleaving them between decode steps
        assert disagg["ttft_p95_s"] < colo["ttft_p95_s"], (
            f"disaggregation must improve p95 TTFT on the mixed workload "
            f"at {n_disagg} engines: {disagg['ttft_p95_s']}s vs "
            f"{colo['ttft_p95_s']}s")
    results["disaggregation"] = {
        "colocated": colo, "disaggregated": disagg,
        "ttft_p95_improvement": improvement,
    }
    rows.append((f"fleet/colocated_{n_disagg}eng", colo["ttft_p95_s"],
                 f"ttft_p95={colo['ttft_p95_s']}s;"
                 f"tok_s={colo['tokens_per_s']}"))
    rows.append((f"fleet/disaggregated_{n_disagg}eng", disagg["ttft_p95_s"],
                 f"ttft_p95={disagg['ttft_p95_s']}s;"
                 f"tok_s={disagg['tokens_per_s']};"
                 f"handoff_MB={disagg['handoff_bytes'] / 1e6:.2f}"))
    rows.append((f"fleet/ttft_p95_improvement_{n_disagg}eng", improvement,
                 f"handoffs={disagg['handoffs']}"))

    if not quick:
        # quick mode is a noise-dominated CI smoke — it must never
        # overwrite the committed full-run numbers
        merge_record(RESULTS_JSON, {"arch": cfg.name,
                                    "n_requests": n_requests, **results})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for name, val, derived in bench_fleet(quick=args.quick):
        print(f"{name},{val:.2f},{derived}")
    if args.quick:
        print("fleet benchmark --quick OK (structural: every disaggregated "
              "prompt migrated exactly once and the handoff shipped exactly "
              "the pages the request owned; throughput scaling and the "
              "TTFT comparison asserted and recorded by the full run only)")
    else:
        print("fleet benchmark OK (2-engine fleet >=1.5x single-engine "
              "aggregate tokens/s on the shared Poisson stream; "
              "disaggregated prefill/decode improves p95 TTFT on the mixed "
              "80/20 workload vs colocated at equal engine count; KV "
              "handoff bytes bounded by the migrating requests' own pages)")
