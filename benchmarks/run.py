"""Benchmark harness entry: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.  --full approaches paper scale."""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--which", default="all",
                    help="comma list: forecasting,hydrology,scaling,"
                         "multi_pipeline,concurrent,roofline,serving,"
                         "decode_kernel,fleet,transport,chaos")
    args = ap.parse_args()
    from benchmarks import paper_tables as P
    from benchmarks import roofline as R
    from benchmarks.chaos import bench_chaos
    from benchmarks.concurrent_pipelines import bench_concurrent_pipelines
    from benchmarks.decode_kernel import bench_decode_kernel
    from benchmarks.fleet import bench_fleet
    from benchmarks.serving import bench_serving
    from benchmarks.transport import bench_transport

    benches = {
        "hydrology": P.bench_hydrology,          # paper Tables 1-2
        "forecasting": P.bench_forecasting,      # paper Table 3
        "scaling": P.bench_scaling_ops,          # paper Fig 4
        "multi_pipeline": P.bench_multi_pipeline,  # paper Table 4
        "concurrent": bench_concurrent_pipelines,  # Table 4, async scheduler
        "roofline": R.bench_roofline,            # beyond-paper: §Roofline
        "serving": bench_serving,                # beyond-paper: continuous batching
        "decode_kernel": bench_decode_kernel,    # beyond-paper: paged flash-decode
        "fleet": bench_fleet,                    # beyond-paper: multi-engine router
        "transport": bench_transport,            # beyond-paper: cross-process exec
        "chaos": bench_chaos,                    # beyond-paper: fault injection
    }
    which = list(benches) if args.which == "all" else args.which.split(",")
    print("name,us_per_call,derived")
    for name in which:
        t0 = time.time()
        try:
            rows = benches[name](full=args.full)
        except Exception as e:  # noqa: BLE001
            print(f"{name},-1,ERROR:{type(e).__name__}:{e}")
            continue
        for r in rows:
            print(f"{r[0]},{r[1]:.2f},{r[2]}")
        print(f"{name}/_total,{(time.time()-t0)*1e6:.0f},", flush=True)


if __name__ == "__main__":
    main()
