"""Concurrent vs serial multi-pipeline scheduling (paper Table 4, async),
plus the multi-pilot placement scenario (Table 4 across per-pod pools).

Scenario 1 (PR 1): N pipelines batched under ONE pilot overlap their
stages on the shared device pool and beat the same N pipelines run
one-at-a-time.

Scenario 2 (this layer, Table 4 across per-pod pools): a single pilot can
only ever be one device pool, so the PR 1 baseline is pinned to one pod —
here HALF the machine running N pipelines.  The multi-pilot scenario
splits the whole machine into two disjoint pods via the PilotManager,
places 2N pipelines plus a greedy wide pipeline (quota-capped at 1
device) across them, and must deliver aggregate overlap >= the
single-pod baseline — the scaling property the placement layer buys.
Asserted invariants: pilot pools are disjoint, placement uses both
pilots, no pipeline exceeds its quota anywhere in the recorded lease
trace, every sibling of the greedy pipeline still completes, and
aggregate overlap factor >= the single-pilot baseline measured in the
same run (both recorded in ``results/bench/multi_pipeline.json``).

Run standalone (forces a multi-device host pool before importing jax):

  PYTHONPATH=src python benchmarks/concurrent_pipelines.py [--quick|--full]

or through the harness: ``python -m benchmarks.run --which concurrent``.
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":  # standalone: emulate a device pool pre-jax
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "bench", "multi_pipeline.json")


def _build_pipelines(n: int, rows: int, quota=None):
    """N two-stage (join -> infer) stage graphs with CPU-bound bodies,
    compiled to named pipelines through the Session DSL."""
    from repro.core import stage

    @stage(kind="data_engineering", name="join")
    def join_fn(ctx, seed):
        rng = np.random.default_rng(seed)
        k = rng.integers(0, rows, rows).astype(np.int32)
        v = rng.normal(size=rows).astype(np.float32)
        order = np.argsort(k, kind="stable")
        return float(np.sum(v[order] * np.arange(rows)))

    @stage(kind="inference", name="infer")
    def infer_fn(ctx, seed):
        x = jnp.asarray(
            np.random.default_rng(seed).normal(size=(256, 128)),
            jnp.float32)
        w = jnp.ones((128, 128), jnp.float32)
        f = jax.jit(lambda x: jnp.tanh(x @ w).sum())
        f(x).block_until_ready()
        acc = 0.0
        for _ in range(40):
            acc += float(f(x))
        return acc + ctx.upstream["join"]

    return [
        (join_fn.bind(i) >> infer_fn.bind(i)).compile(f"pipe{i}", quota=quota)
        for i in range(n)
    ]


def _build_wide_pipeline(n_stages: int, rows: int, quota: int):
    """A greedy pipeline: n_stages independent 1-device stages that would
    grab every free device at once — quota-capped so siblings keep their
    share (the Table-4 fairness scenario)."""
    from repro.core import StageGraph, stage

    @stage(kind="data_engineering")
    def chew(ctx, seed):
        rng = np.random.default_rng(seed)
        k = rng.integers(0, rows, rows).astype(np.int32)
        return float(np.sort(k, kind="stable")[-1])

    return StageGraph([chew.named(f"chew{i}").bind(i)
                       for i in range(n_stages)]).compile("wide", quota=quota)


def _record(update: dict) -> None:
    """Merge new scenario numbers into results/bench/multi_pipeline.json,
    preserving the PR 1 keys already there (paper_tables._dump applies the
    same merge from its side)."""
    from benchmarks.results_io import merge_record
    merge_record(RESULTS_JSON, update)


def bench_concurrent_pipelines(full: bool = False,
                               quick: bool = False) -> List[Tuple]:
    """Rows: serial baseline, concurrent batch, speedup, and the
    multi-pilot scenario.  Fails loudly (in the derived column / via
    assertion) if the scheduler does not beat serial or the multi-pilot
    invariants break.

    Overlap needs >=2 devices; jax device count is fixed at import, so
    when the calling process only has one (the harness path), re-exec the
    standalone script with an emulated 4-device pool and parse its CSV —
    never publish a 1-device "overlap" datapoint.
    """
    from repro.core import Session

    if len(jax.devices()) < 2:
        return _rows_from_subprocess(full, quick)

    n = 4 if quick else (8 if full else 6)
    rows = 60_000 if quick else (400_000 if full else 150_000)
    n_dev = len(jax.devices())

    out_rows: List[Tuple] = []
    if not quick:  # scenario 1 dominates runtime; the CI smoke skips it
        with Session(max_workers_per_pilot=max(n_dev, 2)) as session:
            t0 = time.time()
            for p in _build_pipelines(n, rows):
                session.run_all([p])
            serial_s = time.time() - t0

            t0 = time.time()
            out = session.run_all(_build_pipelines(n, rows))
            concurrent_s = time.time() - t0
            meta = out["_meta"]

        speedup = serial_s / concurrent_s if concurrent_s > 0 else float("inf")
        out_rows += [
            ("concurrent_pipelines/serial", serial_s * 1e6,
             f"n={n};devices={n_dev}"),
            ("concurrent_pipelines/concurrent", concurrent_s * 1e6,
             f"overlap_factor={meta['overlap_factor']:.2f}"),
            ("concurrent_pipelines/speedup", speedup * 1e6,
             f"beats_serial={speedup > 1.0}"),
        ]
    out_rows += bench_multi_pilot(n, rows, n_dev)
    return out_rows


def bench_multi_pilot(n: int, rows: int, n_dev: int) -> List[Tuple]:
    """Scenario 2: single-pod baseline (one pod over half the machine,
    N pipelines — all a single pod can hold) vs a 2-pod Session spreading
    2N pipelines + a quota-capped greedy pipeline over two disjoint pods
    covering the whole machine, each STAGE placed by the Session's
    placement policy.  Records both overlap factors into
    results/bench/multi_pipeline.json."""
    from repro.core import Session
    from repro.core.pilot import PilotDescription

    quota = 1
    pod = max(n_dev // 2, 1)
    wide_stages = max(pod, 4)

    # single-pod baseline (PR 1 mode): one pod, N pipelines, each
    # quota-capped at its natural 1-device width so the cap is enforced
    # (and auditable) in this mode too
    t0 = time.time()
    with Session(pods=[PilotDescription(num_devices=pod, name="solo")],
                 max_workers_per_pilot=max(pod, 2)) as s1:
        single = s1.run_all(_build_pipelines(n, rows, quota=quota))
    single_wall = time.time() - t0
    single_overlap = single["_meta"]["overlap_factor"]

    # multi-pilot: two disjoint per-pod pools, 2N + 1 pipelines whose
    # stages the Session places individually (the workload a single pod
    # cannot span)
    multi_pipes = _build_pipelines(2 * n, rows, quota=quota)
    multi_pipes.append(_build_wide_pipeline(wide_stages, rows, quota))
    t0 = time.time()
    with Session(pods=2) as s2:
        multi = s2.run_all(multi_pipes)
        pilots2 = s2.pilots
    multi_wall = time.time() - t0
    mmeta = multi["_meta"]
    multi_overlap = mmeta["overlap_factor"]

    # invariants
    pools = [frozenset(d.id for d in p.alive_devices()) for p in pilots2]
    assert len(pools) >= 2, f"expected >=2 pilots, got {len(pools)}"
    for i in range(len(pools)):
        for j in range(i + 1, len(pools)):
            assert not pools[i] & pools[j], (
                f"pilot pools overlap: {pools[i] & pools[j]}")
    used = {uid for stages in mmeta["placement"].values()
            for uid in stages.values()}
    assert len(used) >= 2, (
        f"placement used one pilot only: {mmeta['placement']}")
    assert mmeta["quota_violations"] == {}, mmeta["quota_violations"]
    # SUM across agents: quota'd pipelines stick to one pod (Session
    # sticky placement), so the pipeline-WIDE cap must hold even when
    # every agent's local ledger is combined
    peaks_by_group: dict = {}
    for peaks in mmeta["group_peaks"].values():
        for g, peak in peaks.items():
            peaks_by_group[g] = peaks_by_group.get(g, 0) + peak
    over = {g: p for g, p in peaks_by_group.items() if p > quota}
    assert not over, f"lease trace shows pipelines over quota: {over}"
    for name in list(mmeta["per_pipeline"]):
        assert mmeta["per_pipeline"][name]["error"] is None, (
            name, mmeta["per_pipeline"][name]["error"])
    # no tolerance needed: the margin is structural (~2x), not timing —
    # the multi-pilot run drives two pods with 2N+1 pipelines against a
    # one-pod baseline, so noise would have to halve overlap to flake
    assert multi_overlap >= single_overlap, (
        f"multi-pilot overlap {multi_overlap:.2f} below single-pilot "
        f"baseline {single_overlap:.2f}")

    _record({
        "single_pilot": {
            "overlap_factor": round(single_overlap, 3),
            "wall_s": round(single_wall, 3),
            "n_pipelines": n,
            "devices": pod,
        },
        "multi_pilot": {
            "overlap_factor": round(multi_overlap, 3),
            "wall_s": round(multi_wall, 3),
            "n_pipelines": 2 * n + 1,
            "devices": n_dev,
            "pilots": mmeta["pilots"],
            "placement": mmeta["placement"],
            "quota": quota,
            "group_peaks": peaks_by_group,
            "quota_violations": mmeta["quota_violations"],
            "migrations": len(mmeta["migrations"]),
        },
    })
    return [
        ("concurrent_pipelines/single_pilot_overlap", single_overlap * 1e6,
         f"overlap_factor={single_overlap:.2f};pod={pod}dev;n={n}"),
        ("concurrent_pipelines/multi_pilot_overlap", multi_overlap * 1e6,
         f"overlap_factor={multi_overlap:.2f};pilots={len(pools)};"
         f"n={2 * n + 1};wide_peak={peaks_by_group.get('wide', 0)};"
         f"quota_ok={not over}"),
    ]


def _rows_from_subprocess(full: bool, quick: bool = False) -> List[Tuple]:
    import subprocess

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    cmd = [sys.executable, os.path.abspath(__file__)]
    if full:
        cmd.append("--full")
    if quick:
        cmd.append("--quick")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=1800,
                       env=env, cwd=repo)
    if r.returncode != 0:
        raise RuntimeError(
            f"standalone concurrent_pipelines failed:\n{r.stdout[-2000:]}\n"
            f"{r.stderr[-2000:]}")
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("concurrent_pipelines/"):
            name, us, derived = line.split(",", 2)
            rows.append((name, float(us), derived))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: skip the serial baseline, small rows")
    args = ap.parse_args()
    n_dev = len(jax.devices())
    assert n_dev >= 2, (
        f"need >=2 devices for an overlap benchmark, have {n_dev}; set "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4")
    rows = bench_concurrent_pipelines(full=args.full, quick=args.quick)
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
    if not args.quick:
        by_name = {r[0]: r for r in rows}
        speedup = by_name["concurrent_pipelines/speedup"][1] / 1e6
        assert speedup > 1.0, f"concurrent did not beat serial ({speedup:.2f}x)"
        print(f"concurrent_pipelines OK ({speedup:.2f}x over serial on "
              f"{n_dev} devices)")
    else:
        print(f"concurrent_pipelines --quick OK (multi-pilot + quota "
              f"invariants held on {n_dev} devices)")
