"""Concurrent vs serial multi-pipeline scheduling (paper Table 4, async).

Measures the tentpole property of the event-driven scheduler: N pipelines
batched under one pilot overlap their stages on the shared device pool and
beat the same N pipelines run one-at-a-time.  Each pipeline is a
data-engineering stage feeding an inference stage, sized so per-stage work
dominates scheduling overhead.

Run standalone (forces a multi-device host pool before importing jax):

  PYTHONPATH=src python benchmarks/concurrent_pipelines.py [--pipelines 6]

or through the harness: ``python -m benchmarks.run --which concurrent``.
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":  # standalone: emulate a device pool pre-jax
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _build_pipelines(n: int, rows: int):
    """N two-stage (join -> infer) pipelines with CPU-bound stage bodies."""
    from repro.core.bridge import cylon_stage, dl_stage
    from repro.core.pipeline import Pipeline

    def join_fn(comm, upstream, seed):
        rng = np.random.default_rng(seed)
        k = rng.integers(0, rows, rows).astype(np.int32)
        v = rng.normal(size=rows).astype(np.float32)
        order = np.argsort(k, kind="stable")
        return float(np.sum(v[order] * np.arange(rows)))

    def infer_fn(comm, upstream, seed):
        x = jnp.asarray(
            np.random.default_rng(seed).normal(size=(256, 128)),
            jnp.float32)
        w = jnp.ones((128, 128), jnp.float32)
        f = jax.jit(lambda x: jnp.tanh(x @ w).sum())
        f(x).block_until_ready()
        acc = 0.0
        for _ in range(40):
            acc += float(f(x))
        return acc + upstream["join"]

    pipes = []
    for i in range(n):
        pipes.append(Pipeline(f"pipe{i}", [
            cylon_stage("join", lambda c, u, s=i: join_fn(c, u, s)),
            dl_stage("infer", lambda c, u, s=i: infer_fn(c, u, s),
                     deps=("join",), kind="inference"),
        ]))
    return pipes


def bench_concurrent_pipelines(full: bool = False) -> List[Tuple]:
    """Rows: serial baseline, concurrent batch, speedup.  Fails loudly (in
    the derived column) if the scheduler does not beat serial.

    Overlap needs >=2 devices; jax device count is fixed at import, so
    when the calling process only has one (the harness path), re-exec the
    standalone script with an emulated 4-device pool and parse its CSV —
    never publish a 1-device "overlap" datapoint.
    """
    from repro.core.pilot import PilotDescription, PilotManager
    from repro.core.pipeline import run_pipelines

    if len(jax.devices()) < 2:
        return _rows_from_subprocess(full)

    n = 8 if full else 6
    rows = 400_000 if full else 150_000
    pm = PilotManager()
    pilot = pm.submit_pilot(PilotDescription())
    n_dev = pilot.size

    # serial baseline: same pilot, one pipeline at a time
    t0 = time.time()
    for p in _build_pipelines(n, rows):
        run_pipelines([p], pilot=pilot, max_workers=max(n_dev, 2))
    serial_s = time.time() - t0

    t0 = time.time()
    out = run_pipelines(_build_pipelines(n, rows), pilot=pilot,
                        max_workers=max(n_dev, 2))
    concurrent_s = time.time() - t0
    meta = out["_meta"]

    speedup = serial_s / concurrent_s if concurrent_s > 0 else float("inf")
    return [
        ("concurrent_pipelines/serial", serial_s * 1e6,
         f"n={n};devices={n_dev}"),
        ("concurrent_pipelines/concurrent", concurrent_s * 1e6,
         f"overlap_factor={meta['overlap_factor']:.2f}"),
        ("concurrent_pipelines/speedup", speedup * 1e6,
         f"beats_serial={speedup > 1.0}"),
    ]


def _rows_from_subprocess(full: bool) -> List[Tuple]:
    import subprocess

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    cmd = [sys.executable, os.path.abspath(__file__)]
    if full:
        cmd.append("--full")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=1800,
                       env=env, cwd=repo)
    if r.returncode != 0:
        raise RuntimeError(
            f"standalone concurrent_pipelines failed:\n{r.stdout[-2000:]}\n"
            f"{r.stderr[-2000:]}")
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("concurrent_pipelines/"):
            name, us, derived = line.split(",", 2)
            rows.append((name, float(us), derived))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    n_dev = len(jax.devices())
    assert n_dev >= 2, (
        f"need >=2 devices for an overlap benchmark, have {n_dev}; set "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4")
    rows = bench_concurrent_pipelines(full=args.full)
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
    speedup = rows[2][1] / 1e6
    assert speedup > 1.0, f"concurrent did not beat serial ({speedup:.2f}x)"
    print(f"concurrent_pipelines OK ({speedup:.2f}x over serial on "
          f"{n_dev} devices)")
