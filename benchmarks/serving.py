"""Continuous-batching serving benchmark: tokens/s and request latency
under a Poisson-ish open-loop arrival schedule, at several slot counts,
against the static-batch baseline.

Static batching (the seed driver's model: admit a batch, decode until the
WHOLE batch finishes) holds freed slots hostage to the longest generation
in the batch; continuous batching refills freed slots between decode
steps.  With mixed request lengths the occupancy gap is structural, so
continuous must beat static on tokens/s — asserted here and recorded in
``results/bench/serving.json`` (merge-preserving, like the other bench
writers).

Run standalone:

  PYTHONPATH=src python benchmarks/serving.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.results_io import bench_json, merge_record

RESULTS_JSON = bench_json("serving")


def _workload(n_requests: int, seed: int = 0):
    """Mixed-length prompts/budgets + exponential inter-arrival offsets.
    Generation budgets span 4-48 tokens: the wide spread is what makes
    static batching hold finished slots hostage to the batch straggler."""
    rng = np.random.default_rng(seed)
    prompt_lens = rng.integers(4, 9, n_requests)
    gens = rng.integers(4, 49, n_requests)
    gaps = rng.exponential(scale=0.01, size=n_requests)
    arrivals = np.cumsum(gaps)
    arrivals[0] = 0.0
    prompts = [rng.integers(1, 250, int(l)).astype(np.int32)
               for l in prompt_lens]
    return list(zip(arrivals, prompts, gens))


def _drive(engine, workload):
    """Open-loop: submit each request at its arrival offset while stepping
    the engine; returns (requests, wall_s)."""
    from repro.serve import Request

    pending = [(float(t), Request(p, max_new_tokens=int(g)))
               for t, p, g in workload]
    reqs = [r for _, r in pending]
    i = 0
    t0 = time.time()
    while i < len(pending) or engine.has_work():
        now = time.time() - t0
        while i < len(pending) and pending[i][0] <= now:
            req = pending[i][1]
            req.submitted_at = time.time()  # latency clock starts at submit
            engine.submit(req)
            i += 1
        if not engine.step() and i < len(pending):
            time.sleep(min(0.001, max(0.0, pending[i][0] - now)))
    return reqs, time.time() - t0


def _percentile(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


def _bench_one(cfg, params, slots, n_requests, continuous, seed):
    from repro.configs.base import RunConfig
    from repro.serve import ServeEngine

    max_len = 64  # fits prompt<=8 + gen<=48 with headroom
    eng = ServeEngine(cfg, RunConfig(), max_slots=slots, max_len=max_len,
                      params=params, continuous=continuous)
    # warm the jit caches (every power-of-two prefill batch bucket + the
    # fused decode) so the timed window measures serving, not compilation
    n = 1
    while n <= slots:
        for _ in range(n):
            eng.submit(np.arange(1, 7, dtype=np.int32), max_new_tokens=2)
        eng.run_until_drained()
        n *= 2
    eng.reset_stats()

    reqs, wall = _drive(eng, _workload(n_requests, seed))
    assert all(r.done() and r.error is None for r in reqs), "requests failed"
    n_tok = sum(len(r.tokens) for r in reqs)
    lat = [r.latency_s for r in reqs]
    stats = eng.stats()
    return {
        "mode": "continuous" if continuous else "static",
        "slots": slots,
        "requests": len(reqs),
        "generated_tokens": n_tok,
        "wall_s": round(wall, 3),
        "tokens_per_s": round(n_tok / wall, 2),
        "latency_p50_s": round(_percentile(lat, 0.50), 4),
        "latency_p95_s": round(_percentile(lat, 0.95), 4),
        "ttft_p50_s": round(_percentile([r.ttft_s for r in reqs], 0.50), 4),
        "decode_steps": stats["decode_steps"],
        "slot_occupancy": round(stats["slot_occupancy"], 3),
    }


def bench_serving(quick: bool = False, full: bool = False):
    import jax
    from repro.common.params import init_params
    from repro.configs import get_config
    from repro.train.state import model_specs

    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), model_specs(cfg))
    n_requests = 10 if quick else (64 if full else 32)
    slot_counts = (2,) if quick else (2, 4, 8)

    rows = []
    results = {}
    for slots in slot_counts:
        cont = _bench_one(cfg, params, slots, n_requests, True, seed=7)
        stat = _bench_one(cfg, params, slots, n_requests, False, seed=7)
        speedup = cont["tokens_per_s"] / max(stat["tokens_per_s"], 1e-9)
        if quick:
            # CI smoke: sub-second walls are noise-dominated, so assert
            # the structural invariant — continuous keeps slots fuller
            assert cont["slot_occupancy"] > stat["slot_occupancy"], (
                f"continuous occupancy must beat static at {slots} slots: "
                f"{cont['slot_occupancy']} vs {stat['slot_occupancy']}")
        else:
            assert cont["tokens_per_s"] > stat["tokens_per_s"], (
                f"continuous batching must beat static at {slots} slots: "
                f"{cont['tokens_per_s']} vs {stat['tokens_per_s']} tok/s")
        results[f"slots_{slots}"] = {
            "continuous": cont, "static": stat,
            "tokens_per_s_speedup": round(speedup, 2),
        }
        rows.append((f"serving/continuous_{slots}slots",
                     cont["tokens_per_s"],
                     f"tok_s={cont['tokens_per_s']};occ={cont['slot_occupancy']};"
                     f"p95={cont['latency_p95_s']}s"))
        rows.append((f"serving/static_{slots}slots",
                     stat["tokens_per_s"],
                     f"tok_s={stat['tokens_per_s']};occ={stat['slot_occupancy']};"
                     f"speedup={speedup:.2f}x"))
    if not quick:
        # quick mode is a noise-dominated CI smoke — it must never
        # overwrite the committed full-run numbers
        merge_record(RESULTS_JSON, {"arch": cfg.name,
                                    "n_requests": n_requests, **results})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for name, val, derived in bench_serving(quick=args.quick):
        print(f"{name},{val:.2f},{derived}")
    if args.quick:
        print("serving benchmark --quick OK (continuous occupancy > static; "
              "tokens/s asserted and recorded by the full run only)")
    else:
        print("serving benchmark OK (continuous > static tokens/s at every "
              "slot count)")
