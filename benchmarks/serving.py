"""Continuous-batching serving benchmark: tokens/s and request latency
under a Poisson-ish open-loop arrival schedule, at several slot counts,
against the static-batch baseline — plus the KV-layout comparison
(PR-3 contiguous reference vs vector-length kernel vs paged kernel) and
the chunked-prefill comparison (bounded prefill chunks interleaved with
decode vs whole-prompt prefill) on a mixed long/short-prompt workload,
reporting the inter-token stall tail (per-request worst gap p95, global
p99/max) and TTFT.

Static batching (the seed driver's model: admit a batch, decode until the
WHOLE batch finishes) holds freed slots hostage to the longest generation
in the batch; continuous batching refills freed slots between decode
steps.  With mixed request lengths the occupancy gap is structural, so
continuous must beat static on tokens/s — asserted here and recorded in
``results/bench/serving.json`` (merge-preserving, like the other bench
writers).

The layout comparison runs the same open-loop workload through three
engines at slots 4/8/16: the PR-3 baseline (contiguous ``[max_slots,
max_len]`` rows, jnp reference decode), the vector-length kernel on the
contiguous layout, and the paged engine (shared page pool + block
tables, ``kernels/ops.decode_attention_paged``).  The paged engine must
match or beat the contiguous baseline on tokens/s while holding strictly
fewer KV cache bytes per live token (it gathers only its allocated
pages; the contiguous layouts hold the full rectangle).  ``impl`` values
are recorded as *resolved* by ``kernels/ops`` ("pallas" on TPU, "ref"
elsewhere — see the per-op microbench in ``benchmarks/decode_kernel.py``
for the kernel-vs-oracle numbers in interpret mode).

Run standalone:

  PYTHONPATH=src python benchmarks/serving.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.results_io import bench_json, merge_record
from benchmarks.workload import (
    mixed_workload as _mixed_workload,
    percentile as _percentile,
    poisson_workload as _workload,
)

RESULTS_JSON = bench_json("serving")


def _drive(engine, workload):
    """Open-loop: submit each request at its arrival offset while stepping
    the engine; returns (requests, wall_s)."""
    from repro.serve import Request

    pending = [(float(t), Request(p, max_new_tokens=int(g)))
               for t, p, g in workload]
    reqs = [r for _, r in pending]
    i = 0
    t0 = time.time()
    while i < len(pending) or engine.has_work():
        now = time.time() - t0
        while i < len(pending) and pending[i][0] <= now:
            req = pending[i][1]
            req.submitted_at = time.time()  # latency clock starts at submit
            engine.submit(req)
            i += 1
        if not engine.step() and i < len(pending):
            time.sleep(min(0.001, max(0.0, pending[i][0] - now)))
    return reqs, time.time() - t0


def _warm_engine(eng, slots, max_gen):
    """Warm every jit shape bucket the timed window will hit: power-of-two
    prefill batch buckets x the workload's prompt-length buckets (4 and
    8 — the floor is 2 now, so short batches get their own shape), then a
    full batch generating to the workload's longest request so every
    decode page/length bucket compiles.  The engine's ``retraces`` stat
    verifies the timed window stayed warm."""
    n = 1
    while n <= slots:
        for plen in (3, 6):  # P buckets 4 and 8
            for _ in range(n):
                eng.submit(np.arange(1, 1 + plen, dtype=np.int32),
                           max_new_tokens=2)
            eng.run_until_drained()
        n *= 2
    for _ in range(slots):
        eng.submit(np.arange(1, 9, dtype=np.int32), max_new_tokens=max_gen)
    eng.run_until_drained()
    eng.reset_stats()


def _bench_one(cfg, params, slots, n_requests, continuous, seed, *,
               kv_layout="contiguous", decode_impl="auto", max_len=64,
               max_gen=48):
    from repro.configs.base import RunConfig
    from repro.kernels.ops import _resolve_decode
    from repro.serve import ServeEngine

    eng = ServeEngine(cfg, RunConfig(), max_slots=slots, max_len=max_len,
                      params=params, continuous=continuous,
                      kv_layout=kv_layout, decode_impl=decode_impl)
    _warm_engine(eng, slots, max_gen)

    reqs, wall = _drive(eng, _workload(n_requests, seed))
    assert all(r.done() and r.error is None for r in reqs), "requests failed"
    n_tok = sum(len(r.tokens) for r in reqs)
    lat = [r.latency_s for r in reqs]
    stats = eng.stats()
    return {
        "mode": "continuous" if continuous else "static",
        "kv_layout": kv_layout,
        "decode_impl": _resolve_decode(decode_impl),
        "slots": slots,
        "max_len": max_len,
        "requests": len(reqs),
        "generated_tokens": n_tok,
        "wall_s": round(wall, 3),
        "tokens_per_s": round(n_tok / wall, 2),
        "latency_p50_s": round(_percentile(lat, 0.50), 4),
        "latency_p95_s": round(_percentile(lat, 0.95), 4),
        "ttft_p50_s": round(_percentile([r.ttft_s for r in reqs], 0.50), 4),
        "decode_steps": stats["decode_steps"],
        "slot_occupancy": round(stats["slot_occupancy"], 3),
        "kv_bytes_per_token": round(stats["kv_bytes_per_token"], 1),
        "kv_cache_capacity_bytes": stats["kv_cache_capacity_bytes"],
        "retraces": stats["retraces"],
    }


def _bench_layouts(cfg, params, slots, n_requests, quick):
    """Same open-loop workload through the three serving configurations;
    the engine (and its jit caches) is reused across repeats, so the
    best-of-N tokens/s is warm steady-state, not compilation."""
    from repro.configs.base import RunConfig
    from repro.kernels.ops import _resolve_decode
    from repro.serve import ServeEngine

    max_len, reps = 256, (1 if quick else 2)
    # the kernel_contiguous arm isolates the vector-length kernel: real
    # Pallas on TPU; elsewhere interpret-mode Pallas — "auto" would
    # resolve to the same jnp oracle as ref_contiguous and measure
    # nothing but noise
    kc_impl = "auto" if _resolve_decode("auto") == "pallas" else "interpret"
    out = {}
    for name, layout, impl in (("ref_contiguous", "contiguous", "ref"),
                               ("kernel_contiguous", "contiguous", kc_impl),
                               ("kernel_paged", "paged", "auto")):
        eng = ServeEngine(cfg, RunConfig(), max_slots=slots, max_len=max_len,
                          params=params, continuous=True, kv_layout=layout,
                          decode_impl=impl)
        _warm_engine(eng, slots, 48)
        best = None
        for _ in range(reps):
            reqs, wall = _drive(eng, _workload(n_requests, seed=11))
            assert all(r.done() and r.error is None for r in reqs), (
                f"{name}: requests failed")
            n_tok = sum(len(r.tokens) for r in reqs)
            stats = eng.stats()
            row = {
                "kv_layout": layout,
                "decode_impl": _resolve_decode(impl),
                "slots": slots,
                "max_len": max_len,
                "tokens_per_s": round(n_tok / wall, 2),
                "kv_bytes_per_token": round(stats["kv_bytes_per_token"], 1),
                "kv_cache_capacity_bytes": stats["kv_cache_capacity_bytes"],
                "slot_occupancy": round(stats["slot_occupancy"], 3),
                "retraces": stats["retraces"],
            }
            if layout == "paged":
                row["peak_pages"] = stats.get("peak_pages", 0)
                row["page_size"] = stats["page_size"]
            if best is None or row["tokens_per_s"] > best["tokens_per_s"]:
                best = row
            eng.reset_stats()
        out[name] = best
    paged, base = out["kernel_paged"], out["ref_contiguous"]
    # the paged pool holds only its allocated pages; contiguous layouts
    # hold the full [max_slots, max_len] rectangle — strict at any scale
    assert paged["kv_bytes_per_token"] < base["kv_bytes_per_token"], (
        f"paged must hold fewer KV bytes per live token at {slots} slots: "
        f"{paged['kv_bytes_per_token']} vs {base['kv_bytes_per_token']}")
    if not quick:
        # noise-dominated in --quick; the full run asserts the throughput
        assert paged["tokens_per_s"] >= base["tokens_per_s"], (
            f"paged engine must match the contiguous baseline at {slots} "
            f"slots: {paged['tokens_per_s']} vs {base['tokens_per_s']} tok/s")
    out["paged_speedup"] = round(
        paged["tokens_per_s"] / max(base["tokens_per_s"], 1e-9), 2)
    out["paged_bytes_ratio"] = round(
        paged["kv_bytes_per_token"] / max(base["kv_bytes_per_token"], 1e-9),
        3)
    return out


def _warm_chunk_shapes(eng):
    """Compile every (chunk-bucket, page-bucket) prefill shape AND every
    decode page bucket the open loop can hit — which combos a drive
    actually produces depends on arrival interleaving, so an untimed
    drive alone can leave shapes to compile inside the timed window (an
    ~800ms gap that swamps the ITL tail).  Warming mutates no serving
    state: inert prefill calls (chunk_lens == 0) write nothing, and
    all-inactive decode calls against sentinel tables drop their junk
    appends.  Warmed shapes are registered in the engine's retrace
    tracker so the timed window's ``retraces`` stat stays meaningful."""
    import jax.numpy as jnp

    def _buckets(hi, lo):
        b, out = lo, []
        while True:
            out.append(b)
            if b >= hi:
                return out
            b = min(b * 2, hi)

    budget = eng.prefill_chunk_tokens or eng.max_len
    zeros = jnp.zeros((eng.max_slots,), jnp.int32)
    mbs = _buckets(eng.max_pages, 1) if eng.paged else [None]
    for T in _buckets(budget, 2):
        tokens = jnp.zeros((eng.max_slots, T), jnp.int32)
        fn = eng._get_prefill(T)
        for mb in mbs:
            bt = (jnp.asarray(eng.block_table[:, :mb]) if eng.paged
                  else None)
            _, _, eng.cache = fn(eng.params, tokens, zeros, zeros,
                                 eng.cache, bt)
            eng._count_retrace("prefill", (T, mb) if eng.paged else (T,))
    inactive = jnp.zeros((eng.max_slots,), bool)
    keys = jnp.zeros((eng.max_slots, 2), jnp.uint32)
    f32z = jnp.zeros((eng.max_slots,), jnp.float32)
    for mb in mbs:
        args = (eng.params, zeros, eng.cache, zeros, inactive, keys,
                f32z, zeros)
        if eng.paged:
            bt = jnp.full((eng.max_slots, mb), eng.num_pages, jnp.int32)
            args = args + (bt,)
            eng._count_retrace("decode", (mb, False))
        else:
            eng._count_retrace("decode", (eng.max_len, False))
        _, _, eng.cache = eng._decode(*args, sampling=False)


def _bench_chunked(cfg, params, slots, n_requests, quick):
    """Chunked vs whole-prompt prefill on the mixed long/short workload
    (paged engine, same arrivals): the chunked engine spends at most
    ``prefill_chunk_tokens`` prompt tokens per step, so decode tails see
    bounded stalls — a lower inter-token stall tail (p95 of each
    request's worst gap, global p99) for the short requests queued
    behind a long prompt.  Chunking trades a slightly fatter
    mid-distribution (most steps carry a prefill chunk) for that bounded
    tail, so the stall metrics are the ones asserted."""
    from repro.configs.base import RunConfig
    from repro.serve import ServeEngine

    max_len = 256
    out = {}
    for name, chunk in (("unchunked", None), ("chunked", 32)):
        eng = ServeEngine(cfg, RunConfig(), max_slots=slots,
                          max_len=max_len, params=params, continuous=True,
                          kv_layout="paged", prefill_chunk_tokens=chunk)
        # warm decode/sampler shapes with one untimed pass, then compile
        # every chunk shape the timed interleaving could produce
        _drive(eng, _mixed_workload(n_requests, seed=5))
        _warm_chunk_shapes(eng)
        eng.reset_stats()
        reqs, wall = _drive(eng, _mixed_workload(n_requests, seed=23))
        assert all(r.done() and r.error is None for r in reqs), (
            f"{name}: requests failed")
        n_tok = sum(len(r.tokens) for r in reqs)
        itl = [g for r in reqs for g in r.inter_token_s]
        # per-request worst gap: the stall each individual request saw —
        # the whole-prompt prefill stalls land here even when short
        # requests dilute them below the global distribution's p95
        stalls = [max(r.inter_token_s) for r in reqs if r.inter_token_s]
        ttft = [r.ttft_s for r in reqs]
        stats = eng.stats()
        out[name] = {
            "prefill_chunk_tokens": chunk,
            "slots": slots,
            "max_len": max_len,
            "tokens_per_s": round(n_tok / wall, 2),
            "itl_p50_s": round(_percentile(itl, 0.50), 4),
            "itl_p99_s": round(_percentile(itl, 0.99), 4),
            "itl_max_s": round(max(itl), 4),
            "itl_stall_p95_s": round(_percentile(stalls, 0.95), 4),
            "ttft_p50_s": round(_percentile(ttft, 0.50), 4),
            "ttft_p95_s": round(_percentile(ttft, 0.95), 4),
            "prefill_chunks": stats.get("prefill_chunks", 0),
            "prefill_tokens": stats.get("prefill_tokens", 0),
            "retraces": stats["retraces"],
        }
    ch, un = out["chunked"], out["unchunked"]
    # structural invariant (holds even in noisy --quick runs): the same
    # prompt tokens arrive in strictly more, strictly smaller chunks
    assert ch["prefill_chunks"] > un["prefill_chunks"], (
        f"chunked must split prefills: {ch['prefill_chunks']} chunks vs "
        f"{un['prefill_chunks']}")
    if not quick:
        # the tentpole's win: bounding per-step prefill work bounds the
        # decode stalls that land in the inter-token tail — p95 of each
        # request's worst gap, and the global p99
        assert ch["itl_stall_p95_s"] < un["itl_stall_p95_s"], (
            f"chunked prefill must improve p95 inter-token stall at "
            f"{slots} slots: {ch['itl_stall_p95_s']}s vs "
            f"{un['itl_stall_p95_s']}s")
        assert ch["itl_p99_s"] < un["itl_p99_s"], (
            f"chunked prefill must improve p99 inter-token latency at "
            f"{slots} slots: {ch['itl_p99_s']}s vs {un['itl_p99_s']}s")
    out["itl_stall_p95_improvement"] = round(
        un["itl_stall_p95_s"] / max(ch["itl_stall_p95_s"], 1e-9), 2)
    return out


def bench_serving(quick: bool = False, full: bool = False):
    import jax
    from repro.common.params import init_params
    from repro.configs import get_config
    from repro.train.state import model_specs

    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), model_specs(cfg))
    n_requests = 10 if quick else (64 if full else 32)
    slot_counts = (2,) if quick else (2, 4, 8)

    rows = []
    results = {}
    for slots in slot_counts:
        cont = _bench_one(cfg, params, slots, n_requests, True, seed=7)
        stat = _bench_one(cfg, params, slots, n_requests, False, seed=7)
        speedup = cont["tokens_per_s"] / max(stat["tokens_per_s"], 1e-9)
        if quick:
            # CI smoke: sub-second walls are noise-dominated, so assert
            # the structural invariant — continuous keeps slots fuller
            assert cont["slot_occupancy"] > stat["slot_occupancy"], (
                f"continuous occupancy must beat static at {slots} slots: "
                f"{cont['slot_occupancy']} vs {stat['slot_occupancy']}")
        else:
            assert cont["tokens_per_s"] > stat["tokens_per_s"], (
                f"continuous batching must beat static at {slots} slots: "
                f"{cont['tokens_per_s']} vs {stat['tokens_per_s']} tok/s")
        results[f"slots_{slots}"] = {
            "continuous": cont, "static": stat,
            "tokens_per_s_speedup": round(speedup, 2),
        }
        rows.append((f"serving/continuous_{slots}slots",
                     cont["tokens_per_s"],
                     f"tok_s={cont['tokens_per_s']};occ={cont['slot_occupancy']};"
                     f"p95={cont['latency_p95_s']}s"))
        rows.append((f"serving/static_{slots}slots",
                     stat["tokens_per_s"],
                     f"tok_s={stat['tokens_per_s']};occ={stat['slot_occupancy']};"
                     f"speedup={speedup:.2f}x"))

    # KV-layout comparison: PR-3 contiguous reference vs vector-length
    # kernel vs paged kernel, same open-loop workload
    for slots in ((4,) if quick else (4, 8, 16)):
        lay = _bench_layouts(cfg, params, slots, n_requests, quick)
        results[f"layout_slots_{slots}"] = lay
        for name in ("ref_contiguous", "kernel_contiguous", "kernel_paged"):
            r = lay[name]
            rows.append((f"serving/{name}_{slots}slots",
                         r["tokens_per_s"],
                         f"tok_s={r['tokens_per_s']};"
                         f"kvB_per_tok={r['kv_bytes_per_token']};"
                         f"impl={r['decode_impl']}"))
        rows.append((f"serving/paged_speedup_{slots}slots",
                     lay["paged_speedup"],
                     f"bytes_ratio={lay['paged_bytes_ratio']}"))

    # chunked-prefill comparison: mixed long/short prompts, the
    # inter-token stall tail (per-request worst gap p95, global p99)
    # and TTFT
    for slots in ((4,) if quick else (4, 8)):
        mix = _bench_chunked(cfg, params, slots, n_requests, quick)
        results[f"mixed_slots_{slots}"] = mix
        for name in ("unchunked", "chunked"):
            r = mix[name]
            rows.append((f"serving/{name}_mixed_{slots}slots",
                         r["itl_stall_p95_s"],
                         f"itl_stall_p95={r['itl_stall_p95_s']}s;"
                         f"itl_p99={r['itl_p99_s']}s;"
                         f"ttft_p95={r['ttft_p95_s']}s;"
                         f"tok_s={r['tokens_per_s']}"))
        rows.append((f"serving/chunked_stall_p95_improvement_{slots}slots",
                     mix["itl_stall_p95_improvement"],
                     f"chunk={mix['chunked']['prefill_chunk_tokens']}tok"))

    if not quick:
        # quick mode is a noise-dominated CI smoke — it must never
        # overwrite the committed full-run numbers
        merge_record(RESULTS_JSON, {"arch": cfg.name,
                                    "n_requests": n_requests, **results})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for name, val, derived in bench_serving(quick=args.quick):
        print(f"{name},{val:.2f},{derived}")
    if args.quick:
        print("serving benchmark --quick OK (continuous occupancy > static; "
              "paged holds fewer KV bytes/token; chunked prefill splits "
              "mixed-workload prompts; tokens/s and the inter-token "
              "stall tail asserted and recorded by the full run only)")
    else:
        print("serving benchmark OK (continuous > static tokens/s at every "
              "slot count; paged >= contiguous baseline tokens/s with "
              "strictly fewer KV bytes per token at slots 4/8/16; chunked "
              "prefill improves the p95 inter-token stall (per-request "
              "worst gap) and p99 inter-token latency on the mixed "
              "long/short workload)")
