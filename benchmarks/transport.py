"""Cross-process transport overhead + multi-pipeline overlap (ISSUE 9).

Three questions about ``repro.core.exec.SubprocessTransport``:

1. **Startup** — what does a worker-daemon pool cost before the first
   result comes back (process spawn + jax import + hello/ready RPC)?
2. **RPC round-trip** — steady-state per-task overhead of the
   length-prefixed pickle channel vs an in-process thread hop.
3. **Overlap** — N single-stage pipelines with *GIL-bound* bodies run
   through a Session: in-process threads serialise on the interpreter
   lock, subprocess workers genuinely parallelise.  This is the workload
   class the transport exists for (the paper's data-engineering stages
   are exactly such Python-heavy bodies).

Startup is amortised by design — workers are long-lived daemons, so the
pool cost is paid once per Session, not per task; the recorded number is
what that amortisation buys.  Results merge into
``results/bench/transport.json``.

Run standalone (forces a multi-device host pool before importing jax):

  PYTHONPATH=src python -m benchmarks.transport [--quick|--full]

or through the harness: ``python -m benchmarks.run --which transport``.
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":  # standalone: emulate a device pool pre-jax
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    if getattr(sys.modules["__main__"], "__spec__", None) is None:
        # invoked as `python benchmarks/transport.py`: a spec-less
        # __main__ can't satisfy the picklable-task contract (workers
        # import task fns by qualified name), so re-enter through runpy,
        # which runs the module AS `python -m benchmarks.transport`
        import runpy
        runpy.run_module("benchmarks.transport", run_name="__main__",
                         alter_sys=True)
        sys.exit(0)

import time
from typing import List, Tuple

import jax

from repro.core import StageGraph, stage

RESULTS_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "bench", "transport.json")


def _ping(x):
    """Trivial task: measures pure channel + scheduling overhead."""
    return x


def _cores() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # non-linux
        return os.cpu_count() or 1


# module-level (not nested in the builder) so it crosses the subprocess
# transport's pickle boundary by qualified name
@stage(kind="data_engineering", name="spin")
def spin_stage(ctx, seed: int, iters: int) -> float:
    """GIL-bound stage body: a pure-Python accumulation loop that holds
    the interpreter lock the whole time, so in-process threads cannot
    overlap it but subprocess workers can."""
    acc = float(seed)
    for i in range(iters):
        acc = (acc * 1.000001 + i % 7) % 1e9
    return acc


def _build_pipelines(n: int, iters: int):
    return [StageGraph([spin_stage.bind(i, iters)]).compile(f"p{i}")
            for i in range(n)]


def _bench_rpc(quick: bool) -> Tuple[float, float, float]:
    """(startup_s, subprocess round-trip ms, in-process round-trip ms)."""
    from repro.core.exec import SubprocessTransport
    from repro.core.transport import InProcessTransport

    reps = 20 if quick else 100
    t0 = time.time()
    sub = SubprocessTransport(max_workers=1, worker_devices=1)
    try:
        sub.submit(_ping, 0).result(timeout=180)  # first result = pool ready
        startup_s = time.time() - t0
        t0 = time.time()
        for i in range(reps):
            assert sub.submit(_ping, i).result(timeout=60) == i
        sub_ms = (time.time() - t0) / reps * 1e3
    finally:
        sub.shutdown()

    inp = InProcessTransport(max_workers=1)
    try:
        inp.submit(_ping, 0).result(timeout=60)
        t0 = time.time()
        for i in range(reps):
            assert inp.submit(_ping, i).result(timeout=60) == i
        inp_ms = (time.time() - t0) / reps * 1e3
    finally:
        inp.shutdown()
    return startup_s, sub_ms, inp_ms


def _bench_overlap(n: int, iters: int, workers: int) -> dict:
    """Same N GIL-bound pipelines through a Session on each transport."""
    from repro.core import Session

    out = {}
    for label, kwargs in (
            ("in_process", {"transport": "in-process"}),
            ("subprocess", {"transport": "subprocess",
                            "transport_options": {"max_workers": workers,
                                                  "worker_devices": 1}})):
        t0 = time.time()
        with Session(max_workers_per_pilot=max(workers, 2),
                     **kwargs) as session:
            res = session.run_all(_build_pipelines(n, iters))
        wall = time.time() - t0
        meta = res["_meta"]
        for name, per in meta["per_pipeline"].items():
            assert per["error"] is None, (label, name, per["error"])
        out[label] = {
            "wall_s": round(wall, 4),
            "overlap_factor": round(meta["overlap_factor"], 3),
        }
    return out


def bench_transport(full: bool = False, quick: bool = False) -> List[Tuple]:
    """Rows: pool startup, per-task RPC round-trip on each transport, and
    the GIL-bound multi-pipeline walls.  Re-execs standalone with an
    emulated pool when the calling process has a single device (overlap
    needs >=2 lease slots)."""
    if len(jax.devices()) < 2:
        return _rows_from_subprocess(full, quick)

    n = 2 if quick else 4
    # default bodies are ~2s each so the comparison is structural: pool
    # startup (~1s, amortised in real use) cannot mask the GIL effect
    iters = 200_000 if quick else 20_000_000
    workers = min(n, max(len(jax.devices()) // 2, 2))

    startup_s, sub_ms, inp_ms = _bench_rpc(quick)
    overlap = _bench_overlap(n, iters, workers)

    from benchmarks.results_io import merge_record
    merge_record(RESULTS_JSON, {
        "cpu_cores": _cores(),
        "startup_s": round(startup_s, 3),
        "rpc_roundtrip_ms": {"subprocess": round(sub_ms, 3),
                             "in_process": round(inp_ms, 3)},
        "gil_bound_pipelines": {
            "n_pipelines": n, "iters": iters, "workers": workers,
            **overlap,
        },
        "quick": quick,
    })
    speedup = (overlap["in_process"]["wall_s"]
               / max(overlap["subprocess"]["wall_s"], 1e-9))
    return [
        ("transport/pool_startup", startup_s * 1e6, "workers=1"),
        ("transport/rpc_roundtrip_subprocess", sub_ms * 1e3,
         f"in_process_ms={inp_ms:.3f}"),
        ("transport/gil_pipelines_in_process",
         overlap["in_process"]["wall_s"] * 1e6,
         f"overlap_factor={overlap['in_process']['overlap_factor']}"),
        ("transport/gil_pipelines_subprocess",
         overlap["subprocess"]["wall_s"] * 1e6,
         f"overlap_factor={overlap['subprocess']['overlap_factor']};"
         f"speedup_vs_threads={speedup:.2f};cores={_cores()}"),
    ]


def _rows_from_subprocess(full: bool, quick: bool = False) -> List[Tuple]:
    import subprocess

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    cmd = [sys.executable, "-m", "benchmarks.transport"]
    if full:
        cmd.append("--full")
    if quick:
        cmd.append("--quick")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=1800,
                       env=env, cwd=repo)
    if r.returncode != 0:
        raise RuntimeError(
            f"standalone transport bench failed:\n{r.stdout[-2000:]}\n"
            f"{r.stderr[-2000:]}")
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("transport/"):
            name, us, derived = line.split(",", 2)
            rows.append((name, float(us), derived))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny bodies, 2 pipelines, 20 RPC reps")
    args = ap.parse_args()
    rows = bench_transport(full=args.full, quick=args.quick)
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
    by_name = {r[0]: r for r in rows}
    wall_in = by_name["transport/gil_pipelines_in_process"][1]
    wall_sub = by_name["transport/gil_pipelines_subprocess"][1]
    if not args.quick and _cores() >= 2:
        # structural margin: N GIL-bound bodies on threads serialise, so
        # the worker pool must win by roughly min(cores, workers).  On a
        # single-core box there is no parallelism for either side to win
        # — the numbers are still recorded, just not asserted.
        assert wall_sub < wall_in, (
            f"subprocess pipelines ({wall_sub/1e6:.2f}s) did not beat "
            f"GIL-bound threads ({wall_in/1e6:.2f}s) on {_cores()} cores")
    print(f"transport OK (subprocess {wall_sub/1e6:.2f}s vs in-process "
          f"{wall_in/1e6:.2f}s on GIL-bound pipelines)")
