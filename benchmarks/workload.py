"""Shared, explicitly seeded open-loop workload generators.

The single-engine serving benchmark and the fleet benchmark replay the
IDENTICAL request stream (same prompts, same arrival offsets, same
generation budgets) so their numbers are apples-to-apples: both import
from here, and a given ``(n_requests, seed, scale)`` triple is
deterministic — the RNG call order below is part of the contract and
must not be reordered.

A workload is a list of ``(arrival_offset_s, prompt, max_new_tokens)``
tuples sorted by arrival.
"""
from __future__ import annotations

import numpy as np


def poisson_workload(n_requests: int, seed: int = 0, scale: float = 0.002):
    """Mixed-length prompts/budgets + exponential inter-arrival offsets.
    Generation budgets span 4-48 tokens: the wide spread is what makes
    static batching hold finished slots hostage to the batch straggler.
    The 2ms mean gap keeps the engine *capacity-bound* — the paged/kernel
    engines run fast enough that the original 10ms arrivals left 8+ slot
    runs arrival-bound, where every admission policy looks the same."""
    rng = np.random.default_rng(seed)
    prompt_lens = rng.integers(4, 9, n_requests)
    gens = rng.integers(4, 49, n_requests)
    gaps = rng.exponential(scale=scale, size=n_requests)
    arrivals = np.cumsum(gaps)
    arrivals[0] = 0.0
    prompts = [rng.integers(1, 250, int(l)).astype(np.int32)
               for l in prompt_lens]
    return list(zip(arrivals, prompts, gens))


def mixed_workload(n_requests: int, seed: int = 0, scale: float = 0.002):
    """Mostly-short prompts with a long-prompt tail (~80% at 4-16 tokens,
    ~20% at 96-160): the workload where whole-prompt prefill hurts — a
    long admission stalls every in-flight decode for its full prompt,
    which is exactly what the inter-token stall tail (each request's
    worst gap, the global p99) measures.  Also the disaggregation
    workload: long prefills contend with decode unless they run on a
    prefill-specialised engine."""
    rng = np.random.default_rng(seed)
    is_long = rng.random(n_requests) < 0.2
    is_long[: max(2, n_requests // 16)] = True  # tail guaranteed present
    prompt_lens = np.where(is_long, rng.integers(96, 161, n_requests),
                           rng.integers(4, 17, n_requests))
    gens = rng.integers(8, 25, n_requests)
    gaps = rng.exponential(scale=scale, size=n_requests)
    arrivals = np.cumsum(gaps)
    arrivals[0] = 0.0
    prompts = [rng.integers(1, 250, int(l)).astype(np.int32)
               for l in prompt_lens]
    return list(zip(arrivals, prompts, gens))


def percentile(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]
