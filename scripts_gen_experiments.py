"""Generate EXPERIMENTS.md from results/ JSONs (run after the final matrix)."""
import glob, json, os, sys
sys.path.insert(0, "src"); sys.path.insert(0, ".")
from benchmarks.roofline import ACTIVE_B, TOKENS, STEP_FACTOR, model_flops

DRY = "results/dryrun_final"

NOTES = {
    ("train", "memory"): "Pallas flash-attention kernel path (keeps score tiles in VMEM) + bf16-native TPU dots remove the dominant f32 tile traffic",
    ("train", "collective"): "2D sharding or explicitly-scheduled Megatron SP (shard_map) to convert dgrad all-reduces to reduce-scatters",
    ("prefill", "memory"): "flash kernel keeps O(S^2/chunk) tiles in VMEM; quantized (int8) KV write halves cache traffic",
    ("prefill", "collective"): "ring-attention style P2P schedule instead of GSPMD-inserted gathers",
    ("decode", "memory"): "decode is intrinsically cache-bandwidth-bound: quantized KV cache (int8/fp8) or MLA-style latent caches cut the stream ~2-4x",
    ("decode", "collective"): "batch the flash-decoding psum combine across layers",
}


def load(mesh):
    rows = []
    for p in sorted(glob.glob(f"{DRY}/*__{mesh}.json")):
        rows.append(json.load(open(p)))
    return rows


def roofline_table(mesh):
    out = [
        "| arch | shape | compute(s) | memory(s) | collective(s) | dominant | frac | mem/dev (adj) | MODEL/HLO | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | {r.get('error','')[:60]} |")
            continue
        rl, c, m = r["roofline"], r["cost"], r["memory"]
        mf = model_flops(r["arch"], r["shape"], r["kind"])
        ratio = mf / max(c["flops_per_device"] * r["n_chips"], 1)
        frac = rl["compute_s"] / max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        fits = "" if m["fits_16gb_tpu_adjusted"] else " **OVER**"
        note = NOTES.get((r["kind"], rl["dominant"].replace("_s", "")), "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4g} | {rl['memory_s']:.4g} | "
            f"{rl['collective_s']:.4g} | {rl['dominant'].replace('_s','')} | {frac:.3f} | "
            f"{m['per_device_bytes_tpu_adjusted']/1e9:.1f}GB{fits} | {ratio:.3f} | {note} |"
        )
    return "\n".join(out)


def dryrun_table(mesh):
    out = [
        "| arch | shape | compile(s) | args GB/dev | temp GB/dev | adj GB/dev | fits 16GB | HLO GFLOPs/dev | coll GB/dev | top collectives |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | FAIL: {r.get('error','')[:70]} |")
            continue
        m, c = r["memory"], r["cost"]
        pc = c.get("per_collective_bytes", {})
        top = ", ".join(f"{k}:{v/1e9:.1f}GB" for k, v in sorted(pc.items(), key=lambda kv: -kv[1])[:2])
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f} | {m['argument_bytes']/1e9:.2f} | "
            f"{m['temp_bytes']/1e9:.2f} | {m['per_device_bytes_tpu_adjusted']/1e9:.1f} | "
            f"{'yes' if m['fits_16gb_tpu_adjusted'] else 'NO'} | {c['flops_per_device']/1e9:.0f} | "
            f"{c['collective_bytes_per_device']/1e9:.2f} | {top} |"
        )
    return "\n".join(out)


def bench_tables():
    out = []
    bd = "results/bench"
    if os.path.exists(f"{bd}/hydrology.json"):
        h = json.load(open(f"{bd}/hydrology.json"))
        out.append("**Hydrology (paper Tables 1-2 analogue, synthetic CAMELS-like):**\n")
        out.append("| target | val MSE | val NNSE |\n|---|---|---|")
        for t, mm in h["metrics"].items():
            out.append(f"| {t} | {mm['val_mse']:.4f} | {mm['val_nnse']:.3f} |")
        out.append(
            f"\nDeep RC task wall {h['rc_total_s']:.1f}s vs its inner train loop "
            f"{h['rc_train_s']:.1f}s -> **runtime overhead {h['overhead_s']*1000:.0f} ms**, "
            f"constant while training time scales (bare-metal reference {h['bm_train_s']:.1f}s "
            f"incl. first-compile) "
            f"(communicator build {h['task_overheads'].get('communicator',0)*1000:.2f} ms, "
            f"queue {h['task_overheads'].get('queue',0)*1000:.2f} ms) — "
            "the paper's constant-overhead claim (C1), at our scale.\n")
    if os.path.exists(f"{bd}/forecasting.json"):
        f = json.load(open(f"{bd}/forecasting.json"))
        out.append("**11 forecasting models (paper Table 3 analogue):**\n")
        out.append("| model | MAE | MSE | MAPE% | BM train (s) | Deep RC overhead (s) |\n|---|---|---|---|---|---|")
        for name, r in f.items():
            out.append(f"| {name} | {r['bm']['MAE']:.3f} | {r['bm']['MSE']:.3f} | "
                       f"{r['bm']['MAPE']:.1f} | {r['bm']['train_s']:.1f} | {r['overhead_s']:.3f} |")
        out.append("")
    if os.path.exists(f"{bd}/scaling_ops.json"):
        s = json.load(open(f"{bd}/scaling_ops.json"))
        out.append("**Distributed sort/join scaling (paper Fig. 4 analogue):**\n")
        out.append("| mode | workers | sort (s) | join (s) | dropped |\n|---|---|---|---|---|")
        for mode, per_w in s.items():
            for w, ops in sorted(per_w.items(), key=lambda kv: int(kv[0])):
                if "sort" in ops:
                    out.append(f"| {mode} | {w} | {ops['sort']['s']:.3f} | {ops['join']['s']:.3f} | "
                               f"{ops['sort']['dropped']}+{ops['join']['dropped']} |")
        out.append("")
    if os.path.exists(f"{bd}/multi_pipeline.json"):
        m = json.load(open(f"{bd}/multi_pipeline.json"))
        out.append(f"**Multi-pipeline (paper Table 4 analogue):** {m['n_pipelines']} pipelines "
                   f"(1 data-eng + 1 inference each): bare-metal sequential {m['bm_s']:.2f}s vs "
                   f"Deep RC shared-pilot {m['rc_s']:.2f}s -> **saved {m['saved_s']:.2f}s** "
                   "(paper saved 3.28s/75.9s at its scale) — claim C4.\n")
    return "\n".join(out)


tpl = open("EXPERIMENTS.template.md").read()
tpl = tpl.replace("{{ROOFLINE_SINGLE}}", roofline_table("single"))
tpl = tpl.replace("{{DRYRUN_SINGLE}}", dryrun_table("single"))
tpl = tpl.replace("{{DRYRUN_MULTI}}", dryrun_table("multi"))
tpl = tpl.replace("{{BENCH}}", bench_tables())
open("EXPERIMENTS.md", "w").write(tpl)
print("EXPERIMENTS.md written")
