"""Async scheduler tests: non-blocking submission, regression tests for the
two agent bugs (concurrent.futures.TimeoutError mis-catch, speculative
lease leak), and PipelineScheduler behaviour under contention.

Scheduling logic is exercised on a FakePilot whose devices are plain
objects and whose ``carve`` skips jax Mesh construction — so these tests
run fast on the container's single real device while modelling an N-device
pool.  Real-mesh execution is covered by tests/test_system.py.
"""
import threading
import time
from types import SimpleNamespace

import pytest

from repro.core.agent import RemoteAgent
from repro.core.pilot import Pilot
from repro.core.pipeline import Pipeline, PipelineScheduler, Stage, run_pipelines
from repro.core.task import TaskDescription, TaskState


class FakeDevice:
    def __init__(self, i):
        self.id = i
        self.platform = "cpu"


class FakePilot(Pilot):
    """Pilot over dummy devices; carve returns a mesh-free communicator."""

    def carve(self, devices, mesh_shape=None, mesh_axes=("data",)):
        return SimpleNamespace(devices=tuple(devices), size=len(devices),
                               backend="fake", build_time_s=0.0)


def make_pilot(n):
    return FakePilot(f"fake.{n}", [FakeDevice(i) for i in range(n)])


def make_agent(n_devices, **kw):
    kw.setdefault("max_workers", n_devices)
    return RemoteAgent(make_pilot(n_devices), **kw)


# ---------------------------------------------------------------------------
# submit_async is non-blocking
# ---------------------------------------------------------------------------


def test_submit_async_returns_before_completion():
    agent = make_agent(2)
    release = threading.Event()

    def slow(comm):
        release.wait(5.0)
        return "done"

    t0 = time.time()
    tasks = agent.submit_async([TaskDescription(name="slow", fn=slow)])
    elapsed = time.time() - t0
    assert elapsed < 0.5, "submit_async must not block on task completion"
    assert tasks[0].state != TaskState.DONE
    release.set()
    assert agent.wait(tasks, timeout=10.0)
    assert tasks[0].state == TaskState.DONE and tasks[0].result == "done"
    agent.close()


def test_completion_callback_fires_once_terminal():
    agent = make_agent(2)
    seen = []
    tasks = agent.submit_async(
        [TaskDescription(name="cb", fn=lambda comm: 7)],
        on_complete=lambda t: seen.append(t))
    assert agent.wait(tasks, timeout=10.0)
    time.sleep(0.05)
    assert len(seen) == 1 and seen[0].result == 7 and seen[0].finalized
    agent.close()


# ---------------------------------------------------------------------------
# regression: concurrent.futures.TimeoutError on Python 3.10
# ---------------------------------------------------------------------------


def test_slow_task_not_popped_as_done():
    """Old ``execute`` caught builtin TimeoutError around
    ``Future.result(timeout=...)``; on Python 3.10 the raised
    ``concurrent.futures.TimeoutError`` is NOT a subclass, so a
    still-running task fell into the generic handler and was returned
    while RUNNING.  A blocking submit must return the task DONE."""
    agent = make_agent(1)

    def slow(comm):
        time.sleep(0.4)
        return 42

    task, = agent.submit([TaskDescription(name="slow", fn=slow)])
    assert task.state == TaskState.DONE, (
        f"blocking submit returned non-terminal task: {task.state}")
    assert task.result == 42
    agent.close()


# ---------------------------------------------------------------------------
# regression: speculative execution leaked its device lease
# ---------------------------------------------------------------------------


def test_speculative_lease_released():
    """_maybe_speculate leased under ``uid + '.spec'`` but the worker
    released ``task.uid`` — speculative leases were never returned.  With
    the lease uid threaded through the worker, free_count recovers."""
    pilot = make_pilot(4)
    agent = RemoteAgent(pilot, max_workers=4, straggler_factor=1.0,
                        straggler_min_s=0.05, straggler_check_s=0.02)
    # seed duration history so the straggler median is tiny
    agent.submit([TaskDescription(name=f"h{i}", fn=lambda comm: None,
                                  kind="k") for i in range(3)])

    def straggler(comm):
        time.sleep(0.5)
        return "ok"

    task, = agent.submit([TaskDescription(name="s", fn=straggler, kind="k")])
    assert task.state == TaskState.DONE
    # the speculative twin (if any) sleeps too; give it time to drain
    deadline = time.time() + 3.0
    while pilot.free_count() != 4 and time.time() < deadline:
        time.sleep(0.02)
    assert pilot.free_count() == 4, (
        f"leaked leases: free={pilot.free_count()}/4 — speculative lease "
        "was not released")
    agent.close()


def test_retry_success_clears_error():
    """A task that fails then succeeds on retry must not keep its stale
    error — error-checking callers would reject a DONE task."""
    agent = make_agent(2)
    attempts = {"n": 0}

    def flaky(comm):
        attempts["n"] += 1
        if attempts["n"] == 1:
            raise RuntimeError("transient")
        return "recovered"

    task, = agent.submit([TaskDescription(name="flaky", fn=flaky,
                                          max_retries=2)])
    assert task.state == TaskState.DONE and task.result == "recovered"
    assert task.error is None, f"stale error survived retry: {task.error!r}"
    agent.close()


def test_close_finalizes_pending_tasks():
    """close() must CANCEL queued-but-unlaunched tasks and release their
    waiters instead of leaving them hanging."""
    agent = make_agent(1)
    gate = threading.Event()
    blocking = agent.submit_async(
        [TaskDescription(name="blocker", fn=lambda comm: gate.wait(5.0))])
    time.sleep(0.1)  # blocker holds the only device
    queued = agent.submit_async(
        [TaskDescription(name="starved", fn=lambda comm: "never")])
    threading.Timer(0.2, gate.set).start()
    agent.close()
    assert agent.wait(blocking + queued, timeout=5.0), "waiter hung"
    assert queued[0].state == TaskState.CANCELED
    assert queued[0].finalized


# ---------------------------------------------------------------------------
# capacity + priority
# ---------------------------------------------------------------------------


def test_no_overlease_under_contention():
    pilot = make_pilot(2)
    agent = RemoteAgent(pilot, max_workers=8)
    lock = threading.Lock()
    state = {"now": 0, "peak": 0}

    def work(comm):
        with lock:
            state["now"] += 1
            state["peak"] = max(state["peak"], state["now"])
        time.sleep(0.05)
        with lock:
            state["now"] -= 1
        return True

    tasks = agent.submit([TaskDescription(name=f"w{i}", fn=work)
                          for i in range(6)])
    assert all(t.state == TaskState.DONE for t in tasks)
    assert state["peak"] <= 2, f"over-lease: {state['peak']} > 2 devices"
    agent.close()


def test_priority_order_preserved():
    agent = make_agent(1)
    gate = threading.Event()
    order = []

    def blocker(comm):
        gate.wait(5.0)

    def record(comm, tag):
        order.append(tag)

    blocking = agent.submit_async([TaskDescription(name="blocker", fn=blocker)])
    time.sleep(0.1)  # ensure the blocker holds the only device
    queued = agent.submit_async([
        TaskDescription(name="lo", fn=record, args=("lo",), priority=1),
        TaskDescription(name="hi", fn=record, args=("hi",), priority=5),
        TaskDescription(name="mid", fn=record, args=("mid",), priority=3),
    ])
    gate.set()
    assert agent.wait(blocking + queued, timeout=10.0)
    assert order == ["hi", "mid", "lo"]
    agent.close()


# ---------------------------------------------------------------------------
# PipelineScheduler: concurrency, isolation, overlap
# ---------------------------------------------------------------------------


def _two_stage_pipeline(i, sleep_s=0.0):
    def first(comm, upstream):
        time.sleep(sleep_s)
        return i * 10

    def second(comm, upstream):
        time.sleep(sleep_s)
        return upstream["first"] + 1

    return Pipeline(f"p{i}", [
        Stage("first", first),
        Stage("second", second, deps=("first",)),
    ])


def test_concurrent_pipelines_complete():
    agent = make_agent(4)
    pipes = [_two_stage_pipeline(i) for i in range(5)]
    out = PipelineScheduler(agent).run(pipes)
    for i in range(5):
        assert out[f"p{i}"]["first"] == i * 10
        assert out[f"p{i}"]["second"] == i * 10 + 1
        assert "_error" not in out[f"p{i}"]
    meta = out["_meta"]
    assert meta["n_tasks"] == 10 and meta["n_failed"] == 0
    assert meta["wall_s"] > 0
    agent.close()


def test_failing_pipeline_does_not_poison_siblings():
    agent = make_agent(4)

    def boom(comm, upstream):
        raise ValueError("injected")

    bad = Pipeline("bad", [
        Stage("ok", lambda comm, upstream: 1),
        Stage("explode", boom, deps=("ok",), max_retries=0),
        Stage("never", lambda comm, upstream: 2, deps=("explode",)),
    ])
    good = [_two_stage_pipeline(i) for i in range(4)]
    out = PipelineScheduler(agent).run([bad] + good)
    assert "injected" in out["bad"]["_error"]
    assert out["bad"]["_failed_stage"] == "explode"
    assert out["bad"]["ok"] == 1          # upstream result still recorded
    assert "never" not in out["bad"]      # downstream never ran
    for i in range(4):
        assert out[f"p{i}"]["second"] == i * 10 + 1, "sibling was poisoned"
    agent.close()


def test_duplicate_stage_names_rejected():
    p = Pipeline("dup", [Stage("a", lambda c, u: 1),
                         Stage("a", lambda c, u: 2)])
    agent = make_agent(1)
    with pytest.raises(RuntimeError, match="duplicate stage names"):
        p.run(agent)
    agent.close()


def test_pipeline_run_still_raises():
    agent = make_agent(2)
    p = Pipeline("solo", [Stage("explode",
                                lambda comm, upstream: 1 / 0,
                                max_retries=0)])
    with pytest.raises(RuntimeError, match="solo"):
        p.run(agent)
    agent.close()


def test_overlap_beats_serial():
    """>=4 pipelines on >=2 devices: concurrent scheduling must beat the
    one-pipeline-at-a-time baseline on wall clock."""
    sleep_s = 0.15
    n = 4

    # serial baseline: each pipeline run to completion before the next
    agent = make_agent(4)
    t0 = time.time()
    for i in range(n):
        _two_stage_pipeline(i, sleep_s).run(agent)
    serial_wall = time.time() - t0
    agent.close()

    pilot = make_pilot(4)
    out = run_pipelines([_two_stage_pipeline(i, sleep_s) for i in range(n)],
                        pilot=pilot)
    concurrent_wall = out["_meta"]["wall_s"]
    assert concurrent_wall < serial_wall * 0.75, (
        f"no overlap: concurrent={concurrent_wall:.2f}s "
        f"serial={serial_wall:.2f}s")
    assert out["_meta"]["overlap_factor"] > 1.5


def test_run_pipelines_reports_decomposition():
    out = run_pipelines([_two_stage_pipeline(i) for i in range(3)],
                        pilot=make_pilot(2))
    meta = out["_meta"]
    assert set(meta["per_pipeline"]) == {"p0", "p1", "p2"}
    for row in meta["per_pipeline"].values():
        assert row["wall_s"] is not None and row["error"] is None
    assert meta["queue_s"] >= 0 and meta["communicator_s"] >= 0
    assert meta["n_tasks"] == 6
