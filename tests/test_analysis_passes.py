"""Analyzer test coverage (PR 6 satellite): each static pass is proven
against a fixture module carrying exactly the violations it must report,
and the runtime lock-order recorder is proven against a seeded inversion
plus a live two-thread agent interleaving."""
import threading
from pathlib import Path
from types import SimpleNamespace

import pytest

from repro.analysis import excepts, jit_boundary, locks, pickles
from repro.analysis.findings import (
    Finding, diff_against_baseline, load_baseline, write_baseline,
)
from repro.analysis.kernel_contracts import blockspec_findings
from repro.analysis.lockorder import LockOrderRecorder, instrument_runtime
from repro.core.agent import RemoteAgent
from repro.core.pilot import Pilot
from repro.core.task import TaskDescription, TaskState

ROOT = Path(__file__).resolve().parents[1]
FIXTURES = ROOT / "tests" / "fixtures_analysis"


# ---------------------------------------------------------------------------
# lock-discipline pass: guarded-attr escapes
# ---------------------------------------------------------------------------


def test_lock_pass_reports_exactly_the_seeded_escapes():
    findings = locks.run([FIXTURES / "lock_fixture.py"], ROOT)
    got = sorted((f.rule, f.symbol) for f in findings)
    # exactly the two seeded violations: the unlocked read in peek() and
    # the closure that outlives its with-block in escape().  The clean
    # patterns (locked access, *_locked helper, # caller-locked method,
    # __init__) must produce nothing.
    assert got == [("guarded-attr", "Counter.history"),
                   ("guarded-attr", "Counter.value")]
    by_symbol = {f.symbol: f for f in findings}
    assert "peek" not in by_symbol  # symbols are class.attr, not methods
    assert "_lock" in by_symbol["Counter.value"].message


# ---------------------------------------------------------------------------
# jit-boundary pass: host syncs / traced branches / unhashable statics
# ---------------------------------------------------------------------------


def test_jit_pass_reports_exactly_the_seeded_violations():
    findings = jit_boundary.run(
        {"tests.fixtures_analysis.jit_fixture": FIXTURES / "jit_fixture.py"},
        ROOT)
    got = sorted((f.rule, f.line) for f in findings)
    assert got == [
        ("host-sync", 23),          # time.time() under jit
        ("host-sync", 27),          # float() on a traced value
        ("static-unhashable", 41),  # list display bound to static arg
        ("traced-branch", 25),      # if on a traced value
    ]
    # every finding names the offending jit root; clean_step (shape
    # attrs, `is None`, static closure config) contributes nothing
    assert all("leaky_step" in f.symbol for f in findings)


# ---------------------------------------------------------------------------
# kernel-contract pass: BlockSpec misdivision
# ---------------------------------------------------------------------------


def test_kernel_pass_flags_blockspec_misdivision():
    # a head grid the GQA index maps cannot tile: H_pad=6 with KV_pad=4
    bad = SimpleNamespace(padded_gqa=lambda: (6, 4))
    findings = blockspec_findings("badfixture", bad)
    assert [f.rule for f in findings] == ["blockspec"]
    assert findings[0].symbol == "badfixture/gqa"
    assert "H %" in findings[0].message

    good = SimpleNamespace(padded_gqa=lambda: (8, 4))
    assert blockspec_findings("goodfixture", good) == []


# ---------------------------------------------------------------------------
# broad-except pass
# ---------------------------------------------------------------------------


def test_excepts_pass_respects_noqa_boundary():
    findings = excepts.run([FIXTURES / "except_fixture.py"], ROOT)
    assert len(findings) == 1
    assert findings[0].rule == "broad-except"
    assert findings[0].line == 11  # risky() flagged, isolated() exempt


# ---------------------------------------------------------------------------
# picklable-task-contract pass
# ---------------------------------------------------------------------------


def test_pickles_pass_flags_nested_stage_and_lambda_task():
    findings = pickles.run([FIXTURES / "pickle_fixture.py"], ROOT)
    by_rule = {}
    for f in findings:
        by_rule.setdefault(f.rule, []).append(f)
    # nested @stage flagged; module-level and PKL001-marked ones exempt
    assert [f.symbol for f in by_rule.get("stage-nested", [])] == \
        ["inner_stage"]
    # fn=lambda flagged once; the PKL001-marked call site is exempt
    assert len(by_rule.get("lambda-task", [])) == 1
    assert by_rule["lambda-task"][0].symbol == "TaskDescription"
    assert len(findings) == 2


# ---------------------------------------------------------------------------
# baseline protocol
# ---------------------------------------------------------------------------


def test_baseline_diff_keys_exclude_line_numbers(tmp_path):
    f1 = Finding("locks", "guarded-attr", "a.py", 10, "C.x", "m")
    moved = Finding("locks", "guarded-attr", "a.py", 99, "C.x", "m")
    other = Finding("locks", "guarded-attr", "a.py", 5, "C.y", "m")
    path = tmp_path / "baseline.json"
    write_baseline(path, [f1])
    baseline = load_baseline(path)
    # the same finding on a different line is NOT new (edits above it
    # must not churn the baseline); a different symbol IS new
    new, stale = diff_against_baseline([moved], baseline)
    assert new == [] and stale == set()
    new, stale = diff_against_baseline([other], baseline)
    assert [f.symbol for f in new] == ["C.y"]
    new, stale = diff_against_baseline([], baseline)
    assert new == [] and stale == {f1.key()}


# ---------------------------------------------------------------------------
# lock-order recorder: seeded inversion, detected WITHOUT deadlocking
# ---------------------------------------------------------------------------


def test_lock_order_cycle_detected_from_sequential_threads():
    rec = LockOrderRecorder()
    a = rec.wrap(threading.Lock(), "A")
    b = rec.wrap(threading.Lock(), "B")

    def forward():
        with a:
            with b:
                pass

    def backward():
        with b:
            with a:
                pass

    # run the two orders SEQUENTIALLY: no deadlock ever happens, yet the
    # recorder still sees both edges and reports the inversion
    for body in (forward, backward):
        t = threading.Thread(target=body)
        t.start()
        t.join()
    cycles = rec.cycles()
    assert cycles == [["A", "B", "A"]]
    with pytest.raises(AssertionError, match="A -> B -> A"):
        rec.assert_no_cycles()


def test_lock_order_clean_nesting_has_no_cycle():
    rec = LockOrderRecorder()
    a = rec.wrap(threading.Lock(), "A")
    b = rec.wrap(threading.Lock(), "B")
    for _ in range(3):
        with a:
            with b:
                pass
    assert rec.cycles() == []
    rec.assert_no_cycles()  # must not raise


# ---------------------------------------------------------------------------
# live interleaving: agent submit_async / service preemption under the
# recorder — the agent <-> pilot lock orders must stay acyclic
# ---------------------------------------------------------------------------


class _FakeDevice:
    def __init__(self, i):
        self.id = i
        self.platform = "cpu"


class _FakePilot(Pilot):
    def carve(self, devices, mesh_shape=None, mesh_axes=("data",)):
        return SimpleNamespace(devices=tuple(devices), size=len(devices),
                               backend="fake", build_time_s=0.0)


def test_agent_submit_and_preempt_interleaving_is_cycle_free():
    pilot = _FakePilot("fake.2", [_FakeDevice(i) for i in range(2)])
    agent = RemoteAgent(pilot, max_workers=2, straggler_check_s=0.01)
    rec = LockOrderRecorder()
    instrument_runtime(rec, agent=agent)
    rec.instrument(pilot, "_lock", "pilot._lock")

    def service(comm, control=None, resume_state=None):
        while True:
            control.wait_for_work(0.05)
            if control.preempt_requested():
                from repro.core.task import ServicePreempted
                raise ServicePreempted(state="ckpt")
            if control.stop_requested():
                return "stopped"
            control.take_requests()

    def unit(comm):
        return "ok"

    try:
        [svc] = agent.submit_async([TaskDescription(
            name="svc", fn=service, num_devices=2, priority=0, service=True)])
        started = threading.Event()
        svc.description.control.submit_request("warm")

        # thread 1: floods the agent with higher-priority unit work (this
        # starves on devices and triggers a preemption request); thread 2:
        # drives the service control from the submitting side
        def submitter():
            started.wait(5.0)
            tasks = agent.submit_async(
                [TaskDescription(name=f"hi{i}", fn=unit, num_devices=2,
                                 priority=5) for i in range(4)])
            agent.wait(tasks, timeout=10.0)

        def driver():
            started.set()
            for i in range(20):
                try:
                    svc.description.control.submit_request(i)
                except RuntimeError:
                    break

        threads = [threading.Thread(target=submitter),
                   threading.Thread(target=driver)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=15.0)
        svc.description.control.stop()
        svc.wait(10.0)
    finally:
        agent.close(timeout=10.0)

    assert agent.preemption_requests >= 1  # the interleaving really happened
    assert rec.edges(), "recorder saw no lock activity"
    rec.assert_no_cycles()
