"""End-to-end behaviour tests for the Deep RC system: the paper's pipeline
(data engineering -> zero-copy bridge -> DL training -> postprocess) under
the pilot runtime, plus subprocess-spawned multi-device suites."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.agent import RemoteAgent
from repro.core.bridge import cylon_stage, data_bridge, dl_stage
from repro.core.pilot import PilotDescription, PilotManager
from repro.core.pipeline import Pipeline, run_pipelines
from repro.core.task import TaskDescription, TaskState
from repro.dataframe.table import Table


def test_end_to_end_pipeline_single_device():
    """Full Deep RC flow on the container's single device: synthetic table
    -> preprocess (filter/project) -> zero-copy loader -> train a linear
    model -> postprocess metric."""
    rng = np.random.default_rng(0)
    N = 2048
    x1 = rng.normal(size=N).astype(np.float32)
    x2 = rng.normal(size=N).astype(np.float32)
    y = 3.0 * x1 - 2.0 * x2 + 0.1 * rng.normal(size=N).astype(np.float32)

    def preprocess(comm, upstream):
        t = Table.from_columns({"x1": x1, "x2": x2, "y": y})
        from repro.dataframe.ops_local import filter_rows
        cols, valid = filter_rows(t.columns, t.valid, jnp.abs(t.col("x1")) < 3.0)
        return t.with_columns(cols, valid)

    def train(comm, upstream):
        table = upstream["preprocess"]
        loader = data_bridge(table, ["x1", "x2"], "y", global_batch=256,
                             shuffle=True)
        w = jnp.zeros((2,))
        b = jnp.zeros(())

        @jax.jit
        def step(w, b, feats, labels, mask):
            def loss_fn(wb):
                w_, b_ = wb
                pred = feats @ w_ + b_
                err = jnp.where(mask, pred - labels, 0.0)
                return jnp.sum(err**2) / jnp.maximum(jnp.sum(mask), 1)
            l, g = jax.value_and_grad(loss_fn)((w, b))
            return w - 0.1 * g[0], b - 0.1 * g[1], l

        losses = []
        for epoch in range(30):
            for feats, labels, mask in loader.epoch(epoch):
                w, b, l = step(w, b, feats, labels, mask)
            losses.append(float(l))
        return {"w": np.asarray(w), "loss": losses[-1], "first": losses[0]}

    def postprocess(comm, upstream):
        r = upstream["train"]
        return {"w_err": float(np.abs(r["w"] - np.array([3.0, -2.0])).max()),
                **r}

    pm = PilotManager()
    pilot = pm.submit_pilot(PilotDescription())
    agent = RemoteAgent(pilot, max_workers=2)
    pipe = Pipeline("e2e", [
        cylon_stage("preprocess", preprocess),
        dl_stage("train", train, deps=("preprocess",)),
        dl_stage("postprocess", postprocess, deps=("train",), kind="inference"),
    ])
    out = pipe.run(agent)
    assert out["postprocess"]["loss"] < out["postprocess"]["first"]
    assert out["postprocess"]["w_err"] < 0.2, out["postprocess"]
    # overhead accounting exists (paper Table 2 decomposition)
    t = pipe.tasks["train"]
    assert "communicator" in t.overhead_s and "queue" in t.overhead_s


def test_multi_pipeline_shared_pilot():
    """Table-4 mode: N pipelines under one pilot all complete."""
    def work(comm, upstream, i):
        return float(jnp.sum(jnp.ones((64,)) * i))

    pipes = [
        Pipeline(f"p{i}", [dl_stage("work", lambda c, u, j=i: work(c, u, j))])
        for i in range(5)
    ]
    out = run_pipelines(pipes, max_workers=4)
    for i in range(5):
        assert out[f"p{i}"]["work"] == 64.0 * i
    assert out["_meta"]["wall_s"] > 0


def test_task_isolation():
    """A failing task never breaks its siblings (paper §2.3 claim)."""
    pm = PilotManager()
    agent = RemoteAgent(pm.submit_pilot(PilotDescription()), max_workers=2)

    def good(comm):
        return "ok"

    def bad(comm):
        raise ValueError("boom")

    tasks = agent.submit([
        TaskDescription(name="good", fn=good),
        TaskDescription(name="bad", fn=bad, max_retries=0),
    ])
    by_name = {t.description.name: t for t in tasks}
    assert by_name["good"].state == TaskState.DONE
    assert by_name["bad"].state == TaskState.FAILED
    assert "boom" in by_name["bad"].error


def test_distributed_dataframe_ops(spawned):
    """shuffle/sort/join/groupby/reduce on an 8-way mesh (subprocess)."""
    out = spawned("dataframe_ops.py", devices=8)
    assert "ALL DF TESTS PASS" in out


def test_runtime_fault_tolerance(spawned):
    """retry, DeviceFailure re-carve, checkpoint reshard (subprocess)."""
    out = spawned("runtime_ft.py", devices=8)
    assert "ALL RUNTIME TESTS PASS" in out


def test_distributed_extras(spawned):
    """pipeline parallelism + int8 gradient compression (subprocess)."""
    out = spawned("distributed_extras.py", devices=8)
    assert "ALL DISTRIBUTED EXTRAS PASS" in out


def test_subprocess_transport_multiprocess_e2e(spawned):
    """8-device parent driving subprocess workers: concurrent multi-device
    tasks, SIGKILL + checkpoint retry, cross-pod pipeline, clean reap."""
    out = spawned("subprocess_transport.py", devices=8)
    assert "ALL SUBPROCESS TRANSPORT TESTS PASS" in out
