"""Multi-pilot placement layer tests: disjoint pilot pools, capacity/kind
placement, migration on pilot degradation, per-pipeline device quotas, the
pluggable task transport, and checkpoint-aware retry.

Like tests/test_scheduler.py, scheduling logic runs on FakePilots over
plain-object devices (carve skips jax Mesh construction), so an N-device
pool is modelled on the container's single real device.  The checkpoint
retry test uses the real store (numpy leaves only).
"""
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.checkpoint import store
from repro.core.agent import RemoteAgent
from repro.core.pilot import Pilot, PilotDescription, PilotManager
from repro.core.pipeline import (MultiPilotScheduler, Pipeline,
                                 PipelineScheduler, Stage)
from repro.core.task import TaskDescription, TaskState
from repro.core.transport import (InProcessTransport, JaxDistributedTransport,
                                  Transport)


class FakeDevice:
    def __init__(self, i):
        self.id = i
        self.platform = "cpu"


class FakePilot(Pilot):
    """Pilot over dummy devices; carve returns a mesh-free communicator."""

    def carve(self, devices, mesh_shape=None, mesh_axes=("data",)):
        return SimpleNamespace(devices=tuple(devices), size=len(devices),
                               backend="fake", build_time_s=0.0,
                               pilot_uid=self.uid)


def make_manager(n):
    return PilotManager(devices=[FakeDevice(i) for i in range(n)],
                        pilot_factory=FakePilot)


def device_ids(pilot):
    return {d.id for d in pilot.alive_devices()}


# ---------------------------------------------------------------------------
# PilotManager: disjoint pools
# ---------------------------------------------------------------------------


def test_pilots_own_disjoint_pools():
    pm = make_manager(8)
    a = pm.submit_pilot(PilotDescription(num_devices=4, name="a"))
    b = pm.submit_pilot(PilotDescription(num_devices=4, name="b"))
    assert not device_ids(a) & device_ids(b), "pools overlap (seed bug)"
    assert device_ids(a) | device_ids(b) == set(range(8))


def test_submit_raises_when_machine_exhausted():
    pm = make_manager(4)
    pm.submit_pilot(PilotDescription(num_devices=4))
    with pytest.raises(RuntimeError, match="free"):
        pm.submit_pilot(PilotDescription(num_devices=1))


def test_default_pilot_takes_remaining_devices():
    pm = make_manager(8)
    pm.submit_pilot(PilotDescription(num_devices=3))
    rest = pm.submit_pilot(PilotDescription())  # -1 = all still free
    assert rest.size == 5
    with pytest.raises(RuntimeError):
        pm.submit_pilot(PilotDescription())


def test_cancel_pilot_recovers_alive_devices_only():
    pm = make_manager(4)
    a = pm.submit_pilot(PilotDescription(num_devices=4))
    a.mark_failed([0])  # one device dies while the pilot holds the pool
    assert pm.cancel_pilot(a) == 3
    with pytest.raises(RuntimeError):
        pm.submit_pilot(PilotDescription(num_devices=4))
    b = pm.submit_pilot(PilotDescription(num_devices=3))
    assert 0 not in device_ids(b), "failed device re-entered the pool"


def test_cancel_pilot_refuses_while_leased():
    pm = make_manager(2)
    a = pm.submit_pilot(PilotDescription(num_devices=2))
    assert a.lease(1, "t") is not None
    with pytest.raises(RuntimeError, match="leased"):
        pm.cancel_pilot(a)  # recycling a running device would alias pools
    a.release("t")
    assert pm.cancel_pilot(a) == 2


# ---------------------------------------------------------------------------
# PilotManager: placement
# ---------------------------------------------------------------------------


def test_place_picks_most_free_capacity():
    pm = make_manager(8)
    a = pm.submit_pilot(PilotDescription(num_devices=4, name="a"))
    b = pm.submit_pilot(PilotDescription(num_devices=4, name="b"))
    assert a.lease(2, "occupier") is not None
    assert pm.place(num_devices=1) is b
    # the load overlay models capacity already promised but not leased
    assert pm.place(num_devices=1, load={b.uid: 3}) is a


def test_place_respects_kind_and_mesh_requirement():
    pm = make_manager(8)
    de = pm.submit_pilot(PilotDescription(
        num_devices=4, name="de-pod", task_kinds=("data_engineering",)))
    any_ = pm.submit_pilot(PilotDescription(num_devices=4, name="any"))
    assert pm.place(kinds={"train"}) is any_
    assert pm.place(kinds={"data_engineering"}, load={any_.uid: 0}) in (de, any_)
    de.lease(4, "busy")  # kind-pilot full: still placeable (alive, not free)
    assert pm.place(num_devices=5) is None, "no pilot has 5 alive devices"
    assert pm.place(kinds={"train"}, exclude=(any_,)) is None


# ---------------------------------------------------------------------------
# MultiPilotScheduler: spread, migration, unplaceable
# ---------------------------------------------------------------------------


def _sleep_pipeline(name, sleep_s=0.0, quota=None):
    def first(comm, upstream):
        time.sleep(sleep_s)
        return comm.pilot_uid

    def second(comm, upstream):
        time.sleep(sleep_s)
        return upstream["first"]

    return Pipeline(name, [
        Stage("first", first),
        Stage("second", second, deps=("first",)),
    ], quota=quota)


def test_pipelines_land_on_least_loaded_pilot():
    pm = make_manager(4)
    pm.submit_pilot(PilotDescription(num_devices=2, name="a"))
    pm.submit_pilot(PilotDescription(num_devices=2, name="b"))
    sched = MultiPilotScheduler(pm, max_workers_per_pilot=2)
    try:
        out = sched.run([_sleep_pipeline("p0"), _sleep_pipeline("p1")])
    finally:
        sched.close()
    placement = out["_meta"]["placement"]
    assert len(set(placement.values())) == 2, (
        f"both pipelines piled onto one pilot: {placement}")
    for name in ("p0", "p1"):
        assert out[name]["second"] == placement[name], (
            "stage did not run on its placed pilot")


def test_migration_on_pilot_degradation():
    pm = make_manager(8)
    pm.submit_pilot(PilotDescription(num_devices=4, name="a"))
    pm.submit_pilot(PilotDescription(num_devices=4, name="b"))
    started, gate = threading.Event(), threading.Event()
    seen = {}

    def first(comm, upstream):
        seen["first"] = comm.pilot_uid
        started.set()
        gate.wait(5.0)
        return 1

    def wide(comm, upstream):
        seen["wide"] = comm.pilot_uid
        return comm.size

    pipe = Pipeline("mig", [
        Stage("first", first),
        Stage("wide", wide, deps=("first",), num_devices=4),
    ])
    sched = MultiPilotScheduler(pm, max_workers_per_pilot=4)
    results = {}
    th = threading.Thread(target=lambda: results.update(sched.run([pipe])))
    th.start()
    try:
        assert started.wait(5.0), "first stage never launched"
        home = next(p for p in pm.pilots if p.uid == seen["first"])
        other = next(p for p in pm.pilots if p is not home)
        # two device failures drop the home pilot below the 4-device mesh
        # requirement of the remaining stage -> migrate
        home.mark_failed([d.id for d in home.alive_devices()[:2]])
        gate.set()
        th.join(10.0)
        assert not th.is_alive()
    finally:
        gate.set()
        th.join(1.0)
        sched.close()
    assert results["mig"].get("_error") is None or "_error" not in results["mig"]
    assert seen["wide"] == other.uid, (
        f"remaining stage ran on degraded pilot {seen['wide']}")
    assert results["mig"]["wide"] == 4, "migrated stage lost its full mesh"
    migs = results["_meta"]["migrations"]
    assert len(migs) == 1 and migs[0]["from"] == home.uid \
        and migs[0]["to"] == other.uid


def test_unplaceable_pipeline_aborts_without_poisoning_siblings():
    pm = make_manager(4)
    pm.submit_pilot(PilotDescription(num_devices=2, name="a"))
    pm.submit_pilot(PilotDescription(num_devices=2, name="b"))
    huge = Pipeline("huge", [Stage("x", lambda c, u: 1, num_devices=16)])
    ok = _sleep_pipeline("ok")
    sched = MultiPilotScheduler(pm, max_workers_per_pilot=2)
    try:
        out = sched.run([huge, ok])
    finally:
        sched.close()
    assert "unplaceable" in out["huge"]["_error"]
    assert "_error" not in out["ok"]


# ---------------------------------------------------------------------------
# quotas: cap + fairness + backpressure
# ---------------------------------------------------------------------------


def make_agent(n_devices, **kw):
    kw.setdefault("max_workers", n_devices)
    return RemoteAgent(FakePilot("fake.q", [FakeDevice(i) for i in range(n_devices)]),
                       **kw)


def test_quota_capped_pipeline_never_exceeds_share():
    agent = make_agent(4, max_workers=8)
    wide = Pipeline("wide", [
        Stage(f"s{i}", lambda c, u, i=i: time.sleep(0.05) or i)
        for i in range(6)
    ], quota=1)
    sibs = [_sleep_pipeline(f"sib{i}", sleep_s=0.05) for i in range(2)]
    out = PipelineScheduler(agent).run([wide] + sibs)
    assert "_error" not in out["wide"]
    for i in range(2):
        assert "_error" not in out[f"sib{i}"], "sibling starved/failed"
    peaks = agent.group_peaks()
    assert peaks["wide"] == 1, f"quota breached: {peaks}"
    assert agent.quota_violations() == {}
    # the auditable trace agrees with the peak accounting
    held_max = max((held for _, g, _, held in agent.lease_trace if g == "wide"),
                   default=0)
    assert held_max <= 1
    # fairness: while wide serialises on its quota, siblings overlap freely
    wide_wall = out["_meta"]["per_pipeline"]["wide"]["wall_s"]
    for i in range(2):
        assert out["_meta"]["per_pipeline"][f"sib{i}"]["wall_s"] < wide_wall
    agent.close()


def test_quota_shrinks_wide_stage_elastically():
    agent = make_agent(4)
    pipe = Pipeline("clamped", [
        Stage("wide", lambda c, u: c.size, num_devices=4),
    ], quota=2)
    out = PipelineScheduler(agent).run([pipe])
    assert out["clamped"]["wide"] == 2, (
        "stage should shrink to its group's quota share")
    agent.close()


def test_quota_can_be_lifted():
    agent = make_agent(2)
    agent.set_quota("g", 1)
    assert agent.quota("g") == 1
    agent.set_quota("g", None)
    assert agent.quota("g") is None
    with pytest.raises(ValueError):
        agent.set_quota("g", 0)
    agent.close()


# ---------------------------------------------------------------------------
# transport abstraction
# ---------------------------------------------------------------------------


class RecordingTransport(Transport):
    name = "recording"

    def __init__(self, max_workers=2):
        self.capacity = max_workers
        self.submissions = 0
        self._inner = InProcessTransport(max_workers)

    def submit(self, fn, *args):
        self.submissions += 1
        return self._inner.submit(fn, *args)

    def shutdown(self, wait=True):
        self._inner.shutdown(wait=wait)


def test_agent_executes_through_pluggable_transport():
    transport = RecordingTransport(max_workers=2)
    agent = RemoteAgent(FakePilot("fake.t", [FakeDevice(0), FakeDevice(1)]),
                        max_workers=99, transport=transport)
    assert agent.max_workers == 2, "transport capacity must bound in-flight"
    tasks = agent.submit([TaskDescription(name=f"t{i}", fn=lambda comm: comm.size)
                          for i in range(3)])
    assert all(t.state == TaskState.DONE for t in tasks)
    assert transport.submissions >= 3, "attempts bypassed the transport"
    agent.close()
    transport.shutdown()  # injected transports belong to the caller


def test_shared_transport_survives_sibling_agent_close():
    """Closing one agent must not shut down a caller-injected transport
    that another agent still dispatches through."""
    transport = InProcessTransport(max_workers=4)
    a1 = RemoteAgent(FakePilot("fake.s1", [FakeDevice(0)]), transport=transport)
    a2 = RemoteAgent(FakePilot("fake.s2", [FakeDevice(1)]), transport=transport)
    t1, = a1.submit([TaskDescription(name="one", fn=lambda comm: 1)])
    a1.close()
    t2, = a2.submit([TaskDescription(name="two", fn=lambda comm: 2)])
    assert t1.state == TaskState.DONE and t2.state == TaskState.DONE
    assert t2.result == 2
    a2.close()
    transport.shutdown()


def test_dead_transport_fails_task_not_dispatcher():
    """A transport that rejects submissions must fail the task cleanly;
    the dispatcher thread and the device lease must both survive."""
    transport = InProcessTransport(max_workers=2)
    pilot = FakePilot("fake.d", [FakeDevice(0), FakeDevice(1)])
    agent = RemoteAgent(pilot, transport=transport)
    transport.shutdown()  # simulate a shared transport torn down elsewhere
    task, = agent.submit_async([TaskDescription(name="doomed",
                                                fn=lambda comm: 1)])
    assert task.wait(5.0), "waiter hung on transport failure"
    assert task.state == TaskState.FAILED
    assert "transport rejected" in task.error
    assert pilot.free_count() == 2, "lease leaked on transport failure"
    agent.close()


def test_cross_node_transport_is_explicitly_unavailable():
    """Single-host JaxDistributedTransport is the subprocess pool; asking
    for a real multi-host fabric (coordinator / num_processes > 1 /
    process_id != 0) must raise the specific unavailability error BEFORE
    any worker spawns."""
    with pytest.raises(NotImplementedError, match="cross-node"):
        JaxDistributedTransport(coordinator="10.0.0.1:1234", num_processes=2)
    with pytest.raises(NotImplementedError, match="cross-node"):
        JaxDistributedTransport(num_processes=4, process_id=1)


# ---------------------------------------------------------------------------
# checkpoint-aware retry
# ---------------------------------------------------------------------------


def test_retry_receives_last_checkpoint_step(tmp_path):
    ckpt_dir = str(tmp_path / "ckpt")
    agent = make_agent(2)
    seen = []

    def train(comm, resume_step=None):
        seen.append(resume_step)
        if resume_step is None:
            # first attempt: make progress to step 5, then die
            store.save(ckpt_dir, 5, {"w": np.zeros(2, np.float32)})
            raise RuntimeError("mid-train crash")
        return resume_step

    task, = agent.submit([TaskDescription(
        name="ckpt-train", fn=train, checkpoint_dir=ckpt_dir,
        max_retries=1, speculative=False)])
    assert task.state == TaskState.DONE, task.error
    assert seen == [None, 5], (
        f"agent did not thread the checkpoint step into the retry: {seen}")
    assert task.result == 5
    agent.close()


def test_checkpoint_retry_with_no_checkpoint_passes_none(tmp_path):
    agent = make_agent(2)
    seen = []

    def flaky(comm, resume_step=None):
        seen.append(resume_step)
        if len(seen) == 1:
            raise RuntimeError("crash before any checkpoint")
        return "ok"

    task, = agent.submit([TaskDescription(
        name="no-ckpt", fn=flaky, checkpoint_dir=str(tmp_path / "empty"),
        max_retries=1, speculative=False)])
    assert task.state == TaskState.DONE
    assert seen == [None, None]
    agent.close()
