"""Fleet serving tests: EngineRouter load-aware routing, non-terminal
drain, rolling engine restart mid-stream (checkpoint/resume; greedy
streams bitwise-equal to an undisturbed run), pilot-mode preemption
re-route under one PilotManager with zero quota violations, and
disaggregated prefill/decode KV handoff (page blocks shipped through the
transport and re-addressed by block-table rewrite — bitwise-equal to
colocated serving, bytes bounded by the migrating request's own pages).

Like tests/test_serving.py, token-stream equivalence runs in f32 compute
(in bf16 two near-tied logits can argmax-flip between numerically
different but equally valid paths); params are shared — the compute
dtype is applied at runtime.  Pilot-mode tests run on FakePilots over
plain-object devices, so an 8-device fleet is modelled on the
container's single real device.
"""
import dataclasses
import time
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.params import init_params
from repro.configs import get_config
from repro.core.pilot import Pilot, PilotDescription, PilotManager
from repro.core.task import TaskDescription, TaskState
from repro.serve import (EngineRouter, Request, RequestState, ServeEngine,
                         build_fleet)
from repro.train.state import model_specs

CFG = get_config("tinyllama-1.1b", smoke=True)
CFG32 = dataclasses.replace(CFG, compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), model_specs(CFG))


def _prompts(rng, lens):
    return [rng.integers(1, CFG.vocab_size, int(n)).astype(np.int32)
            for n in lens]


def _ref_streams(params, prompts, gen, *, max_len=96):
    """The undisturbed single-engine run every fleet test must match."""
    eng = ServeEngine(CFG32, params=params, max_slots=2, max_len=max_len,
                      page_size=16)
    reqs = [eng.submit(Request(p, max_new_tokens=gen)) for p in prompts]
    eng.run_until_drained()
    return [r.tokens for r in reqs]


# ---------------------------------------------------------------------------
# routing: load-aware spread, bitwise streams, non-terminal drain
# ---------------------------------------------------------------------------


def test_router_spreads_load_and_matches_reference(params):
    rng = np.random.default_rng(0)
    prompts = _prompts(rng, rng.integers(4, 30, 10))
    ref = _ref_streams(params, prompts, 16)

    router = build_fleet(CFG32, num_engines=2, params=params, max_slots=2,
                         max_len=96, page_size=16, name_prefix="t")
    with router:
        reqs = [router.submit(Request(p, max_new_tokens=16))
                for p in prompts]
        assert router.drain(timeout=180)
        # drain is a flush, not a shutdown: the router keeps accepting
        extra = [router.submit(Request(p, max_new_tokens=4))
                 for p in prompts[:2]]
        assert router.drain(timeout=60)
        stats = router.stats()
    assert [r.tokens for r in reqs] == ref, "fleet changed token streams"
    assert all(r.state is RequestState.DONE for r in extra)
    spread = {k: v for k, v in stats.items() if k.startswith("routed_to.")}
    assert len(spread) == 2, f"both engines must serve: {spread}"
    assert stats["fleet_completed"] == len(reqs) + len(extra)


def test_router_admission_signals_one_lock_snapshot(params):
    eng = ServeEngine(CFG32, params=params, max_slots=2, max_len=64,
                      page_size=16, name="sig")
    sig = eng.admission_signals()
    assert sig["engine"] == "sig" and not sig["prefill_only"]
    assert sig["occupied"] == 0 and sig["queue_depth"] == 0
    assert sig["free_pages"] == sig["num_pages"] == eng.num_pages
    eng.submit(Request(np.arange(1, 6, dtype=np.int32), max_new_tokens=4))
    sig = eng.admission_signals()
    assert sig["queue_depth"] == 1
    assert sig["oldest_queued_age_s"] >= 0.0


# ---------------------------------------------------------------------------
# drain + rolling restart: checkpoint/resume mid-stream, bitwise streams
# ---------------------------------------------------------------------------


def test_rolling_restart_mid_stream_bitwise(params):
    rng = np.random.default_rng(1)
    prompts = _prompts(rng, rng.integers(4, 30, 12))
    ref = _ref_streams(params, prompts, 24)

    router = build_fleet(CFG32, num_engines=2, params=params, max_slots=2,
                         max_len=96, page_size=16, name_prefix="rr")
    with router:
        reqs = [router.submit(Request(p, max_new_tokens=24))
                for p in prompts]
        # wait until engine 0 actually holds bound in-flight work, then
        # bounce it: queued entries re-route, bound slots checkpoint and
        # resume exactly where they stopped
        t0 = time.time()
        while (router.members[0].engine.occupancy() == 0
               and time.time() - t0 < 60):
            time.sleep(0.002)
        assert router.members[0].engine.occupancy() > 0
        router.rolling_restart(0)
        assert router.drain(timeout=180)
        stats = router.stats()
    assert [r.tokens for r in reqs] == ref, "restart changed token streams"
    assert stats["restarts"] == 1
    assert sum(e.get("resumes", 0) for e in stats["engines"]) >= 1


# ---------------------------------------------------------------------------
# pilot mode: placement, priority preemption, re-route, quotas
# ---------------------------------------------------------------------------


class FakeDevice:
    def __init__(self, i):
        self.id = i
        self.platform = "fake"


class FakePilot(Pilot):
    """Pilot over dummy devices; carve returns a mesh-free communicator."""

    def carve(self, devices, mesh_shape=None, mesh_axes=("data",)):
        return SimpleNamespace(devices=tuple(devices), size=len(devices),
                               backend="fake", build_time_s=0.0,
                               pilot_uid=self.uid)


def test_pilot_mode_preemption_reroutes_without_quota_violations(params):
    rng = np.random.default_rng(1)
    prompts = _prompts(rng, rng.integers(4, 30, 12))
    ref = _ref_streams(params, prompts, 24)

    mgr = PilotManager(devices=[FakeDevice(i) for i in range(8)],
                       pilot_factory=FakePilot)
    mgr.submit_pilot(PilotDescription(num_devices=4, name="pod0"))
    mgr.submit_pilot(PilotDescription(num_devices=4, name="pod1"))
    engines = [ServeEngine(CFG32, params=params, max_slots=2, max_len=96,
                           page_size=16, name=f"pm{i}") for i in range(2)]
    router = EngineRouter(engines, manager=mgr, group="fleet", priority=0)
    with router:
        assert len({m.pilot.uid for m in router.members}) == 2, \
            "engines must land on distinct pilots"
        reqs = [router.submit(Request(p, max_new_tokens=24))
                for p in prompts]
        assert router.drain(timeout=180)

        # a higher-priority task wanting the whole pod forces the service
        # lease to yield: the agent preempts engine 0, the router steals
        # its inbox and re-routes, and the quota ledger stays clean
        m0 = router.members[0]
        m0.agent.set_quota("fleet", 4)

        def hog(comm):
            time.sleep(0.3)
            return "done"

        tasks = m0.agent.submit_async([TaskDescription(
            name="hog", fn=hog, num_devices=4, priority=10)])
        extra = [router.submit(Request(p, max_new_tokens=8))
                 for p in prompts[:6]]
        m0.agent.wait(tasks, timeout=120)
        assert tasks[0].state is TaskState.DONE, tasks[0].error
        assert router.drain(timeout=180)
        violations = m0.agent.quota_violations()
        assert m0.agent.preemption_requests >= 1
    assert [r.tokens for r in reqs] == ref, "pilot-mode changed streams"
    assert all(r.state is RequestState.DONE for r in extra)
    assert not violations, f"quota violations during preemption: {violations}"


# ---------------------------------------------------------------------------
# disaggregation: prefill -> decode KV handoff
# ---------------------------------------------------------------------------


def test_disaggregated_handoff_bitwise_and_byte_bounded(params):
    # 17 and 23 straddle a page boundary at page_size=16: the handoff
    # must preserve intra-page offsets across the block-table rewrite
    prompts = [np.arange(1, 6, dtype=np.int32),
               np.arange(1, 18, dtype=np.int32),
               np.arange(1, 24, dtype=np.int32)]
    ref = _ref_streams(params, prompts, 12, max_len=64)

    router = build_fleet(CFG32, num_engines=2, disaggregate=True,
                         params=params, max_slots=4, max_len=64,
                         page_size=16, name_prefix="dg")
    with router:
        reqs = [router.submit(Request(p, max_new_tokens=12))
                for p in prompts]
        assert router.drain(timeout=180)
        stats = router.stats()
        eng = router.members[0].engine
        pool_bytes = eng._page_bytes * eng.num_pages
    assert [r.tokens for r in reqs] == ref, "handoff changed token streams"
    assert stats["handoffs_routed"] == len(prompts), \
        "every prompt must migrate exactly once"
    owned_pages = sum(-(-len(p) // 16) for p in prompts)
    assert stats["handoff_pages"] == owned_pages
    assert stats["handoff_bytes"] == owned_pages * eng._page_bytes, \
        "handoff must ship exactly the owned pages, never the pool"
    assert stats["handoff_bytes"] < pool_bytes
    assert stats["fleet_handoffs_exported"] == len(prompts)
    assert stats["fleet_handoffs_imported"] == len(prompts)


def test_handoff_export_import_block_table_rewrite(params):
    prompt = np.arange(1, 18, dtype=np.int32)  # 2 pages, straddles one
    ref = _ref_streams(params, [prompt], 8, max_len=64)

    pre = ServeEngine(CFG32, params=params, max_slots=2, max_len=64,
                      page_size=16, prefill_only=True, name="pre")
    req = pre.submit(Request(prompt, max_new_tokens=8))
    pre.run_until_drained()  # prefill engine drains by exporting the slot
    [hand] = pre.take_handoffs()
    assert req.state is RequestState.RUNNING, \
        "migrating request must stay RUNNING across the handoff"
    assert hand.n_pages == 2 and hand.page_size == 16
    assert hand.kv_bytes == 2 * pre._page_bytes
    assert len(req.tokens) == 1, "prefill engine samples the first token"
    # the exporter's pages are back in the pool, its table row sentineled
    assert len(pre.free_pages) == pre.num_pages
    assert (pre.block_table == pre.num_pages).all()

    dec = ServeEngine(CFG32, params=params, max_slots=2, max_len=64,
                      page_size=16, name="dec")
    assert dec.submit(hand) is req
    dec.step()  # admit (import) + one decode step
    row = dec.block_table[0]
    assert (row[:2] < dec.num_pages).all(), "imported pages must be bound"
    assert (row[2:] == dec.num_pages).all(), \
        "beyond the owned pages the table row stays sentinel-padded"
    dec.run_until_drained()
    assert req.state is RequestState.DONE
    assert [req.tokens] == ref, "migrated stream must match colocated"
    assert dec.stats()["handoffs_imported"] == 1
