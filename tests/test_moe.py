"""MoE dispatch correctness: the optimized gather dispatch must agree with
the GShard-classic einsum dispatch (same routing, same outputs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.params import init_params
from repro.configs import get_config
from repro.models import blocks as B

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["moonshot-v1-16b-a3b", "arctic-480b"])
def test_moe_gather_matches_einsum(arch):
    cfg = get_config(arch, smoke=True).with_overrides(capacity_factor=8.0)
    specs = B.moe_specs(cfg)
    params = init_params(KEY, specs)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5

    y_einsum, aux_e = B.moe_apply(cfg.with_overrides(moe_impl="einsum"), params, x)
    y_gather, aux_g = B.moe_apply(cfg.with_overrides(moe_impl="gather"), params, x)
    np.testing.assert_allclose(
        np.asarray(y_einsum, np.float32), np.asarray(y_gather, np.float32),
        atol=2e-2, rtol=2e-2,
    )
    np.testing.assert_allclose(float(aux_e), float(aux_g), rtol=1e-5)


def test_moe_capacity_drops_counted_consistently():
    """With a tiny capacity factor both impls drop the same token slots
    (output differs from the no-drop case but matches each other)."""
    cfg = get_config("moonshot-v1-16b-a3b", smoke=True).with_overrides(
        capacity_factor=0.5)
    specs = B.moe_specs(cfg)
    params = init_params(KEY, specs)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model)) * 0.5
    y_e, _ = B.moe_apply(cfg.with_overrides(moe_impl="einsum"), params, x)
    y_g, _ = B.moe_apply(cfg.with_overrides(moe_impl="gather"), params, x)
    np.testing.assert_allclose(
        np.asarray(y_e, np.float32), np.asarray(y_g, np.float32),
        atol=2e-2, rtol=2e-2,
    )


def test_moe_grad_flows_both_impls():
    cfg = get_config("moonshot-v1-16b-a3b", smoke=True)
    specs = B.moe_specs(cfg)
    params = init_params(KEY, specs)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model)) * 0.5
    for impl in ("einsum", "gather"):
        c = cfg.with_overrides(moe_impl=impl)

        def loss(p):
            y, aux = B.moe_apply(c, p, x)
            return jnp.mean(y.astype(jnp.float32) ** 2) + 0.01 * aux

        g = jax.grad(loss)(params)
        gn = float(jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                                for l in jax.tree.leaves(g))))
        assert np.isfinite(gn) and gn > 0, impl
