# True multi-process end-to-end check: an 8-device parent drives pilots
# whose attempts execute in separate worker interpreters (each with its
# own emulated device pool), including fault injection + checkpoint
# retry and a cross-pod pipeline.  XLA_FLAGS/PYTHONPATH provided by
# conftest.run_spawned; task fns live in exec_tasks.py (see its
# docstring for why they cannot live here).
import os
import tempfile
import time

import jax

import exec_tasks as T
from repro.core import Session
from repro.core.agent import RemoteAgent
from repro.core.exec import SubprocessTransport
from repro.core.pilot import PilotDescription, PilotManager
from repro.core.task import TaskDescription, TaskState

assert len(jax.devices()) == 8, len(jax.devices())

# --- concurrent multi-device tasks over a shared worker pool ---------------
transport = SubprocessTransport(max_workers=2, worker_devices=2)
pm = PilotManager()
pilot = pm.submit_pilot(PilotDescription(num_devices=8))
agent = RemoteAgent(pilot, transport=transport, max_workers=2)

tasks = agent.submit([
    TaskDescription(name=f"t{i}", fn=T.mesh_sum, args=(64 + i,),
                    num_devices=2) for i in range(4)])
assert all(t.state == TaskState.DONE for t in tasks), \
    [(t.uid, t.error) for t in tasks]
pids = {t.result["pid"] for t in tasks}
parent = os.getpid()
assert parent not in pids, "task ran in the parent process"
# both workers usually serve (2 in flight); a slow second boot on a
# starved host can funnel everything through one — that's still correct
assert 1 <= len(pids) <= 2, pids
for t in tasks:
    # worker-side pool is its own 2-device emulation, not the parent's 8
    assert t.result["worker_devices"] == 2, t.result
    assert t.result["comm_devices"] == 2, t.result
print("concurrent multi-device tasks OK across worker pids", sorted(pids))

# --- fault injection: SIGKILL mid-task -> checkpoint-aware retry -----------
ckpt = tempfile.mkdtemp(prefix="rc-exec-ckpt-")
t0 = time.time()
task, = agent.submit([TaskDescription(
    name="train", fn=T.train_then_die, args=(ckpt,), checkpoint_dir=ckpt,
    max_retries=2, group="g")])
assert task.state == TaskState.DONE, task.error
assert task.result == ("resumed", 7), task.result
assert task.attempts == 2, task.attempts
assert agent.quota_violations() == {}
assert pilot.free_count() == 8, "lease leaked across worker death"
print(f"checkpoint retry OK after worker SIGKILL ({time.time()-t0:.1f}s)")
agent.close()

# --- Session pipeline on two pods, both over subprocess workers ------------
with Session(pods=[
        PilotDescription(num_devices=4, name="pod-a",
                         task_kinds=("data_engineering",)),
        PilotDescription(num_devices=4, name="pod-b",
                         task_kinds=("train",))],
        max_workers_per_pilot=1, transport=transport) as session:
    out = session.run(T.make_stage >> T.reduce_stage, name="xpod")
assert out["reduce"] == float(sum(i * i for i in range(32))), out
print("cross-pod pipeline over subprocess transport OK:", out["reduce"])

# --- shutdown reaps every worker -------------------------------------------
pids = transport.worker_pids()
transport.shutdown(wait=False)
deadline = time.time() + 10
while time.time() < deadline:
    alive = []
    for p in pids:
        try:
            os.kill(p, 0)
            with open(f"/proc/{p}/stat") as f:
                if f.read().split()[2] != "Z":
                    alive.append(p)
        except (ProcessLookupError, OSError):
            pass
    if not alive:
        break
    time.sleep(0.05)
assert not alive, f"orphaned workers: {alive}"
print("shutdown reaped all workers OK")
print("ALL SUBPROCESS TRANSPORT TESTS PASS")
