import os
# XLA_FLAGS provided by conftest
import sys, time; # PYTHONPATH provided by conftest
import jax, jax.numpy as jnp, numpy as np
from repro.core.pilot import PilotManager, PilotDescription
from repro.core.agent import RemoteAgent
from repro.core.task import TaskDescription, TaskState, DeviceFailure
from repro.core.pipeline import Pipeline, Stage, run_pipelines

pm = PilotManager()
pilot = pm.submit_pilot(PilotDescription(num_devices=8))
agent = RemoteAgent(pilot, max_workers=4)

# basic task execution with communicator
def compute(comm, x):
    import jax.numpy as jnp
    return float(jnp.sum(jnp.ones((x,))) )
tasks = agent.submit([TaskDescription(name=f"t{i}", fn=compute, args=(100+i,), num_devices=2) for i in range(6)])
assert all(t.state == TaskState.DONE for t in tasks), [t.error for t in tasks]
print("basic exec OK; overheads:", {k: round(v,4) for k,v in tasks[0].overhead_s.items()})

# fault injection: task fails twice then succeeds
attempts = {"n": 0}
def flaky(comm):
    attempts["n"] += 1
    if attempts["n"] < 3: raise RuntimeError("transient")
    return "recovered"
t, = agent.submit([TaskDescription(name="flaky", fn=flaky, max_retries=3)])
assert t.state == TaskState.DONE and t.result == "recovered" and t.attempts == 3
print("retry OK after", t.attempts, "attempts")

# device failure -> elastic re-carve
calls = {"n": 0}
def failing_devices(comm):
    calls["n"] += 1
    if calls["n"] == 1:
        raise DeviceFailure([d.id for d in comm.devices[:2]])
    return comm.size
t, = agent.submit([TaskDescription(name="devfail", fn=failing_devices, num_devices=8, max_retries=2)])
assert t.state == TaskState.DONE, t.error
assert t.result == 6, t.result  # re-carved on 6 survivors
print("elastic recovery OK: reran on", t.result, "devices; alive:", len(pilot.alive_devices()))

# disjoint pools: the first pilot owns all 8 devices, so a second submit
# must raise until the first pilot is canceled (seed bug: devices[:n]
# handed out overlapping slices silently)
try:
    pm.submit_pilot(PilotDescription(num_devices=2))
    raise AssertionError("overlapping pilot was handed out")
except RuntimeError as e:
    print("exhausted-pool submit raises OK:", e)
agent.close()
recovered = pm.cancel_pilot(pilot)
assert recovered == 6, recovered  # 2 devices died above and stay retired
pilot2 = pm.submit_pilot(PilotDescription(num_devices=4))
pilot3 = pm.submit_pilot(PilotDescription(num_devices=2))
ids2 = {d.id for d in pilot2.alive_devices()}
ids3 = {d.id for d in pilot3.alive_devices()}
assert not ids2 & ids3, f"pilot pools overlap: {ids2 & ids3}"
print("disjoint pools OK:", sorted(ids2), "|", sorted(ids3))

# pipeline DAG on the re-acquired disjoint pilot
def produce(comm, upstream): return 21
def consume(comm, upstream): return upstream["produce"] * 2
p = Pipeline("demo", [Stage("produce", produce), Stage("consume", consume, deps=("produce",))])
out = p.run(RemoteAgent(pilot2, max_workers=2))
assert out["consume"] == 42
print("pipeline DAG OK:", out)

# checkpoint roundtrip with elastic reshard
from repro.checkpoint import store
from repro.launch.mesh import make_mesh
from jax.sharding import NamedSharding, PartitionSpec as P
state = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8,4), "step": jnp.asarray(7)}
path = store.save("/tmp/ckpt_test", 7, state)
mesh2 = make_mesh((4,), ("data",))
sh = {"w": NamedSharding(mesh2, P("data")), "step": None}
restored = store.restore("/tmp/ckpt_test", state, shardings=sh)
assert np.allclose(restored["w"], state["w"]) and int(restored["step"]) == 7
print("checkpoint restore (4-dev reshard) OK:", restored["w"].sharding)
ac = store.AsyncCheckpointer("/tmp/ckpt_async", keep=2)
for s in range(4): ac.save(s, state)
ac.close()
assert store.latest_step("/tmp/ckpt_async") == 3
print("async checkpointer OK, kept:", sorted(os.listdir('/tmp/ckpt_async')))
print("ALL RUNTIME TESTS PASS")
