import os
# XLA_FLAGS provided by conftest
import sys
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.distributed.pipeline_par import pipeline_forward
from repro.distributed.collectives import int8_psum, compressed_grad_sync
from jax.experimental.shard_map import shard_map
import functools

# --- pipeline parallelism: 4 stages, stage i adds w[i] and doubles ---
mesh = make_mesh((4,), ("pipe",))
n_micro, mb, d = 8, 2, 16
x = jax.random.normal(jax.random.PRNGKey(0), (n_micro, mb, d))
w = jnp.arange(1.0, 5.0)[:, None] * jnp.ones((4, d))

def stage_fn(params, x):
    return x * 2.0 + params

got = pipeline_forward(stage_fn, w, x, mesh, axis="pipe")
want = x
for i in range(4):
    want = want * 2.0 + w[i]
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
print("pipeline_forward OK")

# --- int8 gradient all-reduce ---
mesh8 = make_mesh((8,), ("data",))
g_local = jax.random.normal(jax.random.PRNGKey(1), (8, 1024)) * 0.01

@functools.partial(shard_map, mesh=mesh8, in_specs=P("data"), out_specs=P("data"))
def sync(g):
    return int8_psum(g[0], "data")[None] / 8.0

synced = sync(g_local)
want = jnp.mean(g_local, axis=0)
err = float(jnp.max(jnp.abs(synced[0] - want)) / (jnp.max(jnp.abs(want)) + 1e-9))
assert err < 0.02, f"int8 psum relative error too high: {err}"
# every shard sees the same result
np.testing.assert_allclose(np.asarray(synced[0]), np.asarray(synced[3]), rtol=1e-6)
print(f"int8_psum OK (rel err {err:.4f})")

# --- error feedback reduces bias over repeated syncs ---
grads = {"w": jax.random.normal(jax.random.PRNGKey(2), (512,)) * 0.01}
ef = None
accum_plain = jnp.zeros((512,))
accum_ef = jnp.zeros((512,))
for step in range(8):
    synced, ef = compressed_grad_sync(grads, mesh8, "data", error_feedback=ef)
    accum_ef = accum_ef + synced["w"]
    plain, _ = compressed_grad_sync(grads, mesh8, "data", error_feedback=None)
    accum_plain = accum_plain + plain["w"]
true = grads["w"] * 8
err_ef = float(jnp.linalg.norm(accum_ef - true))
err_plain = float(jnp.linalg.norm(accum_plain - true))
assert err_ef <= err_plain * 1.05, (err_ef, err_plain)
print(f"error feedback OK (ef={err_ef:.5f} <= plain={err_plain:.5f})")
print("ALL DISTRIBUTED EXTRAS PASS")
