"""Module-level task fns for tests/spawn/subprocess_transport.py.

Spawn scripts run as plain ``__main__`` scripts (no importable module
spec), so their task fns must live HERE: the script directory is
``sys.path[0]`` in the parent and the transport propagates ``sys.path``
into each worker's PYTHONPATH, so ``exec_tasks.<fn>`` resolves by
qualified name inside the worker interpreter.
"""
import os
import signal

import numpy as np

from repro.checkpoint import store
from repro.core import stage


def mesh_sum(comm, n):
    """Runs on the worker's own carved communicator: proves each worker
    owns an isolated device pool (parent devices never cross the wire)."""
    import jax
    import jax.numpy as jnp
    x = jnp.ones((n,))
    return {"total": float(jnp.sum(x)), "worker_devices": len(jax.devices()),
            "comm_devices": comm.size, "pid": os.getpid()}


def train_then_die(comm, ckpt_dir, resume_step=None):
    if resume_step is None:
        store.save(ckpt_dir, 7, {"w": np.zeros(2, np.float32)})
        os.kill(os.getpid(), signal.SIGKILL)
    return ("resumed", resume_step)


@stage(kind="data_engineering", name="make")
def make_stage(ctx):
    return np.arange(32, dtype=np.float32)


@stage(kind="train", name="reduce")
def reduce_stage(ctx):
    import jax.numpy as jnp
    x = ctx.upstream["make"]
    return float(jnp.sum(jnp.asarray(x) ** 2))
