import os
# XLA_FLAGS provided by conftest
import sys; # PYTHONPATH provided by conftest
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.dataframe.table import Table
from repro.dataframe import ops_dist as D

mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
N = 4096
keys = rng.integers(0, 1000, N).astype(np.int32)
vals = rng.normal(size=N).astype(np.float32)
t = Table.from_columns({"k": keys, "v": vals}, mesh)

# shuffle: equal keys co-located
s, dropped = D.shuffle(t, "k")
print("shuffle dropped:", dropped, "valid:", s.num_valid, "/", N)
assert dropped == 0 and s.num_valid == N

# sort
st, dropped = D.sort(t, "k")
out = st.to_numpy()
# within each shard sorted; global: shard i max <= shard i+1 min
kk = np.asarray(st.col("k")); vv = np.asarray(st.valid)
per = kk.shape[0] // 8
glob = []
for i in range(8):
    seg = kk[i*per:(i+1)*per][vv[i*per:(i+1)*per]]
    assert np.all(np.diff(seg) >= 0), "shard not sorted"
    glob.append(seg)
for i in range(7):
    if len(glob[i]) and len(glob[i+1]):
        assert glob[i].max() <= glob[i+1].min(), "splitters wrong"
allk = np.concatenate(glob)
assert dropped == 0 and len(allk) == N and np.all(np.sort(keys) == allk)
print("sort OK, dropped:", dropped)

# join
rkeys = np.arange(1000).astype(np.int32)
rvals = (rkeys * 10).astype(np.float32)
r = Table.from_columns({"k": rkeys, "w": rvals}, mesh)
j, dropped = D.join(t, r, "k")
jo = j.to_numpy()
assert np.all(jo["w"] == jo["k"] * 10), "join values wrong"
print("join OK rows:", len(jo["k"]), "dropped:", dropped)
assert len(jo["k"]) == N and dropped == 0

# groupby
g, dropped = D.groupby_sum(t, "k", ["v"])
go = g.to_numpy()
import collections
ref = collections.defaultdict(float)
for k, v in zip(keys, vals): ref[int(k)] += float(v)
got = dict(zip(go["k"].tolist(), go["v"].tolist()))
for k in list(ref)[:50]:
    assert abs(ref[k] - got[k]) < 1e-3, (k, ref[k], got.get(k))
print("groupby OK groups:", len(go["k"]))

# reduce
rs = D.reduce_sum(t, ["v"])
assert abs(rs["v"] - vals.sum()) < 1e-2
print("reduce OK:", rs)

# loader
from repro.bridge.loader import ZeroCopyLoader
tl = Table.from_columns({"f1": vals, "f2": vals*2, "y": keys}, mesh)
ld = ZeroCopyLoader(tl, ["f1","f2"], "y", 256)
feats, labels, mask = next(iter(ld))
print("loader batch:", feats.shape, labels.shape, feats.sharding.spec if hasattr(feats,'sharding') else None)
assert feats.shape == (256, 2)
print("ALL DF TESTS PASS")
