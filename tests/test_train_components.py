"""Optimizer / loader / checkpoint unit tests + hypothesis properties."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.params import Param, abstract_params, init_params
from repro.configs.base import RunConfig
from repro.train import optimizer as O


def _quadratic_target():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(16, 8)), jnp.float32)

    def loss_fn(params):
        return jnp.mean((params["w"] - target) ** 2)

    return target, loss_fn


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizer_converges_on_quadratic(opt):
    target, loss_fn = _quadratic_target()
    run = RunConfig(optimizer=opt, learning_rate=0.05, weight_decay=0.0)
    specs = {"w": Param((16, 8), (None, None))}
    params = init_params(jax.random.PRNGKey(0), specs)
    state = init_params(jax.random.PRNGKey(1), O.opt_specs(specs, run))

    @jax.jit
    def step(params, state, i):
        g = jax.grad(loss_fn)(params)
        return O.opt_update(g, state, params, i, run)

    l0 = float(loss_fn(params))
    for i in range(200):
        params, state = step(params, state, jnp.asarray(i))
    assert float(loss_fn(params)) < l0 * 0.05, (opt, l0, float(loss_fn(params)))


def test_opt_specs_shapes_match():
    run_a = RunConfig(optimizer="adamw", opt_state_dtype=jnp.bfloat16)
    run_f = RunConfig(optimizer="adafactor")
    specs = {"big": Param((64, 128), ("embed", "mlp")),
             "vec": Param((64,), (None,))}
    a = abstract_params(O.opt_specs(specs, run_a))
    assert a["big"]["m"].shape == (64, 128) and a["big"]["m"].dtype == jnp.bfloat16
    f = abstract_params(O.opt_specs(specs, run_f))
    assert f["big"]["vr"].shape == (64,) and f["big"]["vc"].shape == (128,)
    assert f["vec"]["v"].shape == (64,)  # unfactored for vectors


@given(seed=st.integers(0, 1000))
@settings(deadline=None, max_examples=10)
def test_clip_by_global_norm_property(seed):
    from repro.train.step import clip_by_global_norm, global_norm

    tree = {"a": jax.random.normal(jax.random.PRNGKey(seed), (32,)) * 10,
            "b": jax.random.normal(jax.random.PRNGKey(seed + 1), (4, 4))}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-4
    # direction preserved
    ratio = clipped["a"] / tree["a"]
    assert float(jnp.std(ratio)) < 1e-5


def test_zero_copy_loader_partition_determinism():
    from repro.bridge.loader import ZeroCopyLoader
    from repro.dataframe.table import Table

    n = 1024
    t = Table.from_columns({
        "f": np.arange(n, dtype=np.float32),
        "y": np.arange(n, dtype=np.int32),
    })
    ld = ZeroCopyLoader(t, ["f"], "y", global_batch=128, shuffle=True, seed=7)
    e0 = [np.asarray(l) for _, l, _ in ld.epoch(0)]
    e0b = [np.asarray(l) for _, l, _ in ld.epoch(0)]
    e1 = [np.asarray(l) for _, l, _ in ld.epoch(1)]
    assert all((a == b).all() for a, b in zip(e0, e0b)), "epoch not deterministic"
    assert any((a != b).any() for a, b in zip(e0, e1)), "shuffle not epoch-varying"
    seen = np.sort(np.concatenate(e0))
    assert (seen == np.arange(n)).all(), "not a permutation"


def test_checkpoint_roundtrip_tmpdir(tmp_path):
    from repro.checkpoint import store

    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
             "step": jnp.asarray(3)}
    store.save(str(tmp_path), 3, state)
    assert store.latest_step(str(tmp_path)) == 3
    restored = store.restore(str(tmp_path), state)
    np.testing.assert_allclose(restored["params"]["w"], state["params"]["w"])
    assert int(restored["step"]) == 3


def test_hydrology_and_forecasting_models_smoke():
    from repro.models import forecasting as F
    from repro.models import hydrology as Hy

    for name, builder in F.MODELS.items():
        init, apply = builder(32, 8)
        params = init(jax.random.PRNGKey(0))
        y = apply(params, jnp.ones((4, 32)))
        assert y.shape == (4, 8), name
        assert np.all(np.isfinite(np.asarray(y))), name
    p = Hy.lstm_init(jax.random.PRNGKey(0))
    out = Hy.lstm_apply(p, jnp.ones((2, 16, Hy.N_FEATURES)))
    assert out.shape == (2, 3) and np.all(np.isfinite(np.asarray(out)))
