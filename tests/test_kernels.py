"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode),
plus hypothesis property tests on kernel invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import decode_attention as da
from repro.kernels import flash_attention as fa
from repro.kernels import hash_partition as hp
from repro.kernels import ref
from repro.kernels import rmsnorm as rn
from repro.kernels import ops

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,H,KV,S,D", [
    (1, 4, 4, 128, 64),    # MHA
    (2, 8, 2, 256, 64),    # GQA 4x
    (1, 4, 1, 128, 128),   # MQA
    (1, 8, 8, 192, 32),    # non-128 block tail
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, KV, S, D, dtype):
    q = jax.random.normal(KEY, (B, H, S, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, D), dtype)
    got = fa.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                             interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_attention_causality():
    """Output at position i must not depend on tokens > i."""
    B, H, S, D = 1, 2, 128, 64
    q = jax.random.normal(KEY, (B, H, S, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D))
    out1 = fa.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    k2 = k.at[:, :, 64:].set(99.0)
    v2 = v.at[:, :, 64:].set(-99.0)
    out2 = fa.flash_attention(q, k2, v2, causal=True, block_q=64, block_k=64,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(out1[:, :, :64]),
                               np.asarray(out2[:, :, :64]), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("B,H,KV,S,D,bk", [
    (2, 8, 2, 512, 64, 128),
    (1, 4, 4, 256, 128, 64),
    (4, 16, 1, 1024, 64, 256),
])
def test_decode_attention_matches_ref(B, H, KV, S, D, bk):
    q = jax.random.normal(KEY, (B, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, D))
    cl = jnp.asarray(S * 3 // 4, jnp.int32)
    got = da.decode_attention(q, k, v, cl, block_k=bk, interpret=True)
    want = ref.decode_attention_ref(q, k, v, cl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@given(cache_len=st.integers(min_value=1, max_value=256))
@settings(deadline=None, max_examples=10)
def test_decode_attention_cache_len_property(cache_len):
    """Positions >= cache_len never contribute."""
    B, H, KV, S, D = 1, 2, 2, 256, 32
    q = jax.random.normal(KEY, (B, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, D))
    cl = jnp.asarray(cache_len, jnp.int32)
    base = da.decode_attention(q, k, v, cl, block_k=64, interpret=True)
    k2 = k.at[:, :, cache_len:].set(7.0)
    v2 = v.at[:, :, cache_len:].set(-7.0)
    got = da.decode_attention(q, k2, v2, cl, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(got),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("shape", [(32, 128), (4, 17, 256), (1, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.PRNGKey(3), (shape[-1],), dtype)
    got = rn.rmsnorm(x, w, block_rows=16, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@given(n=st.integers(100, 5000), p=st.sampled_from([4, 16, 64]))
@settings(deadline=None, max_examples=10)
def test_hash_partition_histogram_property(n, p):
    """Per-block histograms sum to the exact global histogram."""
    keys = jax.random.randint(jax.random.PRNGKey(n), (n,), 0, 10_000)
    hist = hp.hash_partition_histogram(keys, num_buckets=p, block=512,
                                       interpret=True)
    want = ref.hash_partition_histogram_ref(keys, num_buckets=p)
    np.testing.assert_array_equal(np.asarray(hist.sum(0)), np.asarray(want))
    assert int(hist.sum()) == n


def test_partition_order_bucket_contiguous():
    keys = jax.random.randint(KEY, (5000,), 0, 10_000)
    order, offsets = hp.partition_order(keys, 16, interpret=True)
    b = np.asarray((ref.hash_u32_ref(keys) % jnp.uint32(16)).astype(jnp.int32))
    assert np.all(np.diff(b[np.asarray(order)]) >= 0)
    assert offsets.shape == (16,)


def test_ops_dispatch_ref_path():
    """impl='ref' and impl='interpret' agree (CPU container has no TPU)."""
    q = jax.random.normal(KEY, (1, 4, 64, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 64, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 64, 32))
    a = ops.flash_attention(q, k, v, impl="ref")
    b = ops.flash_attention(q, k, v, impl="interpret", block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)
