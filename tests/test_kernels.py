"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode),
plus hypothesis property tests on kernel invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import decode_attention as da
from repro.kernels import flash_attention as fa
from repro.kernels import hash_partition as hp
from repro.kernels import ref
from repro.kernels import rmsnorm as rn
from repro.kernels import ops

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,H,KV,S,D", [
    (1, 4, 4, 128, 64),    # MHA
    (2, 8, 2, 256, 64),    # GQA 4x
    (1, 4, 1, 128, 128),   # MQA
    (1, 8, 8, 192, 32),    # non-128 block tail
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, KV, S, D, dtype):
    q = jax.random.normal(KEY, (B, H, S, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, D), dtype)
    got = fa.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                             interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_attention_causality():
    """Output at position i must not depend on tokens > i."""
    B, H, S, D = 1, 2, 128, 64
    q = jax.random.normal(KEY, (B, H, S, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D))
    out1 = fa.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    k2 = k.at[:, :, 64:].set(99.0)
    v2 = v.at[:, :, 64:].set(-99.0)
    out2 = fa.flash_attention(q, k2, v2, causal=True, block_q=64, block_k=64,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(out1[:, :, :64]),
                               np.asarray(out2[:, :, :64]), atol=1e-5, rtol=1e-5)


def _decode_inputs(B, H, KV, S, D, seed=0):
    """Cache-native layout: q [B,H,D]; k, v [B,S,KV,D]."""
    q = jax.random.normal(jax.random.PRNGKey(seed), (B, H, D))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, KV, D))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (B, S, KV, D))
    return q, k, v


def _paged_inputs(B, H, KV, S, D, page, seed=0, scramble=True):
    """Pool + scrambled block table covering [B, S] logical positions,
    with spare pages left unused and sentinel entries appended."""
    rng = np.random.default_rng(seed)
    mp = S // page
    num_pages = B * mp + 3  # spare pages: gather must ignore them
    q = jax.random.normal(jax.random.PRNGKey(seed), (B, H, D))
    kp = jax.random.normal(jax.random.PRNGKey(seed + 1),
                           (num_pages, page, KV, D))
    vp = jax.random.normal(jax.random.PRNGKey(seed + 2),
                           (num_pages, page, KV, D))
    ids = (rng.permutation(num_pages)[:B * mp] if scramble
           else np.arange(B * mp))
    bt = jnp.asarray(ids.reshape(B, mp).astype(np.int32))
    return q, kp, vp, bt, num_pages


# -- vector-length (per-row [B] cache lengths) parity -----------------------

@pytest.mark.parametrize("B,H,KV,S,D,bk", [
    (2, 8, 2, 512, 64, 128),   # GQA 4x
    (1, 4, 4, 256, 128, 64),   # MHA
    (4, 16, 1, 1024, 64, 256),  # MQA
    (2, 4, 4, 128, 48, 64),    # MLA-expanded layout (KV == H, qk dim 48)
])
def test_decode_attention_matches_ref(B, H, KV, S, D, bk):
    q, k, v = _decode_inputs(B, H, KV, S, D)
    for cl in (jnp.asarray(S * 3 // 4, jnp.int32),          # scalar
               jnp.asarray(np.random.default_rng(B).integers(1, S + 1, B),
                           jnp.int32)):                     # ragged [B]
        got = da.decode_attention(q, k, v, cl, block_k=bk, interpret=True)
        want = ref.decode_attention_ref(q, k, v, cl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [8, 64, 100])
def test_decode_attention_windowed_matches_ref(window):
    """Windowed/local masks ride the same per-row length logic: positions
    outside [len - window, len) never contribute."""
    B, H, KV, S, D = 3, 8, 2, 256, 32
    q, k, v = _decode_inputs(B, H, KV, S, D, seed=3)
    lens = jnp.asarray([S, S // 2, window + 1], jnp.int32)
    got = da.decode_attention(q, k, v, lens, window=window, block_k=64,
                              interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@given(cache_len=st.integers(min_value=1, max_value=256))
@settings(deadline=None, max_examples=10)
def test_decode_attention_cache_len_property(cache_len):
    """Positions >= cache_len never contribute."""
    B, H, KV, S, D = 1, 2, 2, 256, 32
    q, k, v = _decode_inputs(B, H, KV, S, D)
    cl = jnp.asarray(cache_len, jnp.int32)
    base = da.decode_attention(q, k, v, cl, block_k=64, interpret=True)
    k2 = k.at[:, cache_len:].set(7.0)
    v2 = v.at[:, cache_len:].set(-7.0)
    got = da.decode_attention(q, k2, v2, cl, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(got),
                               atol=1e-5, rtol=1e-5)


def test_decode_attention_rows_independent():
    """A [B] length vector must mask each row independently: row i's
    output equals a B=1 call at its own length."""
    B, H, KV, S, D = 4, 8, 2, 128, 32
    q, k, v = _decode_inputs(B, H, KV, S, D, seed=5)
    lens = jnp.asarray([1, 37, 64, 128], jnp.int32)
    got = da.decode_attention(q, k, v, lens, block_k=32, interpret=True)
    for i in range(B):
        solo = da.decode_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                   lens[i], block_k=32, interpret=True)
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(solo[0]),
                                   atol=1e-5, rtol=1e-5)


# -- paged (block-table gather) parity --------------------------------------

@pytest.mark.parametrize("B,H,KV,S,D,page", [
    (2, 8, 2, 256, 64, 64),    # GQA 4x
    (1, 4, 4, 128, 32, 32),    # MHA
    (4, 16, 1, 512, 64, 128),  # MQA
    (2, 4, 4, 128, 48, 32),    # MLA-expanded layout
])
def test_paged_decode_matches_ref(B, H, KV, S, D, page):
    q, kp, vp, bt, _ = _paged_inputs(B, H, KV, S, D, page, seed=7)
    lens = jnp.asarray(np.random.default_rng(B).integers(1, S + 1, B),
                       jnp.int32)
    got = da.decode_attention_paged(q, kp, vp, bt, lens, interpret=True)
    want = ref.decode_attention_paged_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_matches_contiguous():
    """A paged cache whose gathered view equals a contiguous cache must
    produce the contiguous kernel's output — including lengths that end
    exactly on, one past, and one before a page boundary."""
    B, H, KV, S, D, page = 3, 8, 2, 256, 32, 64
    q, kp, vp, bt, _ = _paged_inputs(B, H, KV, S, D, page, seed=9)
    mp = S // page
    k = kp[bt].reshape(B, S, KV, D)
    v = vp[bt].reshape(B, S, KV, D)
    for lens in ([page, 2 * page, 3 * page],        # exactly on boundaries
                 [page + 1, 2 * page - 1, S],       # straddling
                 [1, page // 2, S - 1]):
        cl = jnp.asarray(lens, jnp.int32)
        got = da.decode_attention_paged(q, kp, vp, bt, cl, interpret=True)
        want = da.decode_attention(q, k, v, cl, block_k=page, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


def test_paged_decode_sentinel_entries_ignored():
    """Unallocated logical pages carry a sentinel id (>= num_pages): any
    such page sits at or past the row's length and must not contribute,
    whatever garbage the clamped page holds."""
    B, H, KV, S, D, page = 2, 4, 2, 256, 32, 64
    q, kp, vp, bt, num_pages = _paged_inputs(B, H, KV, S, D, page, seed=11)
    lens = jnp.asarray([page, 2 * page], jnp.int32)
    base = da.decode_attention_paged(q, kp, vp, bt, lens, interpret=True)
    bt_s = np.array(bt)
    bt_s[0, 1:] = num_pages  # rows only keep their live-prefix pages
    bt_s[1, 2:] = num_pages
    got = da.decode_attention_paged(q, kp, vp, jnp.asarray(bt_s), lens,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(got),
                               atol=1e-5, rtol=1e-5)


def test_paged_decode_windowed_matches_ref():
    B, H, KV, S, D, page = 2, 8, 2, 256, 32, 64
    q, kp, vp, bt, _ = _paged_inputs(B, H, KV, S, D, page, seed=13)
    lens = jnp.asarray([S, S // 2 + 3], jnp.int32)
    got = da.decode_attention_paged(q, kp, vp, bt, lens, window=48,
                                    interpret=True)
    want = ref.decode_attention_paged_ref(q, kp, vp, bt, lens, window=48)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shape", [(32, 128), (4, 17, 256), (1, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.PRNGKey(3), (shape[-1],), dtype)
    got = rn.rmsnorm(x, w, block_rows=16, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@given(n=st.integers(100, 5000), p=st.sampled_from([4, 16, 64]))
@settings(deadline=None, max_examples=10)
def test_hash_partition_histogram_property(n, p):
    """Per-block histograms sum to the exact global histogram."""
    keys = jax.random.randint(jax.random.PRNGKey(n), (n,), 0, 10_000)
    hist = hp.hash_partition_histogram(keys, num_buckets=p, block=512,
                                       interpret=True)
    want = ref.hash_partition_histogram_ref(keys, num_buckets=p)
    np.testing.assert_array_equal(np.asarray(hist.sum(0)), np.asarray(want))
    assert int(hist.sum()) == n


def test_partition_order_bucket_contiguous():
    keys = jax.random.randint(KEY, (5000,), 0, 10_000)
    order, offsets = hp.partition_order(keys, 16, interpret=True)
    b = np.asarray((ref.hash_u32_ref(keys) % jnp.uint32(16)).astype(jnp.int32))
    assert np.all(np.diff(b[np.asarray(order)]) >= 0)
    assert offsets.shape == (16,)


def test_ops_dispatch_ref_path():
    """impl='ref' and impl='interpret' agree (CPU container has no TPU)."""
    q = jax.random.normal(KEY, (1, 4, 64, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 64, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 64, 32))
    a = ops.flash_attention(q, k, v, impl="ref")
    b = ops.flash_attention(q, k, v, impl="interpret", block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)
