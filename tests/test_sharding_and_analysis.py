"""Unit + property tests: sharding-rule derivation, padded-GQA search,
trip-count-aware HLO cost analysis (single-device compile)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.distributed import hlo_analysis as ha
from repro.distributed.sharding import merge_rules, spec_for


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


RULES = merge_rules()
MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_spec_basic_weight():
    s = spec_for(("embed", "mlp"), (2048, 5632), MESH, RULES)
    assert s == P("data", "model")


def test_spec_divisibility_fallback():
    # 10 kv heads don't divide the 16-way model axis -> replicated
    s = spec_for(("embed", "kv_heads", None), (2048, 10, 64), MESH, RULES)
    assert s == P("data")


def test_spec_axis_exclusivity():
    # two dims both wanting "model": first wins, second drops
    s = spec_for(("heads", "mlp"), (32, 64), MESH, RULES)
    assert s == P("model")


def test_spec_multi_axis_batch():
    s = spec_for(("act_batch", None), (256, 128), MESH3, RULES)
    assert s == P(("pod", "data"))
    # batch=1 (long_500k): everything falls back
    s1 = spec_for(("act_batch", None), (1, 128), MESH3, RULES)
    assert s1 == P()


@given(H=st.integers(1, 128), ratio=st.sampled_from([1, 2, 4, 7, 8]))
@settings(deadline=None, max_examples=40)
def test_padded_gqa_properties(H, ratio):
    if H % ratio:
        H = H * ratio
    KV = max(H // ratio // 1, 1)
    H = KV * ratio
    cfg = get_config("tinyllama-1.1b", smoke=True).with_overrides(
        num_heads=H, num_kv_heads=KV, head_pad_multiple=16, d_model=H * 16,
        head_dim=16,
    )
    Hp, KVp = cfg.padded_gqa()
    assert Hp % 16 == 0
    assert Hp >= H and KVp >= KV
    assert Hp % KVp == 0  # uniform groups
    assert Hp <= 2 * (H + 16 * ratio + 16)  # sane padding bound


def test_hlo_trip_count_flops():
    """scan body FLOPs must be multiplied by the trip count."""
    L, D = 8, 64

    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    xs = jax.ShapeDtypeStruct((16, D), jnp.float32)
    comp = jax.jit(f).lower(ws, xs).compile()
    res = ha.analyze(comp.as_text())
    expected_dot = 2 * 16 * D * D * L
    assert res["flops_per_device"] >= expected_dot
    assert res["flops_per_device"] < expected_dot * 2.5
    assert res["unknown_trip_loops"] == 0


def test_hlo_unrolled_matches_scan():
    D, L = 32, 6

    def scanned(ws, x):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    def unrolled(ws, x):
        for i in range(L):
            x = x @ ws[i]
        return x

    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    xs = jax.ShapeDtypeStruct((8, D), jnp.float32)
    a = ha.analyze(jax.jit(scanned).lower(ws, xs).compile().as_text())
    b = ha.analyze(jax.jit(unrolled).lower(ws, xs).compile().as_text())
    ratio = a["flops_per_device"] / max(b["flops_per_device"], 1)
    assert 0.7 < ratio < 1.5, (a["flops_per_device"], b["flops_per_device"])


def test_shape_parsing():
    assert ha._shape_bytes("f32[16,256]{1,0}") == 16 * 256 * 4
    assert ha._shape_bytes("(s32[], bf16[8,8]{1,0})") == 4 + 128
    assert ha._shape_elems("pred[2,3]") == 6


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_matches_assignment(arch):
    """Full configs carry the exact published dimensions."""
    cfg = get_config(arch)
    expect = {
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect, f"{arch}: {got} != {expect}"
