"""Shared fixtures.  NOTE: the host-device count is NOT forced here — smoke
tests and benches see the container's single CPU device.  Tests that need a
multi-device mesh (dataframe collectives, elastic FT, HLO SPMD analysis)
run their body in a subprocess with XLA_FLAGS set (see tests/spawn/)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SPAWN = os.path.join(REPO, "tests", "spawn")


def run_spawned(script_name: str, devices: int = 8, timeout: int = 600):
    """Run tests/spawn/<script>.py with N host devices; assert success."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(SPAWN, script_name)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"spawned {script_name} failed:\n{r.stdout[-3000:]}\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.fixture(scope="session")
def spawned():
    return run_spawned
