"""Shared fixtures.  NOTE: the host-device count is NOT forced here — smoke
tests and benches see the container's single CPU device.  Tests that need a
multi-device mesh (dataframe collectives, elastic FT, HLO SPMD analysis)
run their body in a subprocess with XLA_FLAGS set (see tests/spawn/)."""
import importlib.util
import os
import subprocess
import sys
import types

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SPAWN = os.path.join(REPO, "tests", "spawn")

# -- hypothesis fallback ------------------------------------------------------
# Several modules do `from hypothesis import given, settings, strategies`.
# The dependency is declared in pyproject.toml ([dev]), but collection must
# never hard-fail on a bare environment: install a conftest-level stub that
# turns every @given property test into a pytest skip while leaving the rest
# of the module runnable.  (pytest.importorskip at module level would skip
# the whole module, losing the non-property tests.)
if importlib.util.find_spec("hypothesis") is None:
    def _skip_given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[dev])")(fn)
        return deco

    def _passthrough(*_a, **_k):
        return lambda fn: fn

    class _Strategy:
        """Inert placeholder accepted at @given decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.__getattr__ = lambda name: lambda *a, **k: _Strategy()

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _skip_given
    _hyp.settings = _passthrough
    _hyp.strategies = _strategies
    _hyp.__stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _strategies


def run_spawned(script_name: str, devices: int = 8, timeout: int = 600):
    """Run tests/spawn/<script>.py with N host devices; assert success."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(SPAWN, script_name)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"spawned {script_name} failed:\n{r.stdout[-3000:]}\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.fixture(scope="session")
def spawned():
    return run_spawned
