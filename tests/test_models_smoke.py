"""Per-arch smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs; one decode
step against a fresh cache (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.params import init_params, param_count
from repro.configs import ARCHS, get_config
from repro.configs.base import RunConfig
from repro.train.state import cache_specs, model_specs
from repro.train.step import make_decode_step, make_loss_fn

KEY = jax.random.PRNGKey(0)
RUN = RunConfig(num_microbatches=1)


def _batch(cfg, B, S):
    if cfg.is_encoder_decoder:
        dec = max(S // cfg.dec_len_ratio, 8)
        return {
            "frames": jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32),
            "tokens": jnp.ones((B, dec), jnp.int32),
            "labels": jnp.ones((B, dec), jnp.int32),
        }
    if cfg.input_kind == "embeds":
        batch = {
            "embeds": jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32),
            "labels": jnp.ones((B, S), jnp.int32),
        }
        if cfg.mrope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(S)[None, :, None], (B, S, 3)
            ).astype(jnp.int32)
        return batch
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(KEY, model_specs(cfg))
    assert param_count(model_specs(cfg)) > 0
    batch = _batch(cfg, B=2, S=32)
    loss_fn = make_loss_fn(cfg, RUN)
    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gn = float(jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                            for g in jax.tree.leaves(grads))))
    assert np.isfinite(gn) and gn > 0, f"{arch}: bad grad norm {gn}"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(KEY, model_specs(cfg))
    B = 2
    cache = init_params(KEY, cache_specs(cfg, B, 64))
    step = make_decode_step(cfg, RUN)
    nt, logits, new_cache = jax.jit(step)(
        params, jnp.ones((B, 1), jnp.int32), cache, jnp.asarray(8, jnp.int32)
    )
    assert nt.shape == (B,)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.padded_vocab
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # cache structure preserved
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_train_step_improves_loss():
    """Three optimizer steps on repeated data reduce the loss (tinyllama)."""
    from repro.train.state import init_train_state
    from repro.train.step import make_train_step

    cfg = get_config("tinyllama-1.1b", smoke=True)
    run = RunConfig(num_microbatches=2, learning_rate=1e-2)
    state = init_train_state(KEY, cfg, run)
    batch = {
        "tokens": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size),
    }
    step = jax.jit(make_train_step(cfg, run))
    losses = []
    for _ in range(4):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
