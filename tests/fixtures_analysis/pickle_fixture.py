"""Seeded picklable-task-contract violations.

``inner_stage`` (nested ``@stage``) and the ``fn=lambda`` TaskDescription
must be flagged; ``top_stage`` (module level), ``pinned_stage`` (nested
but carrying the ``noqa: PKL001`` in-process marker) and the marked
lambda must not be.
"""


def stage(**kw):
    def wrap(f):
        return f
    return wrap


def TaskDescription(**kw):  # noqa: N802 — mirrors the real ctor name
    return kw


@stage(kind="generic")
def top_stage(ctx):
    return 1


def build_pipeline():
    captured = 2

    @stage(kind="generic")
    def inner_stage(ctx):  # SEEDED VIOLATION: nested @stage, closure
        return captured

    @stage(kind="generic")
    def pinned_stage(ctx):  # noqa: PKL001 — fixture pins in-process
        return captured

    return inner_stage, pinned_stage


def submit_tasks():
    bad = TaskDescription(name="bad", fn=lambda comm: 1)  # SEEDED VIOLATION
    ok = TaskDescription(name="ok",
                         fn=lambda comm: 1)  # noqa: PKL001 — in-process only
    return bad, ok
