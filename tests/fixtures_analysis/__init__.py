"""Fixture modules with deliberately seeded violations.

These files are inputs for the analyzer tests in
``tests/test_analysis_passes.py`` — they are parsed (never executed) by
the static passes, and each one carries exactly the violations its test
asserts on.  They are NOT scanned by ``python -m repro.analysis`` (which
only walks ``src/repro``), so the seeded findings never dirty the repo
baseline.
"""
