"""Seeded jit-boundary violations (parsed, never executed).

Expected findings (asserted exactly in test_analysis_passes.py):

* ``time.time()`` under jit (host-sync);
* ``if y > 0`` — Python branch on a traced value (traced-branch);
* ``float(y)`` — host cast of a traced value (host-sync);
* ``leaky_step(x, scale=[...])`` — list display fed to a
  ``static_argnames`` parameter (static-unhashable).

``clean_step`` exercises the exemptions the pass must honour: shape
attributes, ``is None`` tests, and closure config are all static.
"""
import functools
import time

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("scale",))
def leaky_step(x, scale):
    t0 = time.time()  # SEEDED VIOLATION: wall clock inside jit
    y = jnp.sum(x) * scale
    if y > 0:  # SEEDED VIOLATION: Python branch on a traced value
        y = y + 1.0
    peek = float(y)  # SEEDED VIOLATION: host cast of a traced value
    return y, t0, peek


@functools.partial(jax.jit, static_argnames=("bias",))
def clean_step(x, mask=None, bias=0.0):
    if mask is not None:  # static: identity test
        x = jnp.where(mask, x, 0.0)
    if x.ndim > 1:  # static: shape-derived
        x = x.reshape(-1)
    return x * bias


def caller(x):
    return leaky_step(x, scale=[1, 2])  # SEEDED VIOLATION: unhashable static
