"""Seeded broad-except violation.

``risky`` must be flagged; ``isolated`` carries the ``noqa: BLE001``
boundary marker and must not be.
"""


def risky():
    try:
        return 1 // 0
    except Exception:  # SEEDED VIOLATION: broad handler, no boundary marker
        return None


def isolated():
    try:
        return 1 // 0
    except Exception:  # noqa: BLE001 - fixture isolation boundary
        return None
