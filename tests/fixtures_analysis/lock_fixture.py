"""Seeded lock-discipline violations (guarded-attr escapes).

Expected findings (asserted exactly in test_analysis_passes.py):

* ``Counter.value`` read in ``peek`` without the lock;
* ``Counter.history`` captured by a closure that outlives the ``with``
  block in ``escape``.

Everything else is a clean pattern the pass must NOT flag: locked
access, ``*_locked`` helpers, ``# caller-locked`` methods, ``__init__``.
"""
import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0  # guarded-by: _lock
        self.history = []  # guarded-by: _lock

    def bump(self):
        with self._lock:
            self.value += 1
            self.history.append(self.value)

    def peek(self):
        return self.value  # SEEDED VIOLATION: unlocked read

    def escape(self):
        with self._lock:
            def snapshot():
                # SEEDED VIOLATION: the closure runs after the with block
                # exits, so the lock is no longer held
                return list(self.history)
            return snapshot

    def _total_locked(self):
        return sum(self.history)

    def audited(self):  # caller-locked
        return len(self.history)
