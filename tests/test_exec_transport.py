"""Cross-process worker transport (PR 9): RPC round-trip, the
picklable-task contract, fault detection (SIGKILL / hang), respawn,
checkpoint-aware retry through the agent, Session pipelines and
ServeEngine service stages equal to in-process, and the fleet KV-page
handoff crossing a real process boundary bitwise.

Every task fn here is module-level: pytest puts ``tests/`` on
``sys.path`` and the workers inherit it through the transport's
PYTHONPATH propagation, so the fns resolve by qualified name in the
worker interpreter.
"""
import os
import signal
import time

import numpy as np
import pytest

from repro.checkpoint import store
from repro.core import Session, stage
from repro.core.agent import RemoteAgent
from repro.core.exec import (
    JaxDistributedTransport,
    RemoteTaskError,
    SubprocessTransport,
    WorkerCrashed,
    ensure_picklable,
)
from repro.core.exec.pickling import check_roundtrip
from repro.core.pilot import PilotDescription, PilotManager
from repro.core.task import TaskDescription, TaskState
from repro.serve import Request


# ---------------------------------------------------------------------------
# module-level task fns (the picklable contract)
# ---------------------------------------------------------------------------


def echo(x):
    return x


def double(comm, x):
    return x * 2


def boom():
    raise ValueError("worker-side failure")


def die(comm=None):
    os.kill(os.getpid(), signal.SIGKILL)


def sleep_for(seconds):
    time.sleep(seconds)
    return seconds


def train_then_die(comm, ckpt_dir, resume_step=None):
    """First attempt: checkpoint step 7 then kill own worker (simulated
    node death).  Retry: report the step the agent threaded back in."""
    if resume_step is None:
        store.save(ckpt_dir, 7, {"w": np.zeros(2, np.float32)})
        os.kill(os.getpid(), signal.SIGKILL)
    return ("resumed", resume_step)


@stage(kind="data_engineering", name="make")
def make_stage(ctx):
    return np.arange(8, dtype=np.float32)


@stage(kind="train", name="square")
def square_stage(ctx):
    x = ctx.upstream["make"]
    return float((x * x).sum())


@stage(kind="inference", service=True, name="engine")
def engine_stage(ctx, max_slots=2, max_len=24):
    from repro.configs import get_config
    from repro.configs.base import RunConfig
    from repro.serve import ServeEngine
    cfg = get_config("tinyllama-1.1b", smoke=True)
    engine = ServeEngine(cfg, RunConfig(), max_slots=max_slots,
                         max_len=max_len, seed=0)
    return engine.run_service(ctx.control, resume_state=ctx.resume_state)


# ---------------------------------------------------------------------------
# RPC round-trip + wire fidelity
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pool():
    t = SubprocessTransport(max_workers=2, worker_devices=1)
    yield t
    t.shutdown(wait=False)


def test_submit_roundtrip(pool):
    futs = [pool.submit(echo, i) for i in range(8)]
    assert [f.result(timeout=120) for f in futs] == list(range(8))


def test_numpy_crosses_bitwise(pool):
    a = np.random.default_rng(0).normal(size=(16, 8)).astype(np.float32)
    out = pool.submit(echo, a).result(timeout=120)
    np.testing.assert_array_equal(out, a)
    assert check_roundtrip(a).tobytes() == a.tobytes()


def test_remote_exception_is_typed(pool):
    with pytest.raises(RemoteTaskError) as ei:
        pool.submit(boom).result(timeout=120)
    assert ei.value.remote_type == "ValueError"
    assert "worker-side failure" in str(ei.value)
    assert "boom" in ei.value.remote_traceback


def test_unpicklable_fn_rejected_at_submit(pool):
    with pytest.raises(TypeError, match="picklable-task contract"):
        pool.submit(lambda: 1)
    with pytest.raises(TypeError, match="picklable-task contract"):
        pool.submit(pool.shutdown)  # bound method of a live instance

    captured = 3

    def nested():
        return captured

    ensure_picklable(echo)  # module-level fn: fine
    with pytest.raises(TypeError, match="nested function"):
        ensure_picklable(nested)


def test_unpicklable_argument_names_the_leaf(pool):
    import threading
    with pytest.raises(TypeError, match=r"args\[0\]\['ev'\]"):
        pool.submit(echo, {"ev": threading.Event()})


# ---------------------------------------------------------------------------
# fault detection
# ---------------------------------------------------------------------------


def test_sigkill_surfaces_promptly_and_worker_respawns():
    t = SubprocessTransport(max_workers=1, worker_devices=1)
    try:
        assert t.submit(echo, 1).result(timeout=120) == 1
        t0 = time.time()
        with pytest.raises(WorkerCrashed, match="died while running"):
            t.submit(die).result(timeout=30)
        assert time.time() - t0 < 10.0, "crash detection too slow"
        # the pool respawned: the next task runs on a fresh worker
        assert t.submit(echo, 2).result(timeout=120) == 2
    finally:
        t.shutdown(wait=False)


def test_hung_worker_caught_by_heartbeat_backstop():
    """SIGSTOP freezes the worker without closing its socket or exiting
    the process — only the heartbeat-age path can catch it."""
    t = SubprocessTransport(max_workers=1, worker_devices=1,
                            heartbeat_s=0.1, heartbeat_timeout_s=1.0)
    try:
        # prove the worker is up first: freezing it mid-boot would land on
        # the (long) start-timeout path instead of the heartbeat backstop
        assert t.submit(echo, 0).result(timeout=120) == 0
        fut = t.submit(sleep_for, 60)
        time.sleep(0.3)  # let the task land on the worker
        (pid,) = t.worker_pids()
        os.kill(pid, signal.SIGSTOP)
        with pytest.raises(WorkerCrashed, match="heartbeat"):
            fut.result(timeout=30)
    finally:
        t.shutdown(wait=False)


def test_shutdown_no_wait_reaps_all_workers():
    t = SubprocessTransport(max_workers=2, worker_devices=1)
    t.submit(echo, 1).result(timeout=120)
    pids = t.worker_pids()
    assert len(pids) == 2
    t.shutdown(wait=False)
    deadline = time.time() + 10
    while time.time() < deadline:
        alive = [p for p in pids if _pid_alive(p)]
        if not alive:
            return
        time.sleep(0.05)
    raise AssertionError(f"orphaned workers after shutdown: {alive}")


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    # zombie counts as reaped-in-progress: ask the kernel for state
    try:
        with open(f"/proc/{pid}/stat") as f:
            return f.read().split()[2] != "Z"
    except OSError:
        return False


# ---------------------------------------------------------------------------
# agent integration: checkpoint-aware retry across a worker death
# ---------------------------------------------------------------------------


def test_agent_retries_dead_worker_task_from_checkpoint(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    transport = SubprocessTransport(max_workers=1, worker_devices=1)
    pm = PilotManager()
    pilot = pm.submit_pilot(PilotDescription(num_devices=1))
    agent = RemoteAgent(pilot, transport=transport)
    try:
        task, = agent.submit([TaskDescription(
            name="train", fn=train_then_die, args=(ckpt,),
            checkpoint_dir=ckpt, max_retries=2, group="g")])
        assert task.state == TaskState.DONE, task.error
        assert task.result == ("resumed", 7)
        assert task.attempts == 2
        assert agent.quota_violations() == {}
        assert pilot.free_count() == 1, "lease leaked across worker death"
    finally:
        agent.close()
        transport.shutdown(wait=False)


# ---------------------------------------------------------------------------
# retired stub: JaxDistributedTransport is now the subprocess pool
# ---------------------------------------------------------------------------


def test_jax_distributed_single_host_executes():
    t = JaxDistributedTransport(num_processes=1, process_id=0)
    try:
        assert t.name == "jax-distributed"
        assert t.submit(echo, 41).result(timeout=120) == 41
    finally:
        t.shutdown(wait=False)


# ---------------------------------------------------------------------------
# Session: pipeline + service stage end-to-end over subprocess workers
# ---------------------------------------------------------------------------


def _run_pipeline(transport_spec):
    with Session(pods=[PilotDescription(num_devices=1)],
                 max_workers_per_pilot=1, transport=transport_spec,
                 transport_options={"worker_devices": 1}) as s:
        return s.run(make_stage >> square_stage, name="p")


def test_session_pipeline_matches_in_process():
    got_sub = _run_pipeline("subprocess")
    got_in = _run_pipeline("in-process")
    assert got_sub["square"] == got_in["square"] == 140.0
    np.testing.assert_array_equal(got_sub["make"], got_in["make"])


def _run_service(transport_spec):
    with Session(pods=[PilotDescription(num_devices=1)],
                 max_workers_per_pilot=1, transport=transport_spec,
                 transport_options={"worker_devices": 1}) as s:
        handle = s.serve(engine_stage, name="svc")
        rng = np.random.default_rng(5)
        reqs = [handle.submit_request(
            Request(rng.integers(1, 64, 8), max_new_tokens=6))
            for _ in range(3)]
        deadline = time.time() + 300
        for r in reqs:
            while not r.wait(1.0):
                task = handle.task
                if task is not None and task.finalized and task.error:
                    raise AssertionError(f"service failed: {task.error}")
                assert time.time() < deadline, f"{r.rid} stalled: {r.tokens}"
        assert handle.stop(drain=True, timeout=60)
        return [list(r.tokens) for r in reqs]


def test_service_stage_streams_match_in_process():
    toks_sub = _run_service("subprocess")
    toks_in = _run_service("in-process")
    assert toks_sub == toks_in
    assert all(len(t) == 6 for t in toks_sub)


# ---------------------------------------------------------------------------
# fleet: KV-page handoff round-trips bitwise across the process boundary
# ---------------------------------------------------------------------------


def _run_fleet(transport):
    from repro.configs import get_config
    from repro.configs.base import RunConfig
    from repro.serve import build_fleet

    cfg = get_config("tinyllama-1.1b", smoke=True)
    kw = {"transport": transport} if transport is not None else {}
    router = build_fleet(cfg, RunConfig(), num_engines=2, disaggregate=True,
                         seed=0, max_slots=2, max_len=24,
                         router_kwargs=kw, name_prefix="t")
    router.start()
    try:
        rng = np.random.default_rng(3)
        reqs = [router.submit(Request(rng.integers(1, 64, 8),
                                      max_new_tokens=6))
                for _ in range(3)]
        deadline = time.time() + 300
        for r in reqs:
            while not r.wait(1.0):
                assert time.time() < deadline, f"{r.rid} stalled: {r.tokens}"
        return [list(r.tokens) for r in reqs], router.stats()
    finally:
        router.close()


def test_fleet_handoff_roundtrips_bitwise_across_processes():
    transport = SubprocessTransport(max_workers=1, worker_devices=1)
    try:
        toks_sub, stats_sub = _run_fleet(transport)
    finally:
        transport.shutdown(wait=False)
    toks_in, stats_in = _run_fleet(None)
    # every prefill->decode migration crossed a real process boundary on
    # the subprocess run, and the decoded streams are identical token for
    # token — the page bytes round-tripped bitwise
    assert stats_sub["handoffs_routed"] >= 1
    assert stats_sub["handoff_wire_roundtrips"] == stats_sub["handoffs_routed"]
    assert stats_in.get("handoff_wire_roundtrips", 0) == 0
    assert toks_sub == toks_in
