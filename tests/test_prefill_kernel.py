"""Ragged cache-writing prefill kernel: parity sweeps vs the jnp oracles
(interpret mode), chunk-offset equivalence, paged-vs-contiguous equality,
and the flash-attention ragged-tail regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as fa
from repro.kernels import prefill_attention as pa
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _inputs(B, T, H, KV, D, S, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k_new = jax.random.normal(ks[1], (B, T, KV, D))
    v_new = jax.random.normal(ks[2], (B, T, KV, D))
    k_cache = jax.random.normal(ks[3], (B, S, KV, D))
    v_cache = jax.random.normal(ks[4], (B, S, KV, D))
    return q, k_new, v_new, k_cache, v_cache


@pytest.mark.parametrize("H,KV", [(4, 4), (8, 2), (4, 1)])  # MHA/GQA/MQA
def test_prefill_matches_ref_ragged(H, KV):
    B, T, D, S = 3, 8, 32, 64
    q, kn, vn, kc, vc = _inputs(B, T, H, KV, D, S)
    base = jnp.array([0, 5, 13], jnp.int32)
    clens = jnp.array([8, 3, 0], jnp.int32)  # full / partial / inert row
    got, gkc, gvc = pa.prefill_attention(
        q, kn, vn, kc, vc, base, clens, block_q=8, block_k=16,
        interpret=True)
    want, wkc, wvc = ref.prefill_attention_ref(q, kn, vn, kc, vc, base,
                                               clens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # cache writes are a masked scatter of the same values: exact
    np.testing.assert_array_equal(np.asarray(gkc), np.asarray(wkc))
    np.testing.assert_array_equal(np.asarray(gvc), np.asarray(wvc))


def test_prefill_padding_rows_exact_zero():
    B, T, H, KV, D, S = 2, 8, 4, 2, 32, 32
    q, kn, vn, kc, vc = _inputs(B, T, H, KV, D, S)
    clens = jnp.array([5, 0], jnp.int32)
    out, _, _ = pa.prefill_attention(
        q, kn, vn, kc, vc, jnp.array([0, 7], jnp.int32), clens,
        block_q=8, block_k=16, interpret=True)
    out = np.asarray(out)
    assert (out[0, 5:] == 0.0).all() and (out[1] == 0.0).all()
    assert np.isfinite(out).all()


def test_prefill_chunked_equals_one_shot():
    """Two chunks at offsets 0 and T1 == one whole-prompt pass."""
    B, T, H, KV, D, S = 2, 8, 4, 2, 32, 64
    T1 = 4
    q, kn, vn, kc, vc = _inputs(B, T, H, KV, D, S)
    full = jnp.full((B,), T, jnp.int32)
    zero = jnp.zeros((B,), jnp.int32)
    o_all, kc_all, vc_all = pa.prefill_attention(
        q, kn, vn, kc, vc, zero, full, block_q=4, block_k=16,
        interpret=True)
    o1, kc1, vc1 = pa.prefill_attention(
        q[:, :T1], kn[:, :T1], vn[:, :T1], kc, vc, zero,
        jnp.full((B,), T1, jnp.int32), block_q=4, block_k=16,
        interpret=True)
    o2, kc2, vc2 = pa.prefill_attention(
        q[:, T1:], kn[:, T1:], vn[:, T1:], kc1, vc1,
        jnp.full((B,), T1, jnp.int32), jnp.full((B,), T - T1, jnp.int32),
        block_q=4, block_k=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(kc2), np.asarray(kc_all))
    np.testing.assert_array_equal(np.asarray(vc2), np.asarray(vc_all))
    got = np.concatenate([np.asarray(o1), np.asarray(o2)], axis=1)
    np.testing.assert_allclose(got, np.asarray(o_all), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("H,KV", [(4, 4), (8, 2), (4, 1)])
def test_prefill_paged_matches_ref(H, KV):
    B, T, D = 3, 8, 32
    page, max_pages, num_pages = 16, 4, 16
    q, kn, vn, _, _ = _inputs(B, T, H, KV, D, 1)
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    kp = jax.random.normal(ks[0], (num_pages, page, KV, D))
    vp = jax.random.normal(ks[1], (num_pages, page, KV, D))
    # scrambled physical pages + sentinel (unallocated) tail entries
    bt = jnp.array([[5, 9, 2, num_pages],
                    [0, 7, num_pages, num_pages],
                    [11, 3, 8, 1]], jnp.int32)
    base = jnp.array([0, 5, 13], jnp.int32)
    clens = jnp.array([8, 3, 0], jnp.int32)
    got, gkp, gvp = pa.prefill_attention_paged(
        q, kn, vn, kp, vp, bt, base, clens, block_q=8, interpret=True)
    want, wkp, wvp = ref.prefill_attention_paged_ref(
        q, kn, vn, kp, vp, bt, base, clens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_array_equal(np.asarray(gkp), np.asarray(wkp))
    np.testing.assert_array_equal(np.asarray(gvp), np.asarray(wvp))


def test_prefill_paged_equals_contiguous():
    """An identity-mapped page pool IS a contiguous cache: both layouts
    must produce bitwise-identical outputs (f32 path)."""
    B, T, H, KV, D = 2, 8, 4, 2, 32
    page, max_pages = 16, 3
    S = page * max_pages
    q, kn, vn, kc, vc = _inputs(B, T, H, KV, D, S)
    bt = jnp.arange(B * max_pages, dtype=jnp.int32).reshape(B, max_pages)
    kp = kc.reshape(B * max_pages, page, KV, D)
    vp = vc.reshape(B * max_pages, page, KV, D)
    base = jnp.array([0, 17], jnp.int32)
    clens = jnp.array([8, 6], jnp.int32)
    oc, kcc, _ = pa.prefill_attention(q, kn, vn, kc, vc, base, clens,
                                      block_q=8, block_k=16,
                                      interpret=True)
    op, kpp, _ = pa.prefill_attention_paged(q, kn, vn, kp, vp, bt, base,
                                            clens, block_q=8,
                                            interpret=True)
    np.testing.assert_array_equal(np.asarray(op), np.asarray(oc))
    np.testing.assert_array_equal(
        np.asarray(kpp).reshape(B, S, KV, D), np.asarray(kcc))


def test_prefill_ops_dispatch():
    """ops.prefill_attention impl= routing: ref and interpret agree."""
    B, T, H, KV, D, S = 2, 4, 4, 2, 32, 32
    q, kn, vn, kc, vc = _inputs(B, T, H, KV, D, S)
    base = jnp.array([0, 9], jnp.int32)
    clens = jnp.array([4, 2], jnp.int32)
    o_ref, krf, _ = ops.prefill_attention(q, kn, vn, kc, vc, base, clens,
                                          impl="ref")
    o_int, kin, _ = ops.prefill_attention(q, kn, vn, kc, vc, base, clens,
                                          impl="interpret")
    np.testing.assert_allclose(np.asarray(o_int), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_array_equal(np.asarray(kin), np.asarray(krf))


def test_flash_attention_ragged_tail():
    """Regression: S not a multiple of the block no longer silently
    truncates trailing queries/keys (old grid was S // block_q)."""
    B, H, S, D = 1, 4, 130, 64
    q = jax.random.normal(KEY, (B, H, S, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D))
    for causal in (True, False):
        got = fa.flash_attention(q, k, v, causal=causal, block_q=64,
                                 block_k=64, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        assert got.shape == (B, H, S, D)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)
