"""Resilience-layer chaos suite (tentpole PR): deterministic fault
injection, unified FailurePolicy, and crash-consistent checkpoints.

Covers the failure modes the runtime claims to survive, each driven by a
seeded :class:`FaultPlan` so the schedule is reproducible:

- same seed => same logical fault-event trace, run twice (fleet chaos);
- a checkpoint torn mid-write restores the *prior* step bitwise;
- the router's circuit breaker ejects a crashed engine, serves around
  it, and re-admits it after a probationary probe — with zero lost and
  zero duplicated requests, token streams bitwise-equal to an
  undisturbed run (f32 compute, like tests/test_fleet.py);
- an end-to-end deadline expiry fails *cleanly*: devices recycled back
  to the pilot, zero quota violations;
- a killed worker respawns with policy-driven backoff recorded in the
  transport's respawn stats.

FailurePolicy/CircuitBreaker unit tests pin the deterministic-jitter
backoff schedule and the closed -> open -> half_open -> closed state
machine the system tests rely on.
"""
import dataclasses
import time
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (
    CheckpointCorrupt, latest_step, restore, save, verify_step,
)
from repro.common.params import init_params
from repro.configs import get_config
from repro.core.exec.transport import SubprocessTransport, WorkerCrashed
from repro.core.pilot import Pilot
from repro.core.resilience import (
    CircuitBreaker, FailurePolicy, FaultPlan, inject, set_fault_injector,
)
from repro.core.task import TaskDescription, TaskState
from repro.serve import Request, RequestState, ServeEngine, build_fleet
from repro.train.state import model_specs

CFG = get_config("tinyllama-1.1b", smoke=True)
CFG32 = dataclasses.replace(CFG, compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), model_specs(CFG))


# ---------------------------------------------------------------------------
# module-level task fns (picklable-task contract, as in test_exec_transport)
# ---------------------------------------------------------------------------


def add_one(x):
    return x + 1


# ---------------------------------------------------------------------------
# FailurePolicy: deterministic backoff, retry budget, deadlines
# ---------------------------------------------------------------------------


def test_backoff_deterministic_and_bounded():
    pol = FailurePolicy(backoff_base_s=0.5, backoff_factor=2.0,
                        backoff_max_s=3.0, jitter=0.1)
    # same (attempt, key) -> identical delay, run-to-run
    assert pol.backoff_s(1, key="t1") == pol.backoff_s(1, key="t1")
    assert pol.backoff_s(2, key="t1") == pol.backoff_s(2, key="t1")
    # different keys de-synchronize (thundering-herd jitter)
    assert pol.backoff_s(1, key="t1") != pol.backoff_s(1, key="t2")
    # exponential envelope with bounded jitter, capped at backoff_max_s
    for attempt, base in ((1, 0.5), (2, 1.0), (3, 2.0)):
        d = pol.backoff_s(attempt, key="k")
        assert base <= d <= base * 1.1 + 1e-9, (attempt, d)
    assert pol.backoff_s(9, key="k") <= 3.0 * 1.1 + 1e-9


def test_retry_budget_and_deadline_arithmetic():
    pol = FailurePolicy(max_retries=2, deadline_s=10.0)
    # attempts consumed: 1 (first) + 2 retries
    assert pol.allow_retry(1) and pol.allow_retry(2)
    assert not pol.allow_retry(3)
    assert pol.deadline_at(100.0) == 110.0
    assert FailurePolicy(deadline_s=None).deadline_at(100.0) is None


def test_circuit_breaker_state_machine():
    br = CircuitBreaker(eject_after=2, probation_s=0.05)
    assert br.state == "closed"
    assert not br.record_fault()          # streak 1: still closed
    assert br.record_fault()              # streak 2: ejected
    assert br.state == "open"
    assert not br.admit()                 # probation not elapsed
    time.sleep(0.06)
    assert br.admit()                     # the single probe
    assert br.state == "half_open"
    assert not br.admit()                 # probe already in flight
    br.record_fault()                     # probe failed: re-open
    assert br.state == "open"
    time.sleep(0.06)
    assert br.admit()
    br.record_success()                   # probe succeeded: re-admitted
    assert br.state == "closed"
    assert br.snapshot()["consecutive_faults"] == 0
    assert [state for state, _ in br.transitions] == \
        ["open", "half_open", "open", "half_open", "closed"]


# ---------------------------------------------------------------------------
# FaultPlan: seeded, serializable, reproducible trace
# ---------------------------------------------------------------------------


def test_fault_plan_roundtrip_and_injector_determinism():
    plan = (FaultPlan(seed=5)
            .crash_worker(worker=1, at_task=2)
            .drop_reply(nth=3)
            .tear_checkpoint(at_byte=64, step=7))
    assert FaultPlan.from_json(plan.to_json()).to_json() == plan.to_json()

    def drive(inj):
        fired = []
        for task in range(1, 4):
            for worker in range(2):
                fired.append(inj.fire("transport.dispatch",
                                      worker=worker, task=task))
        for n in range(4):
            fired.append(inj.fire("protocol.recv", mtype="result", frame=n))
        fired.append(inj.fire("checkpoint.save", step=7))
        return fired

    a, b = plan.injector(), plan.injector()
    assert drive(a) == drive(b)
    assert a.trace() == b.trace()
    assert a.all_fired() and not a.pending()
    # each spec fires exactly once, at its logical coordinate
    assert [(e[1], e[2]) for e in a.trace()] == [
        ("transport.dispatch", "crash_worker"),
        ("protocol.recv", "drop"),
        ("checkpoint.save", "tear"),
    ]


# ---------------------------------------------------------------------------
# crash-consistent checkpoints: torn write -> prior step restored bitwise
# ---------------------------------------------------------------------------


def test_torn_checkpoint_restores_prior_step_bitwise(tmp_path):
    d = str(tmp_path)
    rng = np.random.default_rng(3)
    state1 = {"w": jnp.asarray(rng.normal(size=(16,)).astype(np.float32)),
              "step": jnp.asarray(1)}
    state2 = {"w": jnp.asarray(rng.normal(size=(16,)).astype(np.float32)),
              "step": jnp.asarray(2)}
    save(d, 1, state1)
    # a crash mid-write of step 2, after the rename made it visible
    with inject(FaultPlan(seed=0).tear_checkpoint(at_byte=48, step=2)) as inj:
        save(d, 2, state2)
        assert inj.all_fired()
    assert verify_step(d, 1)
    assert not verify_step(d, 2)
    assert latest_step(d, verify=False) == 2     # naive scan would load it
    with pytest.warns(RuntimeWarning):
        assert latest_step(d, verify=True) == 1  # verified recovery skips it
    with pytest.raises(CheckpointCorrupt):
        restore(d, state1, step=2)
    with pytest.warns(RuntimeWarning):
        got = restore(d, state1)
    assert int(got["step"]) == 1
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(state1["w"]))


# ---------------------------------------------------------------------------
# fleet chaos: breaker eject/probation/re-admit, reproducible trace,
# zero lost, zero duplicated, bitwise-equal streams
# ---------------------------------------------------------------------------


def _chaos_fleet_run(params, prompts, gen, plan):
    """One fleet run under the plan: engine eng0 crashes mid-decode, is
    ejected (eject_after=1), probed after probation, re-admitted."""
    policy = FailurePolicy(eject_after=1, probation_s=0.2)
    router = build_fleet(CFG32, num_engines=2, params=params, max_slots=2,
                         max_len=96, page_size=16, name_prefix="flt",
                         router_kwargs={"policy": policy})
    inj = plan.injector()
    set_fault_injector(inj)
    try:
        with router:
            reqs = [router.submit(Request(p, max_new_tokens=gen))
                    for p in prompts]
            assert router.drain(timeout=300)
            # the probationary probe is a real request: feed tiny ones
            # until the ejected engine has been re-admitted
            rng = np.random.default_rng(123)
            deadline = time.time() + 30
            while time.time() < deadline:
                st = router.stats()
                if st.get("readmissions", 0) >= st.get("ejections", 0):
                    break
                router.submit(Request(
                    rng.integers(1, CFG.vocab_size, 4).astype(np.int32),
                    max_new_tokens=2))
                router.drain(timeout=60)
                time.sleep(0.02)
            stats = router.stats()
    finally:
        set_fault_injector(None)
    return reqs, stats, inj.trace()


def test_breaker_ejects_probes_and_readmits_bitwise(params):
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, CFG.vocab_size, int(n)).astype(np.int32)
               for n in (6, 9, 5, 8, 7, 10)]
    gen = 10
    # undisturbed single-engine reference (greedy, f32: bitwise target)
    eng = ServeEngine(CFG32, params=params, max_slots=2, max_len=96,
                      page_size=16)
    ref = [eng.submit(Request(p, max_new_tokens=gen)) for p in prompts]
    eng.run_until_drained()

    def plan():
        return FaultPlan(seed=7).crash_engine(engine="flt.eng0", at_step=3)

    reqs, st, trace = _chaos_fleet_run(params, prompts, gen, plan())

    # zero lost, zero duplicated: every request terminal exactly once,
    # with exactly the requested number of tokens (a duplicated or
    # re-run-without-reset request would double-append)
    assert all(r.state is RequestState.DONE for r in reqs)
    assert [len(r.tokens) for r in reqs] == [gen] * len(reqs)
    # recovery is invisible in the streams: bitwise-equal to undisturbed
    assert [r.tokens for r in reqs] == [r.tokens for r in ref]

    assert st["engine_crashes"] == 1
    assert st["ejections"] == 1 and st["readmissions"] == 1
    assert st["requests_recovered"] >= 1
    assert st["recoveries"] and st["recoveries"][0]["engine"] == "flt.eng0"
    assert st["recoveries"][0]["recovery_s"] > 0
    snap = st["breakers"]["flt.eng0"]
    assert snap["state"] == "closed"
    assert [state for state, _ in snap["transitions"]] == \
        ["open", "half_open", "closed"]

    # same seed => same logical fault-event trace (chaos reproducibility)
    reqs2, _, trace2 = _chaos_fleet_run(params, prompts, gen, plan())
    assert trace2 == trace
    assert [r.tokens for r in reqs2] == [r.tokens for r in ref]


# ---------------------------------------------------------------------------
# deadline expiry: clean failure, devices recycled, quotas balanced
# ---------------------------------------------------------------------------


class _FakeDevice:
    def __init__(self, i):
        self.id = i
        self.platform = "cpu"


class _FakePilot(Pilot):
    """Pilot over dummy devices; carve skips jax Mesh construction."""

    def carve(self, devices, mesh_shape=None, mesh_axes=("data",)):
        return SimpleNamespace(devices=tuple(devices), size=len(devices),
                               backend="fake", build_time_s=0.0)


def _always_fails(comm):
    raise ValueError("permanently broken task body")


def test_deadline_expiry_fails_cleanly_devices_recycled():
    from repro.core.agent import RemoteAgent

    pilot = _FakePilot("fake.4", [_FakeDevice(i) for i in range(4)])
    pol = FailurePolicy(max_retries=1000, backoff_base_s=0.05,
                        backoff_factor=1.0, jitter=0.0, deadline_s=0.6)
    with RemoteAgent(pilot, max_workers=2) as agent:
        agent.set_quota("grp", 2)
        t0 = time.time()
        (task,) = agent.submit([TaskDescription(
            name="doomed", fn=_always_fails, num_devices=2, group="grp",
            policy=pol)])
        # failed terminally via the deadline, not the retry budget
        assert task.state is TaskState.FAILED
        assert "deadline exceeded" in task.error
        assert 1 <= task.attempts < 1000
        assert time.time() - t0 < 30
        # clean: every lease returned, fairness invariant intact
        deadline = time.time() + 5
        while pilot.free_count() != 4 and time.time() < deadline:
            time.sleep(0.01)
        assert pilot.free_count() == 4
        assert agent.quota_violations() == {}


# ---------------------------------------------------------------------------
# worker respawn: policy-driven backoff recorded in transport stats
# ---------------------------------------------------------------------------


def test_worker_respawn_backoff_recorded():
    plan = FaultPlan(seed=1).crash_worker(worker=0, at_task=1)
    sub = SubprocessTransport(max_workers=1, worker_devices=1,
                              heartbeat_s=0.1, heartbeat_timeout_s=2.0)
    try:
        with inject(plan) as inj:
            fut = sub.submit(add_one, 41, label="doomed-dispatch")
            with pytest.raises(WorkerCrashed):
                fut.result(timeout=120)
            assert inj.all_fired()
            # the respawned worker serves the retry
            assert sub.submit(add_one, 41).result(timeout=120) == 42
        st = sub.stats()
    finally:
        sub.shutdown(wait=True)
    assert st["respawns"] >= 1
    entry = st["respawn_log"][0]
    assert entry["worker"] == 0 and entry["streak"] == 1
    # policy-driven backoff: non-zero, bounded, jittered off the base
    assert 0.01 <= entry["delay_s"] <= 5.0


# ---------------------------------------------------------------------------
# Request.reset_for_retry: the engine-recovery primitive
# ---------------------------------------------------------------------------


def test_reset_for_retry_requeues_and_rejects_finished():
    r = Request(np.arange(1, 6, dtype=np.int32), max_new_tokens=4)
    r.state = RequestState.RUNNING
    r.tokens = [3, 1]
    r.token_times = [0.1, 0.2]
    r.admitted_at = r.first_token_at = 1.0
    r.reset_for_retry()
    assert r.state is RequestState.QUEUED
    assert r.tokens == [] and r.token_times == []
    assert r.admitted_at is None and r.first_token_at is None
    assert not r.done()
    r._finish(RequestState.DONE)
    with pytest.raises(RuntimeError):
        r.reset_for_retry()
