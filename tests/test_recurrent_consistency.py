"""Property tests for the recurrent mixers: the chunked/parallel training
forms must agree with step-by-step decode recurrences — the invariant that
makes long_500k decode trustworthy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.params import init_params
from repro.configs import get_config
from repro.models import recurrent as R
from repro.models import blocks as B

KEY = jax.random.PRNGKey(0)


def test_rglru_chunked_vs_sequential():
    cfg = get_config("recurrentgemma-9b", smoke=True)
    specs = R.rglru_specs(cfg)
    params = init_params(KEY, specs)
    Bsz, S, r = 2, 64, cfg.rnn_width
    u = jax.random.normal(jax.random.PRNGKey(1), (Bsz, S, r)) * 0.5
    h_chunked = R.rglru_scan(params, u, chunk=16)
    # sequential via rglru_step
    h_prev = jnp.zeros((Bsz, r), jnp.float32)
    outs = []
    for t in range(S):
        o, h_prev = R.rglru_step(params, u[:, t:t + 1], h_prev)
        outs.append(o[:, 0])
    h_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_chunked), np.asarray(h_seq),
                               atol=1e-4, rtol=1e-4)


def test_rglru_block_decode_matches_full():
    """Running the block over a sequence == running decode step-by-step."""
    cfg = get_config("recurrentgemma-9b", smoke=True)
    specs = R.rglru_specs(cfg)
    params = init_params(KEY, specs)
    Bsz, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(2), (Bsz, S, cfg.d_model)) * 0.5
    full, _ = R.rglru_block_apply(cfg, params, x)
    cache = {
        "conv": jnp.zeros((Bsz, cfg.conv_width - 1, cfg.rnn_width), x.dtype),
        "h": jnp.zeros((Bsz, cfg.rnn_width), jnp.float32),
    }
    outs = []
    for t in range(S):
        o, cache = R.rglru_block_apply(cfg, params, x[:, t:t + 1], cache)
        outs.append(o[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=2e-3, rtol=2e-3)


def test_mlstm_chunked_vs_recurrent():
    """Chunked-parallel mLSTM == exact recurrent scan (stabilized)."""
    Bsz, S, nh, dh = 2, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (Bsz, S, nh, dh)) * 0.3
    k = jax.random.normal(ks[1], (Bsz, S, nh, dh)) * 0.3
    v = jax.random.normal(ks[2], (Bsz, S, nh, dh)) * 0.3
    log_i = jax.random.normal(ks[3], (Bsz, S, nh)) * 0.5
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (Bsz, S, nh)) + 1.0)
    chunked = R._mlstm_chunk_scan(q, k, v, log_i, log_f, chunk=16)
    # exact recurrence via mlstm_step
    state = (
        jnp.zeros((Bsz, nh, dh, dh), jnp.float32),
        jnp.zeros((Bsz, nh, dh), jnp.float32),
        jnp.full((Bsz, nh), -1e30, jnp.float32),
    )
    outs = []
    for t in range(S):
        h, state = R.mlstm_step(
            q[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1],
            log_i[:, t:t + 1], log_f[:, t:t + 1], state,
        )
        outs.append(h[:, 0])
    seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(seq),
                               atol=2e-3, rtol=2e-2)


def test_mlstm_block_decode_matches_full():
    cfg = get_config("xlstm-125m", smoke=True)
    specs = R.mlstm_specs(cfg)
    params = init_params(KEY, specs)
    Bsz, S = 1, 16
    x = jax.random.normal(jax.random.PRNGKey(4), (Bsz, S, cfg.d_model)) * 0.5
    full, _ = R.mlstm_block_apply(cfg, params, x, chunk=8)
    m = 2 * cfg.d_model
    nh = cfg.num_heads
    dh = m // nh
    cache = {
        "conv": jnp.zeros((Bsz, cfg.conv_width - 1, m), x.dtype),
        "state": (
            jnp.zeros((Bsz, nh, dh, dh), jnp.float32),
            jnp.zeros((Bsz, nh, dh), jnp.float32),
            jnp.full((Bsz, nh), -1e30, jnp.float32),
        ),
    }
    outs = []
    for t in range(S):
        o, cache = R.mlstm_block_apply(cfg, params, x[:, t:t + 1], cache)
        outs.append(o[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=5e-3, rtol=5e-2)


def test_slstm_block_decode_matches_full():
    cfg = get_config("xlstm-125m", smoke=True)
    specs = R.slstm_specs(cfg)
    params = init_params(KEY, specs)
    Bsz, S, d = 1, 16, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(5), (Bsz, S, d)) * 0.5
    full, _ = R.slstm_block_apply(cfg, params, x)
    cache = {"state": tuple(
        jnp.zeros((Bsz, d), jnp.float32) if i != 3
        else jnp.full((Bsz, d), -1e30, jnp.float32) for i in range(4)
    )}
    outs = []
    for t in range(S):
        o, cache = R.slstm_block_apply(cfg, params, x[:, t:t + 1], cache)
        outs.append(o[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=2e-3, rtol=2e-2)


def test_local_attention_window_masking():
    """Windowed chunked attention == full attention with a band mask."""
    cfg = get_config("recurrentgemma-9b", smoke=True)
    Bsz, S, H, D = 1, 64, 4, 16
    window = cfg.window  # 16
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (Bsz, S, H, D))
    k = jax.random.normal(ks[1], (Bsz, S, H, D))
    v = jax.random.normal(ks[2], (Bsz, S, H, D))
    got = B.chunked_attention(q, k, v, causal=True, window=window,
                              q_chunk=16, kv_chunk=16)
    # dense reference with band mask
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(D)
    pos = jnp.arange(S)
    mask = (pos[:, None] >= pos[None, :]) & ((pos[:, None] - pos[None, :]) < window)
    s = jnp.where(mask[None, None], s, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=1e-4, rtol=1e-4)


def test_gqa_decode_step_matches_prefill_suffix():
    """Filling a cache token-by-token reproduces full-sequence attention."""
    cfg = get_config("tinyllama-1.1b", smoke=True)
    specs = B.attn_specs(cfg)
    params = init_params(KEY, specs)
    Bsz, S = 1, 12
    x = jax.random.normal(jax.random.PRNGKey(7), (Bsz, S, cfg.d_model)) * 0.5
    positions = jnp.arange(S)[None, :]
    full, _ = B.attn_apply(cfg, params, x, positions, causal=True)
    H, KV = cfg.padded_gqa()
    cache = {
        "k": jnp.zeros((Bsz, S, KV, cfg.head_dim), cfg.compute_dtype),
        "v": jnp.zeros((Bsz, S, KV, cfg.head_dim), cfg.compute_dtype),
        "len": jnp.asarray(0, jnp.int32),
    }
    outs = []
    for t in range(S):
        pos_t = jnp.asarray([[t]], jnp.int32)
        o, cache = B.attn_apply(cfg, params, x[:, t:t + 1], pos_t, cache, causal=True)
        outs.append(o[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(step, np.float32), atol=3e-2, rtol=3e-2)
