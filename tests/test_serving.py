"""Serving subsystem tests: ServeEngine slot mechanics, batched-prefill
correctness against the old token-replay path (kept here as the reference
check), and service stages on the runtime (barrier exclusion, priority
preemption with checkpoint/resume, coexistence with a training pipeline
under one PilotManager).

Model-level tests run the tinyllama smoke config on the container's
single CPU device; runtime tests use tiny sleep-stage pipelines.
"""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.params import init_params, is_param
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.core.agent import RemoteAgent
from repro.core.pilot import PilotDescription, PilotManager
from repro.core.pipeline import Pipeline, PipelineScheduler, Stage
from repro.core.task import ServiceControl, TaskDescription, TaskState
from repro.models.lm import lm_cache_specs
from repro.serve import Request, RequestState, ServeEngine
from repro.train.state import model_specs
from repro.train.step import make_decode_step, make_prefill_step

CFG = get_config("tinyllama-1.1b", smoke=True)
# token-stream equivalence runs in f32 compute: in bf16 two near-tied
# logits can argmax-flip between the (numerically different but equally
# valid) batched-prefill and token-replay paths.  Params are shared — the
# compute dtype is applied at runtime.
CFG32 = dataclasses.replace(CFG, compute_dtype=jnp.float32)
RUN = RunConfig()


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), model_specs(CFG))


def _prompts(rng, lens):
    return [rng.integers(1, CFG.vocab_size, int(l)).astype(np.int32)
            for l in lens]


def _replay_generate(params, prompt, n_new, max_len, cfg=CFG):
    """The seed driver's token-by-token path: replay the prompt through
    the jitted decode step, then greedy-decode — the reference the
    batched prefill must match."""
    decode = jax.jit(make_decode_step(cfg, RUN))
    cache = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                         lm_cache_specs(cfg, 1, max_len), is_leaf=is_param)
    tok = None
    logits = None
    for t in range(len(prompt)):
        tok, logits, cache = decode(params, jnp.asarray(prompt[None, t:t + 1]),
                                    cache, jnp.asarray(t, jnp.int32))
    out = [int(tok[0])]
    pos = len(prompt)
    while len(out) < n_new:
        tok, logits, cache = decode(params, tok[:, None], cache,
                                    jnp.asarray(pos, jnp.int32))
        out.append(int(tok[0]))
        pos += 1
    return out, np.asarray(logits[0, -1], np.float32)


# ---------------------------------------------------------------------------
# engine mechanics: admission, eviction, slot reuse
# ---------------------------------------------------------------------------


def test_engine_admission_eviction_slot_reuse(params):
    eng = ServeEngine(CFG, RUN, max_slots=2, max_len=32, params=params)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(p, max_new_tokens=g)
            for p, g in zip(_prompts(rng, [5, 9, 3, 7, 4]), [4, 2, 7, 1, 3])]
    eng.run_until_drained()
    for r in reqs:
        assert r.state is RequestState.DONE
        assert len(r.tokens) == r.max_new_tokens
        assert r.latency_s is not None and r.ttft_s is not None
    stats = eng.stats()
    # 5 requests through 2 slots: slots were freed and reused
    assert stats["admitted"] == 5 and stats["completed"] == 5
    assert stats["prefill_batches"] >= 3
    # eviction left the engine empty
    assert eng.occupancy() == 0 and not eng.has_work()
    assert (eng.lengths == 0).all()


def test_chunked_prefill_matches_unchunked_streams(params):
    """Prompt processing in bounded chunks must not change any token
    stream: same greedy tokens whether a prompt prefills whole
    (prefill_chunk_tokens=None) or 8 tokens per step."""
    rng = np.random.default_rng(3)
    prompts = _prompts(rng, [21, 3, 14, 9])
    streams = {}
    for chunk in (None, 8):
        eng = ServeEngine(CFG32, RUN, max_slots=2, max_len=64,
                          params=params, prefill_chunk_tokens=chunk)
        reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
        eng.run_until_drained()
        assert all(r.state is RequestState.DONE for r in reqs)
        streams[chunk] = [r.tokens for r in reqs]
        if chunk is not None:
            # a 21-token prompt at 8 tokens/step needs >= 3 chunks
            assert eng.stats()["prefill_chunks"] > len(prompts)
    assert streams[None] == streams[8]


def test_prefill_fn_cache_bounded_and_reported(params):
    eng = ServeEngine(CFG, RUN, max_slots=2, max_len=32, params=params,
                      prefill_chunk_tokens=8)
    rng = np.random.default_rng(1)
    for p in _prompts(rng, [3, 9, 15, 2, 11]):
        eng.submit(p, max_new_tokens=2)
    eng.run_until_drained()
    stats = eng.stats()
    assert 1 <= stats["prefill_fns_cached"] <= ServeEngine._PREFILL_FN_CAP
    assert stats["prefill_chunk_tokens"] == 8
    # force cache churn well past the cap: eviction, not growth
    for t in range(ServeEngine._PREFILL_FN_CAP + 3):
        eng._get_prefill(1000 + t)
    assert len(eng._prefill_fns) == ServeEngine._PREFILL_FN_CAP
    assert eng.stats()["prefill_fns_evicted"] >= 3


def test_token_times_track_tokens(params):
    eng = ServeEngine(CFG, RUN, max_slots=2, max_len=64, params=params,
                      prefill_chunk_tokens=4)
    rng = np.random.default_rng(2)
    reqs = [eng.submit(p, max_new_tokens=5) for p in _prompts(rng, [10, 4])]
    eng.run_until_drained()
    for r in reqs:
        assert len(r.token_times) == len(r.tokens)
        assert r.token_times == sorted(r.token_times)
        assert len(r.inter_token_s) == len(r.tokens) - 1
        assert r.token_times[0] == r.first_token_at


def test_engine_rejects_oversized_prompt(params):
    eng = ServeEngine(CFG, RUN, max_slots=1, max_len=16, params=params)
    bad = eng.submit(np.ones(16, np.int32), max_new_tokens=2)
    ok = eng.submit(np.ones(4, np.int32), max_new_tokens=2)
    eng.run_until_drained()
    assert bad.state is RequestState.FAILED and "fit" in bad.error
    assert ok.state is RequestState.DONE and len(ok.tokens) == 2


def test_engine_respects_stop_token(params):
    eng = ServeEngine(CFG, RUN, max_slots=1, max_len=64, params=params)
    free = eng.submit(np.arange(1, 6, dtype=np.int32), max_new_tokens=40)
    eng.run_until_drained()
    assert len(free.tokens) == 40
    # stop on a token from the free-running stream: identical greedy
    # stream, cut at that token's FIRST occurrence
    stop_tok = free.tokens[2]
    first = free.tokens.index(stop_tok)
    stop = eng.submit(np.arange(1, 6, dtype=np.int32), max_new_tokens=40,
                      stop_token=stop_tok)
    eng.run_until_drained()
    assert stop.tokens == free.tokens[:first + 1]


# ---------------------------------------------------------------------------
# batched prefill vs token replay (the old serve path as reference)
# ---------------------------------------------------------------------------


def test_batched_prefill_matches_token_replay(params):
    max_len = 32
    rng = np.random.default_rng(1)
    prompts = _prompts(rng, [5, 9, 7])
    P = max(len(p) for p in prompts)
    tokens = np.zeros((len(prompts), P), np.int32)
    lens = np.zeros(len(prompts), np.int32)
    for i, p in enumerate(prompts):
        tokens[i, :len(p)] = p
        lens[i] = len(p)
    prefill = jax.jit(make_prefill_step(CFG, RUN, with_cache=True,
                                        max_len=max_len))
    next_tok, last_logits, cache = prefill(params, jnp.asarray(tokens),
                                           jnp.asarray(lens))
    for i, p in enumerate(prompts):
        replay_toks, replay_logits = _replay_generate(params, p, 1, max_len)
        got = np.asarray(last_logits[i], np.float32)
        # same last-position logits (bf16 compute: loose allclose + argmax)
        scale = np.max(np.abs(replay_logits)) + 1e-9
        assert np.max(np.abs(got - replay_logits)) / scale < 0.05
        assert int(next_tok[i]) == replay_toks[0]


def test_prefill_cache_matches_replay_cache(params):
    """The K/V written by the one-shot prefill equals what token replay
    deposits, for every row's valid prefix."""
    max_len = 32
    rng = np.random.default_rng(2)
    prompt = _prompts(rng, [9])[0]
    prefill = jax.jit(make_prefill_step(CFG, RUN, with_cache=True,
                                        max_len=max_len))
    _, _, cache = prefill(params, jnp.asarray(prompt[None]),
                          jnp.asarray([len(prompt)], np.int32))
    decode = jax.jit(make_decode_step(CFG, RUN))
    ref = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                       lm_cache_specs(CFG, 1, max_len), is_leaf=is_param)
    for t in range(len(prompt)):
        _, _, ref = decode(params, jnp.asarray(prompt[None, t:t + 1]), ref,
                           jnp.asarray(t, jnp.int32))
    L = len(prompt)
    for kind in ("k", "v"):
        got = np.asarray(cache["unit"]["b0"][kind][:, 0, :L], np.float32)
        want = np.asarray(ref["unit"]["b0"][kind][:, 0, :L], np.float32)
        assert np.max(np.abs(got - want)) < 0.05, kind


def test_engine_generation_matches_token_replay(params):
    """Mixed-length continuous batching produces the same greedy streams
    as isolated token replay — per-slot lengths never cross-talk."""
    max_len = 48
    rng = np.random.default_rng(3)
    prompts = _prompts(rng, [4, 11, 7])
    gens = [6, 3, 9]
    eng = ServeEngine(CFG32, RUN, max_slots=2, max_len=max_len, params=params)
    reqs = [eng.submit(p, max_new_tokens=g) for p, g in zip(prompts, gens)]
    eng.run_until_drained()
    for r, p, g in zip(reqs, prompts, gens):
        want, _ = _replay_generate(params, p, g, max_len, cfg=CFG32)
        assert r.tokens == want, (r.rid, r.tokens, want)


def test_continuous_beats_static_admission(params):
    """With mixed generation lengths, continuous batching refills freed
    slots mid-flight and needs fewer fused decode steps than the
    static-batch baseline for the same work."""
    rng = np.random.default_rng(4)
    prompts = _prompts(rng, [4, 4, 4, 4])
    gens = [10, 2, 10, 2]

    def drive(continuous):
        eng = ServeEngine(CFG, RUN, max_slots=2, max_len=32, params=params,
                          continuous=continuous)
        reqs = [eng.submit(p, max_new_tokens=g)
                for p, g in zip(prompts, gens)]
        eng.run_until_drained()
        assert all(r.state is RequestState.DONE for r in reqs)
        return eng.stats()["decode_steps"]

    assert drive(True) < drive(False)


# ---------------------------------------------------------------------------
# paged KV cache: layout equivalence, page reuse, backpressure, checkpoint
# ---------------------------------------------------------------------------


def test_paged_matches_contiguous_streams(params):
    """The paged engine (page pool + block tables) must emit exactly the
    contiguous engine's greedy streams — the layout is invisible to the
    math."""
    rng = np.random.default_rng(10)
    prompts = _prompts(rng, [4, 11, 7])
    gens = [6, 3, 9]
    outs = {}
    for layout in ("paged", "contiguous"):
        eng = ServeEngine(CFG32, RUN, max_slots=2, max_len=48, params=params,
                          kv_layout=layout, page_size=8)
        reqs = [eng.submit(p, max_new_tokens=g)
                for p, g in zip(prompts, gens)]
        eng.run_until_drained()
        assert all(r.state is RequestState.DONE for r in reqs)
        outs[layout] = [r.tokens for r in reqs]
    assert outs["paged"] == outs["contiguous"]


def test_paged_page_reuse_after_eviction(params):
    """A pool far smaller than max_slots x max_len serves a stream of
    requests because _finish_slot recycles pages: with 2 pages total only
    one request fits at a time, yet all five complete (FIFO backpressure
    holds the queue, never fails it)."""
    eng = ServeEngine(CFG, RUN, max_slots=2, max_len=32, params=params,
                      kv_layout="paged", page_size=8, num_pages=2)
    rng = np.random.default_rng(11)
    # prompt + generation stay within the 2 reserved pages (<= 16 slots)
    reqs = [eng.submit(p, max_new_tokens=6)
            for p in _prompts(rng, [5, 7, 4, 6, 5])]
    eng.run_until_drained()
    for r in reqs:
        assert r.state is RequestState.DONE and len(r.tokens) == 6
    stats = eng.stats()
    assert stats["peak_pages"] <= 2
    assert eng.pages_in_use() == 0
    assert sorted(eng.free_pages) == [0, 1]
    assert (eng.block_table == eng.num_pages).all()


def test_paged_pool_exhaustion_fails_slot_then_recovers(params):
    """Overcommit gone wrong: a sequence that outgrows the pool fails
    with a page-pool error (never hangs), its pages return to the free
    list, and later requests succeed."""
    eng = ServeEngine(CFG, RUN, max_slots=1, max_len=64, params=params,
                      kv_layout="paged", page_size=8, num_pages=2)
    hog = eng.submit(np.arange(1, 7, dtype=np.int32), max_new_tokens=40)
    eng.run_until_drained()
    assert hog.state is RequestState.FAILED
    assert "page pool exhausted" in hog.error
    ok = eng.submit(np.arange(1, 7, dtype=np.int32), max_new_tokens=8)
    eng.run_until_drained()
    assert ok.state is RequestState.DONE and len(ok.tokens) == 8
    assert eng.pages_in_use() == 0


def test_paged_unservable_prompt_fails_fast(params):
    """A prompt whose page requirement exceeds the whole pool can never
    be admitted — it must fail immediately instead of livelocking the
    FIFO queue (and everything behind it) forever."""
    eng = ServeEngine(CFG, RUN, max_slots=1, max_len=64, params=params,
                      kv_layout="paged", page_size=8, num_pages=2)
    hog = eng.submit(np.arange(1, 22, dtype=np.int32), max_new_tokens=2)
    ok = eng.submit(np.arange(1, 7, dtype=np.int32), max_new_tokens=4)
    eng.run_until_drained()
    assert hog.state is RequestState.FAILED and "pool" in hog.error
    assert ok.state is RequestState.DONE and len(ok.tokens) == 4


def test_paged_checkpoint_restore_roundtrip(params):
    """checkpoint/restore round-trips the page pool, block tables, and
    free list mid-generation: the resumed engine finishes with exactly
    the uninterrupted streams."""
    rng = np.random.default_rng(12)
    prompts = _prompts(rng, [5, 9])
    want = [_replay_generate(params, p, 10, 64, cfg=CFG32)[0]
            for p in prompts]
    eng = ServeEngine(CFG32, RUN, max_slots=2, max_len=64, params=params,
                      kv_layout="paged", page_size=8)
    reqs = [eng.submit(p, max_new_tokens=10) for p in prompts]
    for _ in range(4):
        eng.step()
    state = eng.checkpoint()
    eng._release_state()
    assert eng.pages_in_use() == 0  # released engine holds nothing
    eng.restore(state)
    assert np.array_equal(eng.block_table, state["block_table"])
    assert eng.free_pages == state["free_pages"]
    eng.run_until_drained()
    for r, w in zip(reqs, want):
        assert r.state is RequestState.DONE
        assert r.tokens == w, (r.tokens, w)


def test_bucket_floor_and_retrace_stats(params):
    """The prefill prompt bucket floor is 2 (an 8-floor padded every
    small admission to shape 8), and the engine counts each fresh jit
    shape in stats() so the bucketing/retrace tradeoff is observable."""
    from repro.serve.engine import _bucket
    assert _bucket(1) == 2 and _bucket(3) == 4 and _bucket(8) == 8
    eng = ServeEngine(CFG, RUN, max_slots=2, max_len=64, params=params)
    eng.submit(np.arange(1, 4, dtype=np.int32), max_new_tokens=2)
    eng.run_until_drained()
    first = eng.stats()["retraces"]
    assert first >= 2  # one prefill shape + one decode bucket
    eng.submit(np.arange(1, 4, dtype=np.int32), max_new_tokens=2)
    eng.run_until_drained()
    assert eng.stats()["retraces"] == first  # warm shapes: no retrace
    eng.submit(np.arange(1, 25, dtype=np.int32), max_new_tokens=2)
    eng.run_until_drained()
    assert eng.stats()["retraces_prefill"] > 1  # new P bucket counted


# ---------------------------------------------------------------------------
# sampling: temperature / top-k / seeded per-slot streams
# ---------------------------------------------------------------------------


def test_sampling_seeded_reproducible(params):
    """Same seed -> same sampled stream; different seed -> different (at
    temperature 2 over a 256-vocab the 12-token collision odds are nil).
    Streams depend only on the request's own seed, not batch placement."""
    eng = ServeEngine(CFG32, RUN, max_slots=2, max_len=64, params=params)
    prompt = np.arange(1, 7, dtype=np.int32)
    a = eng.submit(prompt, max_new_tokens=12, temperature=2.0, seed=123)
    b = eng.submit(prompt, max_new_tokens=12, temperature=2.0, seed=123)
    c = eng.submit(prompt, max_new_tokens=12, temperature=2.0, seed=124)
    eng.run_until_drained()
    assert a.tokens == b.tokens
    assert a.tokens != c.tokens


def test_sampling_does_not_perturb_greedy_neighbors(params):
    """A sampling request sharing the fused batch must not change a
    greedy neighbour's stream — greedy stays bit-identical to isolated
    token replay."""
    rng = np.random.default_rng(13)
    prompt = _prompts(rng, [6])[0]
    want, _ = _replay_generate(params, prompt, 8, 64, cfg=CFG32)
    eng = ServeEngine(CFG32, RUN, max_slots=2, max_len=64, params=params)
    greedy = eng.submit(prompt, max_new_tokens=8)
    eng.submit(_prompts(rng, [5])[0], max_new_tokens=8, temperature=1.5,
               seed=7)
    eng.run_until_drained()
    assert greedy.tokens == want


def test_sampling_top_k_one_is_argmax(params):
    """top_k=1 collapses sampling to argmax whatever the temperature."""
    prompt = np.arange(1, 8, dtype=np.int32)
    eng = ServeEngine(CFG32, RUN, max_slots=1, max_len=64, params=params)
    greedy = eng.submit(prompt, max_new_tokens=10)
    eng.run_until_drained()
    topk1 = eng.submit(prompt, max_new_tokens=10, temperature=3.0, top_k=1,
                       seed=99)
    eng.run_until_drained()
    assert topk1.tokens == greedy.tokens


def test_sampling_stream_survives_preemption(params):
    """The per-slot PRNG keys ride the checkpoint: a preempted-and-resumed
    sampled stream equals the uninterrupted one."""
    prompt = np.arange(1, 8, dtype=np.int32)
    ref_eng = ServeEngine(CFG32, RUN, max_slots=2, max_len=64, params=params)
    ref_req = ref_eng.submit(prompt, max_new_tokens=12, temperature=1.0,
                             seed=42)
    ref_eng.run_until_drained()

    eng = ServeEngine(CFG32, RUN, max_slots=2, max_len=64, params=params)
    req = eng.submit(prompt, max_new_tokens=12, temperature=1.0, seed=42)
    for _ in range(5):
        eng.step()
    state = eng.checkpoint()
    eng._release_state()
    eng.restore(state)
    eng.run_until_drained()
    assert req.state is RequestState.DONE
    assert req.tokens == ref_req.tokens


# ---------------------------------------------------------------------------
# service stages on the runtime
# ---------------------------------------------------------------------------


def _service_pipeline(engine, priority=0, quota=None):
    return Pipeline("serve", [Stage(
        "engine",
        lambda comm, upstream, control=None, resume_state=None:
            engine.run_service(control, resume_state=resume_state),
        kind="inference", service=True, priority=priority)], quota=quota)


def test_service_stage_excluded_from_barrier(params):
    eng = ServeEngine(CFG, RUN, max_slots=1, max_len=32, params=params,
                      idle_wait_s=0.002)
    pm = PilotManager()
    agent = RemoteAgent(pm.submit_pilot(PilotDescription()), max_workers=2)
    try:
        pipe = Pipeline("mixed", [
            Stage("plain", lambda comm, upstream: 41),
            Stage("engine",
                  lambda comm, upstream, control=None, resume_state=None:
                      eng.run_service(control, resume_state=resume_state),
                  kind="inference", service=True),
        ])
        out = pipe.run(agent)  # returns when `plain` is done
        assert out["plain"] == 41
        svc = pipe.tasks["engine"]
        assert not svc.finalized, "service must outlive the barrier"
        req = pipe.control("engine").submit_request(
            Request(np.arange(1, 5, dtype=np.int32), max_new_tokens=3))
        assert req.wait(30) and req.state is RequestState.DONE
        assert pipe.stop_services(drain=True, timeout=30)
        assert pipe.results["engine"]["completed"] == 1
    finally:
        agent.close()


def test_failed_pipeline_stops_its_service(params):
    """A pipeline whose ordinary stage fails must stop its service stages
    on the way out — a leaked service would pin its device lease forever."""
    eng = ServeEngine(CFG, RUN, max_slots=1, max_len=32, params=params,
                      idle_wait_s=0.002)
    pm = PilotManager()
    agent = RemoteAgent(pm.submit_pilot(PilotDescription()), max_workers=2)
    try:
        pipe = Pipeline("doomed", [
            Stage("boom", lambda comm, upstream: 1 / 0, max_retries=0),
            Stage("engine",
                  lambda comm, upstream, control=None, resume_state=None:
                      eng.run_service(control, resume_state=resume_state),
                  kind="inference", service=True),
        ])
        with pytest.raises(RuntimeError, match="boom"):
            pipe.run(agent)
        svc = pipe.tasks["engine"]
        assert svc.wait(30), "service must stop when the pipeline fails"
        assert svc.state is TaskState.DONE
    finally:
        agent.close()


def test_service_stage_cannot_be_dependency():
    pipe = Pipeline("bad", [
        Stage("svc", lambda c, u: None, service=True),
        Stage("after", lambda c, u: None, deps=("svc",)),
    ])
    with pytest.raises(RuntimeError, match="depends on service"):
        pipe._validate_dag()


def test_training_preempts_service_and_it_resumes(params):
    """Acceptance scenario: a service stage and a training pipeline share
    one PilotManager; higher-priority training preempts the engine (it
    checkpoints + yields its device), then the engine resumes from the
    checkpoint and drains every accepted request.  Zero quota violations."""
    eng = ServeEngine(CFG, RUN, max_slots=2, max_len=128, params=params,
                      idle_wait_s=0.002)
    pm = PilotManager()
    # a single-device pilot forces genuine contention: the service holds
    # the only device, so training can ONLY run by preempting it (the
    # host may emulate any device count — pin the pool size)
    agent = RemoteAgent(pm.submit_pilot(PilotDescription(num_devices=1)),
                        max_workers=2)
    try:
        serve_pipe = _service_pipeline(eng, priority=0)
        trained = threading.Event()

        def train_fn(comm, upstream):
            trained.set()
            time.sleep(0.25)
            return "trained"

        train_pipe = Pipeline("train", [
            Stage("step", train_fn, kind="train", priority=10)])

        serve_pipe.start(agent)
        ctl = serve_pipe.control("engine")
        rng = np.random.default_rng(5)
        reqs = [ctl.submit_request(Request(p, max_new_tokens=80))
                for p in _prompts(rng, [6, 6, 6])]
        deadline = time.time() + 60
        while reqs[0].first_token_at is None:
            assert time.time() < deadline, "service never started generating"
            time.sleep(0.01)

        out = PipelineScheduler(agent).run([train_pipe], timeout=60)
        assert out["train"]["step"] == "trained" and trained.is_set()

        svc_task = serve_pipe.tasks["engine"]
        assert svc_task.preemptions >= 1, "training never preempted the engine"
        assert agent.preemption_requests >= 1
        for r in reqs:
            assert r.wait(120), f"{r.rid} not drained after resume"
            assert len(r.tokens) == 80
        assert serve_pipe.stop_services(drain=True, timeout=60)
        stats = serve_pipe.results["engine"]
        assert stats["completed"] == len(reqs)
        assert stats["preemptions"] >= 1 and stats["resumes"] >= 1
        assert agent.quota_violations() == {}
    finally:
        agent.close()


def test_preemption_preserves_greedy_streams(params):
    """A preempted-and-resumed engine must emit exactly the tokens an
    uninterrupted engine would — the checkpoint carries the slot cache."""
    rng = np.random.default_rng(6)
    prompts = _prompts(rng, [5, 8])
    want = [_replay_generate(params, p, 12, 64, cfg=CFG32)[0]
            for p in prompts]

    eng = ServeEngine(CFG32, RUN, max_slots=2, max_len=64, params=params)
    ctl = ServiceControl()
    reqs = [ctl.submit_request(Request(p, max_new_tokens=12))
            for p in prompts]
    # run a few steps, force a preemption mid-generation, then resume
    from repro.core.task import ServicePreempted
    for r in ctl.take_requests():
        eng.submit(r)
    for _ in range(4):
        eng.step()
    ctl.request_preempt()
    with pytest.raises(ServicePreempted) as ei:
        eng.run_service(ctl)
    ctl._clear_preempt()
    ctl.drain()
    eng.run_service(ctl, resume_state=ei.value.state)
    for r, w in zip(reqs, want):
        assert r.state is RequestState.DONE
        assert r.tokens == w, (r.tokens, w)


def test_stop_releases_waiting_requests(params):
    """A hard stop() must FAIL outstanding requests (in-slot and queued),
    not abandon them — clients block on Request.wait() with no timeout."""
    eng = ServeEngine(CFG, RUN, max_slots=1, max_len=64, params=params)
    ctl = ServiceControl()
    r1 = ctl.submit_request(Request(np.arange(1, 6, dtype=np.int32),
                                    max_new_tokens=50))
    r2 = ctl.submit_request(Request(np.arange(1, 4, dtype=np.int32),
                                    max_new_tokens=50))
    for r in ctl.take_requests():
        eng.submit(r)
    eng.step()  # r1 occupies the only slot; r2 still queued
    ctl.stop()
    eng.run_service(ctl)
    for r in (r1, r2):
        assert r.wait(5), f"{r.rid} waiter never released"
        assert r.state is RequestState.FAILED and "stopped" in r.error
    assert not eng.has_work()


def test_agent_close_stops_running_service(params):
    """close() must signal running services to stop instead of hanging on
    the transport drain."""
    eng = ServeEngine(CFG, RUN, max_slots=1, max_len=32, params=params,
                      idle_wait_s=0.002)
    pm = PilotManager()
    agent = RemoteAgent(pm.submit_pilot(PilotDescription()), max_workers=2)
    task, = agent.submit_async([TaskDescription(
        name="svc",
        fn=lambda comm, control=None, resume_state=None:
            eng.run_service(control, resume_state=resume_state),
        kind="inference", service=True)])
    deadline = time.time() + 30
    while task.state is not TaskState.RUNNING:
        assert time.time() < deadline
        time.sleep(0.01)
    t0 = time.time()
    agent.close(timeout=30)
    assert time.time() - t0 < 30
    assert task.wait(10) and task.state is TaskState.DONE
