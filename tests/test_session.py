"""Session API tests: the stage-graph DSL (`@stage`, `>>`, `|`,
`.after`), the Session facade (lazy pods, lifecycle, quotas, serving),
and per-stage cross-pilot placement — one DAG whose stages land on
different kind-specialised pods with real dependency edges crossing
agents, plus per-STAGE migration when a pod degrades.

Like tests/test_scheduler.py, scheduling logic runs on FakePilots over
plain-object devices (carve skips jax Mesh construction), so an N-device
pool is modelled on the container's single real device.
"""
import threading
import time
from types import SimpleNamespace

import pytest

from repro.core import (KindAwarePlacement, Session, StageContext,
                        StageGraph, StageSpec, stage)
from repro.core.pilot import Pilot, PilotDescription, PilotManager
from repro.core.pipeline import (Pipeline, Stage, aggregate_metrics,
                                 run_pipelines, run_pipelines_multi)
from repro.core.task import TaskState


class FakeDevice:
    def __init__(self, i):
        self.id = i
        self.platform = "cpu"


class FakePilot(Pilot):
    """Pilot over dummy devices; carve returns a mesh-free communicator."""

    def carve(self, devices, mesh_shape=None, mesh_axes=("data",)):
        return SimpleNamespace(devices=tuple(devices), size=len(devices),
                               backend="fake", build_time_s=0.0,
                               pilot_uid=self.uid)


def make_manager(n):
    return PilotManager(devices=[FakeDevice(i) for i in range(n)],
                        pilot_factory=FakePilot)


def make_session(n, pods=None, **kw):
    return Session(manager=make_manager(n), pods=pods, **kw)


KIND_PODS = [
    PilotDescription(num_devices=2, name="data",
                     task_kinds=("data_engineering",)),
    PilotDescription(num_devices=2, name="dl",
                     task_kinds=("train", "inference")),
]


# ---------------------------------------------------------------------------
# stage DSL: decorator, composition, compilation
# ---------------------------------------------------------------------------


def test_stage_decorator_defaults_and_options():
    @stage
    def plain(ctx):
        return 1

    assert isinstance(plain, StageSpec)
    assert (plain.name, plain.kind, plain.num_devices) == ("plain", "generic", 1)

    @stage(kind="train", num_devices=4, checkpoint="/tmp/ck", priority=3)
    def heavy(ctx):
        return 2

    assert heavy.kind == "train" and heavy.num_devices == 4
    assert heavy.checkpoint == "/tmp/ck" and heavy.priority == 3
    narrowed = heavy.options(num_devices=2)
    assert narrowed.num_devices == 2 and heavy.num_devices == 4, \
        "options() must clone, not mutate"


def test_rshift_and_parallel_build_expected_edges():
    a, b, c, d = [stage(lambda ctx: None, name=n) for n in "abcd"]
    g = (a | b) >> c >> d
    specs = {s.name: s for s in g}
    assert set(specs) == {"a", "b", "c", "d"}
    assert specs["a"].deps == () and specs["b"].deps == ()
    assert set(specs["c"].deps) == {"a", "b"}
    assert specs["d"].deps == ("c",)
    assert g.sources() == ("a", "b") and g.sinks() == ("d",)


def test_after_adds_explicit_edges():
    a = stage(lambda ctx: 1, name="a")
    b = stage(lambda ctx: 2, name="b")
    c = stage(lambda ctx: 3, name="c").after(a, "b")
    g = StageGraph([a, b, c])
    assert set(next(s for s in g if s.name == "c").deps) == {"a", "b"}
    assert g.sinks() == ("c",)


def test_named_reuse_and_duplicate_detection():
    work = stage(lambda ctx: 0, name="work")
    g = StageGraph([work.named("w0"), work.named("w1")])
    assert set(g.names) == {"w0", "w1"}
    with pytest.raises(ValueError, match="duplicate"):
        StageGraph([work, work])
    with pytest.raises(ValueError, match="duplicate"):
        _ = StageGraph([work]) >> work


def test_spec_is_directly_callable_and_bindable():
    @stage(kind="train")
    def scale(ctx, factor, offset=0):
        return ctx.upstream["src"] * factor + offset

    ctx = StageContext(comm=None, upstream={"src": 10})
    assert scale(ctx, 3) == 30
    bound = scale.bind(2, offset=5)
    assert bound(ctx) == 25
    assert bound.to_stage().fn(None, {"src": 10}) == 25


def test_ctx_dep_helper():
    ctx = StageContext(comm=None, upstream={"only": 7})
    assert ctx.dep() == 7 and ctx.dep("only") == 7
    two = StageContext(comm=None, upstream={"a": 1, "b": 2})
    with pytest.raises(KeyError):
        two.dep()


def test_compile_lowers_to_pipeline():
    a = stage(lambda ctx: 1, name="a", kind="data_engineering")
    b = stage(lambda ctx: 2, name="b", kind="train", num_devices=3)
    pipe = (a >> b).compile("lowered", quota=2)
    assert isinstance(pipe, Pipeline) and pipe.quota == 2
    stages = {s.name: s for s in pipe.stages}
    assert stages["b"].deps == ("a",)
    assert stages["b"].kind == "train" and stages["b"].num_devices == 3


def test_rshift_refuses_all_service_left_side():
    svc = stage(lambda ctx: None, name="svc", service=True)
    tail = stage(lambda ctx: None, name="tail")
    with pytest.raises(ValueError, match="service"):
        _ = StageGraph([svc]) >> tail


# ---------------------------------------------------------------------------
# Pipeline._validate_dag: unknown dependency vs cycle (bugfix)
# ---------------------------------------------------------------------------


def test_unknown_dependency_is_not_reported_as_cycle():
    pipe = Pipeline("p", [Stage("a", lambda c, u: 1, deps=("ghost",))])
    with pytest.raises(RuntimeError, match="unknown stage.*ghost"):
        pipe.start(None)


def test_cycle_still_reported_as_cycle():
    pipe = Pipeline("p", [
        Stage("a", lambda c, u: 1, deps=("b",)),
        Stage("b", lambda c, u: 2, deps=("a",)),
    ])
    with pytest.raises(RuntimeError, match="cycle"):
        pipe.start(None)


# ---------------------------------------------------------------------------
# submit-time task recording (bugfix): live readers see running stages
# ---------------------------------------------------------------------------


def test_running_stage_visible_in_tasks_and_metrics():
    from repro.core.agent import RemoteAgent

    agent = RemoteAgent(FakePilot("fake.live", [FakeDevice(0), FakeDevice(1)]),
                        max_workers=2)
    started, gate = threading.Event(), threading.Event()

    def slow(comm, upstream):
        started.set()
        gate.wait(5.0)
        return "done"

    pipe = Pipeline("live", [Stage("slow", slow)])
    try:
        pipe.start(agent)
        assert started.wait(5.0)
        task = pipe.tasks.get("slow")
        assert task is not None and not task.finalized, (
            "non-service task must be visible at submit time")
        meta = aggregate_metrics([pipe], wall=0.1)
        assert meta["per_pipeline"]["live"]["running"] == ["slow"]
        assert meta["n_running"] == 1
        gate.set()
        assert pipe.wait(10.0)
        meta = aggregate_metrics([pipe], wall=0.1)
        assert meta["per_pipeline"]["live"]["running"] == []
        assert meta["n_running"] == 0 and pipe.results["slow"] == "done"
    finally:
        gate.set()
        agent.close()


# ---------------------------------------------------------------------------
# Session lifecycle
# ---------------------------------------------------------------------------


def test_session_materializes_pods_lazily_and_recycles_on_close():
    session = make_session(8, pods=2)
    assert session.manager.pilots == [], "pilots must not exist before use"
    out = session.run(stage(lambda ctx: 42, name="x"), name="p")
    assert out == {"x": 42}
    assert len(session.manager.pilots) == 2
    sizes = sorted(p.size for p in session.manager.pilots)
    assert sizes == [4, 4]
    ids = [frozenset(d.id for d in p.alive_devices())
           for p in session.manager.pilots]
    assert not ids[0] & ids[1], "session pods must be disjoint"
    session.close()
    assert session.manager.free_devices() == 8, (
        "close() must cancel owned pilots and recycle devices")
    with pytest.raises(RuntimeError, match="closed"):
        session.run(stage(lambda ctx: 0, name="y"))


def test_session_context_manager_closes_on_error():
    pm = make_manager(4)
    with pytest.raises(RuntimeError, match="boom"):
        with Session(manager=pm) as session:
            session.run(stage(lambda ctx: 0, name="ok"), name="warm")
            raise RuntimeError("boom")
    assert pm.free_devices() == 4, "devices leaked on the error path"


def test_session_adopts_existing_pilots_without_owning_them():
    pm = make_manager(4)
    mine = pm.submit_pilot(PilotDescription(num_devices=4, name="mine"))
    session = Session(manager=pm)
    assert session.run(stage(lambda ctx: 1, name="s")) == {"s": 1}
    assert session.pilots == [mine]
    session.close()
    assert pm.pilots == [mine], "adopted pilots must survive close()"


def test_session_run_raises_on_stage_failure():
    session = make_session(2)

    @stage(max_retries=0)
    def bad(ctx):
        raise ValueError("exploded")

    try:
        with pytest.raises(RuntimeError, match="exploded"):
            session.run(bad, name="failing")
    finally:
        session.close()


def test_quota_pipeline_sticks_to_one_pod():
    """The device cap is enforced per agent, so a quota'd pipeline must
    not spread over pods (it could then hold quota*K devices): all its
    stages resolve to the SAME pilot when one pod can host them, and the
    recorded peak never exceeds the quota anywhere."""
    session = make_session(8, pods=2, max_workers_per_pilot=8)
    work = stage(lambda ctx: time.sleep(0.03) or 1, name="w")
    g = StageGraph([work.named(f"w{i}") for i in range(6)])
    try:
        pipe = session.start(g, name="sticky", quota=1)
        assert pipe.wait(10.0) and pipe.error is None, pipe.error
        placements = pipe.stage_placements()
        assert len(set(placements.values())) == 1, (
            f"quota'd pipeline spread over pods: {placements}")
        total_peak = sum(
            session.agent_for(p).group_peaks().get("sticky", 0)
            for p in session.pilots)
        assert total_peak == 1, (
            f"pipeline-wide quota breached across agents: {total_peak}")
    finally:
        session.close()


def test_quota_passes_through_to_prebuilt_pipeline():
    session = make_session(4, max_workers_per_pilot=4)
    pipe = Pipeline("pre", [
        Stage(f"s{i}", lambda c, u: time.sleep(0.02) or 1) for i in range(4)])
    try:
        out = session.run_all([pipe], quota=1)
        assert "_error" not in out["pre"]
        pilot, = session.pilots
        assert session.agent_for(pilot).group_peaks()["pre"] == 1
    finally:
        session.close()


def test_session_quota_enforced_via_graph_compile():
    session = make_session(4, max_workers_per_pilot=8)
    work = stage(lambda ctx: time.sleep(0.05) or 1, name="w")
    g = StageGraph([work.named(f"w{i}") for i in range(6)])
    try:
        session.run(g, name="capped", quota=1)
        pilot, = session.pilots
        peaks = session.agent_for(pilot).group_peaks()
        assert peaks["capped"] == 1, f"quota breached: {peaks}"
        assert session.agent_for(pilot).quota_violations() == {}
    finally:
        session.close()


# ---------------------------------------------------------------------------
# per-stage cross-pilot placement (the tentpole)
# ---------------------------------------------------------------------------


def test_cross_pilot_dag_places_stages_by_kind_and_flows_results():
    """One preprocess -> train -> postprocess DAG over two kind-specialised
    pods: the data stage lands on the data pod, the DL stages on the DL
    pod, and the dependency edges cross agents with results intact."""
    session = make_session(4, pods=KIND_PODS)
    seen = {}

    @stage(kind="data_engineering")
    def preprocess(ctx):
        seen["preprocess"] = ctx.comm.pilot_uid
        return 21

    @stage(kind="train")
    def train(ctx):
        seen["train"] = ctx.comm.pilot_uid
        return ctx.upstream["preprocess"] * 2

    @stage(kind="inference")
    def postprocess(ctx):
        seen["postprocess"] = ctx.comm.pilot_uid
        return ctx.upstream["train"] + 1

    try:
        pipe = session.start(preprocess >> train >> postprocess, name="x")
        assert pipe.wait(10.0) and pipe.error is None, pipe.error
        assert pipe.results == {"preprocess": 21, "train": 42,
                                "postprocess": 43}
        placements = pipe.stage_placements()
        assert placements["preprocess"].startswith("data")
        assert placements["train"].startswith("dl")
        assert placements["postprocess"].startswith("dl")
        assert placements["preprocess"] != placements["train"], (
            "dependency edge must cross pilots")
        # stages really executed on the pilot they were placed on
        assert seen == {k: placements[k] for k in placements}
        # one agent per pilot: the stages' agents differ across the edge
        assert pipe.stage_agents["preprocess"] is not pipe.stage_agents["train"]
    finally:
        session.close()


def test_data_and_dl_pod_stages_overlap():
    """Independent stages of ONE pipeline run concurrently on their
    respective pods — the overlap the old two-pipeline --kind-pods hack
    serialized away.  Each stage blocks until it has seen the other
    running; a serialized schedule would deadlock-and-fail here."""
    session = make_session(4, pods=KIND_PODS)
    de_running, dl_running = threading.Event(), threading.Event()

    @stage(kind="data_engineering")
    def de(ctx):
        de_running.set()
        assert dl_running.wait(5.0), "DL stage never overlapped"
        return "de"

    @stage(kind="train")
    def tr(ctx):
        dl_running.set()
        assert de_running.wait(5.0), "data stage never overlapped"
        return "tr"

    try:
        pipe = session.start(de | tr, name="overlap")
        assert pipe.wait(10.0) and pipe.error is None, pipe.error
        placements = pipe.stage_placements()
        assert placements["de"] != placements["tr"]
        assert pipe.results == {"de": "de", "tr": "tr"}
    finally:
        session.close()


def test_degraded_pod_migrates_only_the_affected_stage():
    """While the data stage is still running, the DL pod planned for the
    train stage degrades below its device ask: at submit time the stage
    re-resolves to the healthy DL pod and a per-STAGE migration is
    recorded; the data stage's placement is untouched."""
    session = make_session(8, pods=[
        PilotDescription(num_devices=2, name="data",
                         task_kinds=("data_engineering",)),
        PilotDescription(num_devices=4, name="dl1",
                         task_kinds=("train", "inference")),
        PilotDescription(num_devices=2, name="dl2",
                         task_kinds=("train", "inference")),
    ])
    started, gate = threading.Event(), threading.Event()
    seen = {}

    @stage(kind="data_engineering")
    def pre(ctx):
        seen["pre"] = ctx.comm.pilot_uid
        started.set()
        assert gate.wait(5.0)
        return 1

    @stage(kind="train", num_devices=2)
    def tr(ctx):
        seen["tr"] = ctx.comm.pilot_uid
        return ctx.comm.size

    try:
        pipe = session.start(pre >> tr, name="mig")
        assert started.wait(5.0), "data stage never launched"
        dl1 = next(p for p in session.pilots if p.uid.startswith("dl1"))
        dl2 = next(p for p in session.pilots if p.uid.startswith("dl2"))
        # planned placement favoured dl1 (most free capacity); kill 3 of
        # its 4 devices so it can no longer host the 2-device train stage
        dl1.mark_failed([d.id for d in dl1.alive_devices()[:3]])
        gate.set()
        assert pipe.wait(10.0) and pipe.error is None, pipe.error
        assert seen["tr"] == dl2.uid, (
            f"train stage ran on degraded pod: {seen['tr']}")
        assert pipe.results["tr"] == 2, "migrated stage lost its mesh"
        assert seen["pre"].startswith("data"), (
            "unaffected stage must keep its placement")
        assert len(pipe.migrations) == 1, pipe.migrations
        m = pipe.migrations[0]
        assert m["stage"] == "tr" and m["from"] == dl1.uid \
            and m["to"] == dl2.uid
    finally:
        gate.set()
        session.close()


def test_unplaceable_kind_aborts_pipeline_before_start():
    session = make_session(2, pods=[
        PilotDescription(num_devices=2, name="data",
                         task_kinds=("data_engineering",))])
    try:
        with pytest.raises(RuntimeError, match="unplaceable.*train"):
            session.run(stage(lambda ctx: 1, name="t", kind="train"),
                        name="nope")
    finally:
        session.close()


def test_stage_unplaceable_at_submit_time_fails_pipeline_cleanly():
    """The pre-flight check passes, then EVERY pod able to host the train
    stage dies while the data stage runs — the ready stage resolves to
    None and the pipeline fails with the stage named, without hanging."""
    session = make_session(4, pods=KIND_PODS)
    started, gate = threading.Event(), threading.Event()

    @stage(kind="data_engineering")
    def pre(ctx):
        started.set()
        assert gate.wait(5.0)
        return 1

    @stage(kind="train", num_devices=2)
    def tr(ctx):
        return 2

    try:
        pipe = session.start(pre >> tr, name="doomed")
        assert started.wait(5.0)
        dl = next(p for p in session.pilots if p.uid.startswith("dl"))
        dl.mark_failed([d.id for d in dl.alive_devices()])
        gate.set()
        assert pipe.wait(10.0), "pipeline hung on unplaceable stage"
        assert pipe.error is not None and "unplaceable" in pipe.error
        assert pipe.failed_stage == "tr"
    finally:
        gate.set()
        session.close()


def test_run_all_isolates_unplaceable_sibling():
    session = make_session(4, pods=KIND_PODS)
    ok = StageGraph([stage(lambda ctx: 5, name="s",
                           kind="data_engineering")]).compile("ok")
    huge = StageGraph([stage(lambda ctx: 1, name="wide", kind="train",
                             num_devices=16)]).compile("huge")
    try:
        out = session.run_all([ok, huge])
        assert out["ok"]["s"] == 5
        assert "unplaceable" in out["huge"]["_error"]
        assert set(out["_meta"]["placement"]["ok"]) == {"s"}
    finally:
        session.close()


# ---------------------------------------------------------------------------
# serving through the Session
# ---------------------------------------------------------------------------


def _echo_service():
    @stage(kind="inference", service=True)
    def svc(ctx):
        out = []
        while True:
            ctx.control.wait_for_work(0.05)
            out.extend(ctx.control.take_requests())
            if ctx.control.stop_requested():
                break
            if ctx.control.drain_requested() \
                    and ctx.control.pending_requests() == 0:
                break
        return out

    return svc


def test_session_serve_roundtrip_and_drain():
    session = make_session(2)
    try:
        handle = session.serve(_echo_service(), name="echo")
        handle.submit_request("a")
        handle.submit_request("b")
        assert handle.stop(drain=True, timeout=10.0), "service did not drain"
        assert handle.result == ["a", "b"]
        assert handle.task.state == TaskState.DONE
    finally:
        session.close()


def test_session_close_stops_running_service():
    session = make_session(2)
    handle = session.serve(_echo_service(), name="echo")
    handle.submit_request("x")
    session.close()
    task = handle.task
    assert task is not None and task.wait(10.0), (
        "close() must stop the service, not leave it holding its lease")
    assert session.manager.free_devices() == 2


def test_serve_rejects_graphs_without_exactly_one_service_stage():
    session = make_session(2)
    ran = []
    try:
        with pytest.raises(ValueError, match="service"):
            session.serve(stage(lambda ctx: ran.append(1), name="plain"))
        time.sleep(0.05)
        assert ran == [], "invalid serve graph must be rejected BEFORE it runs"
    finally:
        session.close()


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------


def test_run_pipelines_shim_still_works_but_warns():
    pilot = FakePilot("fake.shim", [FakeDevice(0), FakeDevice(1)])
    with pytest.warns(DeprecationWarning, match="Session"):
        out = run_pipelines([Pipeline("p", [Stage("s", lambda c, u: 1)])],
                            pilot=pilot)
    assert out["p"]["s"] == 1


def test_run_pipelines_multi_shim_still_works_but_warns():
    with pytest.warns(DeprecationWarning, match="Session"):
        out = run_pipelines_multi(
            [Pipeline("p", [Stage("s", lambda c, u: 2)])],
            manager=make_manager(4), num_pilots=2)
    assert out["p"]["s"] == 2
