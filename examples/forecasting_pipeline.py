"""Paper Table-3 pipeline: the 11 NeuralForecast-analogue models trained and
evaluated through Deep RC (shared pilot, overlapped tasks).

  PYTHONPATH=src python examples/forecasting_pipeline.py [--models NLinear,GRU] [--steps 60]
"""
import argparse, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import paper_tables as P
from repro.models import forecasting as F

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default=",".join(list(F.MODELS)[:3]))
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    for name in args.models.split(","):
        r = P._train_forecaster(name, args.steps)
        print(f"{name:20s} MAE={r['MAE']:.3f} MSE={r['MSE']:.3f} "
              f"MAPE={r['MAPE']:.2f}% train={r['train_s']:.1f}s")
    print("forecasting pipeline OK")
