"""Paper Table-3 pipeline: NeuralForecast-analogue models trained and
evaluated through Deep RC — as N *concurrent* pipelines batched under the
pilot layer (the Table-4 mode), not a serial loop.

Single-pilot by default; ``--pilots 2`` splits the emulated device pool
into disjoint per-pod pilots and places one model pipeline per pod via
the PilotManager scheduler; ``--quota N`` caps each pipeline's concurrent
device share (fairness under contention).

  PYTHONPATH=src python examples/forecasting_pipeline.py \
      [--models NLinear,GRU] [--steps 60] [--pilots 2] [--quota 1]
"""
import argparse, os, sys
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import paper_tables as P
from repro.core.bridge import dl_stage
from repro.core.pipeline import Pipeline, run_pipelines, run_pipelines_multi
from repro.models import forecasting as F

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default=",".join(list(F.MODELS)[:3]))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--pilots", type=int, default=1,
                    help="number of disjoint pilots to spread pipelines over")
    ap.add_argument("--quota", type=int, default=None,
                    help="per-pipeline concurrent-device cap")
    args = ap.parse_args()
    names = args.models.split(",")

    pipes = [
        Pipeline(name, [
            dl_stage("train", lambda c, u, nm=name: P._train_forecaster(
                nm, args.steps), kind="train"),
        ], quota=args.quota)
        for name in names
    ]
    if args.pilots > 1:
        out = run_pipelines_multi(pipes, num_pilots=args.pilots)
    else:
        out = run_pipelines(pipes, max_workers=4)
    failed = False
    for name in names:
        if "_error" in out[name]:  # fault isolation: siblings still report
            failed = True
            first_line = out[name]["_error"].splitlines()[0]
            print(f"{name:20s} FAILED: {first_line}")
            continue
        r = out[name]["train"]
        print(f"{name:20s} MAE={r['MAE']:.3f} MSE={r['MSE']:.3f} "
              f"MAPE={r['MAPE']:.2f}% train={r['train_s']:.1f}s")
    meta = out["_meta"]
    print(f"batch wall={meta['wall_s']:.1f}s "
          f"task_busy={meta['task_busy_s']:.1f}s "
          f"overlap_factor={meta['overlap_factor']:.2f}")
    if args.pilots > 1:
        print("placement:", meta["placement"])
        if meta["quota_violations"]:
            sys.exit(f"quota violations: {meta['quota_violations']}")
    if failed:
        sys.exit("forecasting pipeline had failures (see above)")
    print("forecasting pipeline OK")
