"""Paper Table-3 pipeline: NeuralForecast-analogue models trained and
evaluated through Deep RC — as N *concurrent* stage graphs batched under
one Session (the Table-4 mode), not a serial loop.

Single shared pod by default; ``--pilots 2`` splits the emulated device
pool into disjoint per-pod pilots and the Session's per-stage placement
policy spreads the model stages across them; ``--quota N`` caps each
pipeline's concurrent device share (fairness under contention).

  PYTHONPATH=src python examples/forecasting_pipeline.py \
      [--models NLinear,GRU] [--steps 60] [--pilots 2] [--quota 1]
"""
import argparse, os, sys
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import paper_tables as P
from repro.core import Session, StageGraph, stage
from repro.models import forecasting as F

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default=",".join(list(F.MODELS)[:3]))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--pilots", type=int, default=1,
                    help="number of disjoint pods to spread pipelines over")
    ap.add_argument("--quota", type=int, default=None,
                    help="per-pipeline concurrent-device cap")
    args = ap.parse_args()
    names = args.models.split(",")

    @stage(kind="train", name="train")
    def train_model(ctx, model_name, steps):
        return P._train_forecaster(model_name, steps)

    # one single-stage graph per model, compiled to a pipeline named after
    # the model so results stay keyed the way Table 3 reports them
    pipes = [
        StageGraph([train_model.bind(nm, args.steps)])
        .compile(nm, quota=args.quota)
        for nm in names
    ]
    with Session(pods=args.pilots if args.pilots > 1 else None,
                 max_workers_per_pilot=4) as session:
        out = session.run_all(pipes)
    meta = out["_meta"]
    failed = False
    for name in names:
        if "_error" in out[name]:  # fault isolation: siblings still report
            failed = True
            first_line = out[name]["_error"].splitlines()[0]
            print(f"{name:20s} FAILED: {first_line}")
            continue
        r = out[name]["train"]
        print(f"{name:20s} MAE={r['MAE']:.3f} MSE={r['MSE']:.3f} "
              f"MAPE={r['MAPE']:.2f}% train={r['train_s']:.1f}s")
    print(f"batch wall={meta['wall_s']:.1f}s "
          f"task_busy={meta['task_busy_s']:.1f}s "
          f"overlap_factor={meta['overlap_factor']:.2f}")
    if args.pilots > 1:
        print("placement:", meta["placement"])
        if meta["quota_violations"]:
            sys.exit(f"quota violations: {meta['quota_violations']}")
    if failed:
        sys.exit("forecasting pipeline had failures (see above)")
    print("forecasting pipeline OK")
