"""Batched serving example: prefill + KV-cache decode under the pilot
runtime.

  PYTHONPATH=src python examples/serve_lm.py --batch 4 --gen 16
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import build_parser, run

if __name__ == "__main__":
    ap = build_parser()
    ap.set_defaults(smoke=True)
    res = run(ap.parse_args())
    print("serve_lm OK")
