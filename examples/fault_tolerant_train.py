"""Fault-tolerance demo: a training task that loses devices mid-run is
retried by the RemoteAgent on the surviving pool and resumes from the last
async checkpoint — the Deep RC isolation story end-to-end.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import os, sys
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.core.agent import RemoteAgent
from repro.core.pilot import PilotDescription, PilotManager
from repro.core.task import DeviceFailure, TaskDescription

CKPT = "/tmp/deep_rc_ft_demo"
STATE = {"w": jnp.zeros((4,)), "step": jnp.asarray(0)}


def train_task(comm, resume_step=None):
    # checkpoint-aware retry: the agent reads the last completed step from
    # the checkpoint dir and hands it in on every retried attempt — the
    # task no longer rediscovers it with store.latest_step itself
    state = STATE
    start = 0
    if resume_step is not None:
        state = store.restore(CKPT, STATE, step=resume_step)
        start = int(state["step"])
        print(f"  agent handed resume_step={resume_step}; resuming at {start}")
    for i in range(start, 10):
        state = {"w": state["w"] + 1.0, "step": state["step"] + 1}
        store.save(CKPT, i + 1, state)
        if i == 4 and start == 0:  # first attempt dies mid-run
            raise DeviceFailure([d.id for d in comm.devices[:2]],
                                "injected mid-training failure")
    return {"final_w": float(state["w"][0]), "steps": int(state["step"])}


if __name__ == "__main__":
    import shutil
    shutil.rmtree(CKPT, ignore_errors=True)
    pm = PilotManager()
    pilot = pm.submit_pilot(PilotDescription())
    agent = RemoteAgent(pilot, max_workers=2)
    # non-blocking submission: the call returns before the task runs; the
    # dispatcher launches it in the background and `wait` joins the result
    task, = agent.submit_async([TaskDescription(
        name="ft-train", fn=train_task, num_devices=pilot.size, max_retries=2,
        checkpoint_dir=CKPT)])
    assert not task.finalized, "submit_async must return before completion"
    print("submitted (non-blocking), state:", task.state.value)
    agent.wait([task])
    print("state:", task.state.value, "result:", task.result,
          "attempts:", task.attempts)
    print("alive devices after failure:", len(pilot.alive_devices()), "/", pilot.size)
    assert task.result["steps"] == 10 and task.attempts == 2
    print("fault_tolerant_train OK")
