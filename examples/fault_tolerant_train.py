"""Fault-tolerance demo: a training stage that loses devices mid-run is
retried by the runtime on the surviving pool and resumes from the last
async checkpoint — the Deep RC isolation story end-to-end, through the
Session API (the stage declares ``checkpoint=`` and reads
``ctx.resume_step``; Session.close() recycles the surviving devices).

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import os, sys
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.core import Session, stage
from repro.core.task import DeviceFailure

CKPT = "/tmp/deep_rc_ft_demo"
STATE = {"w": jnp.zeros((4,)), "step": jnp.asarray(0)}


@stage(kind="train", max_retries=2, checkpoint=CKPT)
def train(ctx):
    # checkpoint-aware retry: the agent reads the last completed step from
    # the checkpoint dir and hands it in as ctx.resume_step on every
    # retried attempt — the stage body no longer rediscovers it itself
    state = STATE
    start = 0
    if ctx.resume_step is not None:
        state = store.restore(CKPT, STATE, step=ctx.resume_step)
        start = int(state["step"])
        print(f"  agent handed resume_step={ctx.resume_step}; resuming at {start}")
    for i in range(start, 10):
        state = {"w": state["w"] + 1.0, "step": state["step"] + 1}
        store.save(CKPT, i + 1, state)
        if i == 4 and start == 0:  # first attempt dies mid-run
            raise DeviceFailure([d.id for d in ctx.comm.devices[:2]],
                                "injected mid-training failure")
    return {"final_w": float(state["w"][0]), "steps": int(state["step"])}


if __name__ == "__main__":
    import shutil
    shutil.rmtree(CKPT, ignore_errors=True)
    n_dev = len(jax.devices())
    assert n_dev >= 3, (
        f"demo needs >=3 devices to survive losing 2, have {n_dev}; unset "
        "XLA_FLAGS or use --xla_force_host_platform_device_count=8")
    with Session(max_workers_per_pilot=2) as session:
        # non-blocking submission: start() returns before the stage runs;
        # the dispatcher launches it in the background and wait() joins.
        # The stage width adapts to the actual pool (num_devices=n_dev).
        pipe = session.start(train.options(num_devices=n_dev), name="ft")
        print("submitted (non-blocking), finished:", pipe.finished)
        assert not pipe.finished, "start must return before completion"
        pipe.wait()
        task = pipe.tasks["train"]
        print("state:", task.state.value, "result:", task.result,
              "attempts:", task.attempts)
        pilot, = session.pilots
        print("alive devices after failure:",
              len(pilot.alive_devices()), "/", pilot.size)
        assert task.result["steps"] == 10 and task.attempts == 2
        alive = len(pilot.alive_devices())
    # close() recycled the SURVIVING devices back to the manager's pool
    assert session.manager.free_devices() == alive == n_dev - 2
    print("fault_tolerant_train OK (survivors recycled:",
          session.manager.free_devices(), "of", n_dev, ")")
