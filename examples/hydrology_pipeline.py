"""Paper Tables 1-2 pipeline: LSTM hydrology model on synthetic CAMELS-like
data through Deep RC, with the Table-2 overhead decomposition surfaced from
the scheduler's per-task accounting (queue / communicator-build / execute).

  PYTHONPATH=src python examples/hydrology_pipeline.py
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.paper_tables import bench_hydrology
from repro.core.bridge import cylon_stage, dl_stage
from repro.core.pipeline import Pipeline, run_pipelines

if __name__ == "__main__":
    rows = bench_hydrology(full=False)
    for r in rows:
        print(f"{r[0]:35s} {r[1]:12.1f}us  {r[2]}")

    # Table-2 decomposition through the async scheduler: a minimal
    # preprocess -> train DAG whose per-task overheads are recorded by the
    # agent and aggregated into run_pipelines' _meta.  The pipeline runs
    # through the full PilotManager -> Pilot -> Transport stack; each
    # stage's communicator records which pilot pool it was carved from.
    pilots_seen = set()

    def note_pilot(c, v):
        pilots_seen.add(getattr(c, "pilot_uid", None))
        return v

    pipe = Pipeline("hydro", [
        cylon_stage("preprocess", lambda c, u: note_pilot(c, 1.0)),
        dl_stage("train", lambda c, u: note_pilot(c, u["preprocess"] * 2),
                 deps=("preprocess",)),
    ], quota=1)  # cap: hydro never holds more than 1 device at once
    out = run_pipelines([pipe])
    for stage, task in pipe.tasks.items():
        print(f"overhead/{stage:12s} queue={task.overhead_s['queue']*1e3:.2f}ms "
              f"communicator={task.overhead_s['communicator']*1e3:.2f}ms "
              f"execute={task.duration_s*1e3:.2f}ms")
    print(f"pipeline wall={out['_meta']['wall_s']*1e3:.1f}ms "
          f"pilot={out['_meta']['pilot']} carved_from={sorted(pilots_seen)}")
    print("hydrology pipeline OK")
