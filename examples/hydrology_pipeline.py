"""Paper Tables 1-2 pipeline: LSTM hydrology model on synthetic CAMELS-like
data through Deep RC, with overhead decomposition.

  PYTHONPATH=src python examples/hydrology_pipeline.py
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.paper_tables import bench_hydrology

if __name__ == "__main__":
    rows = bench_hydrology(full=False)
    for r in rows:
        print(f"{r[0]:35s} {r[1]:12.1f}us  {r[2]}")
    print("hydrology pipeline OK")
