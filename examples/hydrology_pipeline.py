"""Paper Tables 1-2 pipeline: LSTM hydrology model on synthetic CAMELS-like
data through Deep RC, with the Table-2 overhead decomposition surfaced from
the scheduler's per-task accounting (queue / communicator-build / execute)
— written against the Session API (`@stage` graph, per-stage placement).

  PYTHONPATH=src python examples/hydrology_pipeline.py
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.paper_tables import bench_hydrology
from repro.core import Session, stage

if __name__ == "__main__":
    rows = bench_hydrology(full=False)
    for r in rows:
        print(f"{r[0]:35s} {r[1]:12.1f}us  {r[2]}")

    # Table-2 decomposition through the async scheduler: a minimal
    # preprocess -> train DAG whose per-task overheads are recorded by the
    # agent.  The graph runs through the full Session -> PilotManager ->
    # Pilot -> Transport stack; each stage's communicator records which
    # pilot pool it was carved from.
    pilots_seen = set()

    def note_pilot(c, v):
        pilots_seen.add(getattr(c, "pilot_uid", None))
        return v

    @stage(kind="data_engineering")
    def preprocess(ctx):
        return note_pilot(ctx.comm, 1.0)

    @stage(kind="train")
    def train(ctx):
        return note_pilot(ctx.comm, ctx.upstream["preprocess"] * 2)

    with Session() as session:
        # quota=1: hydro never holds more than 1 device at once
        pipe = session.start(preprocess >> train, name="hydro", quota=1)
        pipe.wait()
        if pipe.error is not None:
            raise RuntimeError(pipe.error)
        for stage_name, task in pipe.tasks.items():
            print(f"overhead/{stage_name:12s} "
                  f"queue={task.overhead_s['queue']*1e3:.2f}ms "
                  f"communicator={task.overhead_s['communicator']*1e3:.2f}ms "
                  f"execute={task.duration_s*1e3:.2f}ms")
        print(f"pipeline wall={pipe.wall_s*1e3:.1f}ms "
              f"placement={pipe.stage_placements()} "
              f"carved_from={sorted(pilots_seen)}")
    print("hydrology pipeline OK")
