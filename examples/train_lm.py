"""End-to-end driver: train an assigned-architecture LM through the full
Deep RC pipeline (data engineering -> bridge -> pjit train loop -> async
checkpoints -> postprocess).

Default is a quick smoke;  a ~100M-parameter run of the paper-scale kind:
  PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m --steps 300 \
      --batch 8 --seq 256 --ckpt-every 50        # (~30 min on 1 CPU core)
Restart after interruption with --resume.
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import build_parser, run

if __name__ == "__main__":
    ap = build_parser()
    ap.set_defaults(smoke=True, steps=20, arch="tinyllama-1.1b")
    res = run(ap.parse_args())
    assert res["improved"], res
    print("train_lm OK")
