"""Quickstart: the minimal Deep RC pipeline through the Session API.

Synthetic table -> Cylon-analogue preprocess -> zero-copy Data Bridge ->
train a tiny linear model -> postprocess, written as a stage graph
(`@stage` + `>>`) and run under one Session — no manual PilotManager /
RemoteAgent / Pipeline wiring, and devices are recycled on exit.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Session, stage
from repro.core.bridge import data_bridge
from repro.dataframe.table import Table


@stage(kind="data_engineering")
def preprocess(ctx):
    rng = np.random.default_rng(0)
    n = 4096
    x1, x2 = rng.normal(size=n).astype(np.float32), rng.normal(size=n).astype(np.float32)
    y = 2.0 * x1 - x2 + 0.05 * rng.normal(size=n).astype(np.float32)
    return Table.from_columns({"x1": x1, "x2": x2, "y": y})


@stage(kind="train")
def train(ctx):
    loader = data_bridge(ctx.upstream["preprocess"], ["x1", "x2"], "y", 512)
    w, b = jnp.zeros((2,)), jnp.zeros(())

    @jax.jit
    def step(w, b, feats, labels, mask):
        def loss_fn(wb):
            pred = feats @ wb[0] + wb[1]
            err = jnp.where(mask, pred - labels, 0.0)
            return jnp.sum(err ** 2) / jnp.maximum(jnp.sum(mask), 1)
        l, g = jax.value_and_grad(loss_fn)((w, b))
        return w - 0.2 * g[0], b - 0.2 * g[1], l

    for epoch in range(20):
        for feats, labels, mask in loader.epoch(epoch):
            w, b, loss = step(w, b, feats, labels, mask)
    return {"w": np.asarray(w), "loss": float(loss)}


@stage(kind="inference")
def postprocess(ctx):
    r = ctx.dep("train")
    return {"w": r["w"].round(3).tolist(), "final_loss": r["loss"]}


if __name__ == "__main__":
    with Session(max_workers_per_pilot=2) as session:
        pipe = session.start(preprocess >> train >> postprocess,
                             name="quickstart")
        pipe.wait()
        if pipe.error is not None:
            raise RuntimeError(pipe.error)
        out = pipe.results
        print("result:", out["postprocess"])
        print("train-task overheads:", pipe.tasks["train"].overhead_s)
        print("placement:", pipe.stage_placements())
    assert out["postprocess"]["final_loss"] < 0.1
    assert session.manager.free_devices() == session.manager.total_devices, \
        "Session.close() must recycle the pilot's devices"
    print("quickstart OK")
