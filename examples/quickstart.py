"""Quickstart: the minimal Deep RC pipeline on one device.

Synthetic table -> Cylon-analogue preprocess -> zero-copy Data Bridge ->
train a tiny linear model -> postprocess, all under the pilot runtime.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent import RemoteAgent
from repro.core.bridge import cylon_stage, data_bridge, dl_stage
from repro.core.pilot import PilotDescription, PilotManager
from repro.core.pipeline import Pipeline
from repro.dataframe.table import Table


def preprocess(comm, upstream):
    rng = np.random.default_rng(0)
    n = 4096
    x1, x2 = rng.normal(size=n).astype(np.float32), rng.normal(size=n).astype(np.float32)
    y = 2.0 * x1 - x2 + 0.05 * rng.normal(size=n).astype(np.float32)
    return Table.from_columns({"x1": x1, "x2": x2, "y": y})


def train(comm, upstream):
    loader = data_bridge(upstream["preprocess"], ["x1", "x2"], "y", 512)
    w, b = jnp.zeros((2,)), jnp.zeros(())

    @jax.jit
    def step(w, b, feats, labels, mask):
        def loss_fn(wb):
            pred = feats @ wb[0] + wb[1]
            err = jnp.where(mask, pred - labels, 0.0)
            return jnp.sum(err ** 2) / jnp.maximum(jnp.sum(mask), 1)
        l, g = jax.value_and_grad(loss_fn)((w, b))
        return w - 0.2 * g[0], b - 0.2 * g[1], l

    for epoch in range(20):
        for feats, labels, mask in loader.epoch(epoch):
            w, b, loss = step(w, b, feats, labels, mask)
    return {"w": np.asarray(w), "loss": float(loss)}


def postprocess(comm, upstream):
    r = upstream["train"]
    return {"w": r["w"].round(3).tolist(), "final_loss": r["loss"]}


if __name__ == "__main__":
    pm = PilotManager()
    agent = RemoteAgent(pm.submit_pilot(PilotDescription()), max_workers=2)
    pipe = Pipeline("quickstart", [
        cylon_stage("preprocess", preprocess),
        dl_stage("train", train, deps=("preprocess",)),
        dl_stage("postprocess", postprocess, deps=("train",), kind="inference"),
    ])
    out = pipe.run(agent)
    print("result:", out["postprocess"])
    print("train-task overheads:", pipe.tasks["train"].overhead_s)
    assert out["postprocess"]["final_loss"] < 0.1
    print("quickstart OK")
