"""Cylon-analogue distributed columnar Table.

A :class:`Table` is a dict of equal-length jnp columns plus a ``valid`` row
mask.  Distribution model (the TPU-native re-founding of Cylon's
rank-partitioned Arrow tables — see DESIGN.md §2):

* every shard (mesh slice along ``axis``, default ``"data"``) owns a
  fixed-capacity partition of rows;
* ragged partitions are expressed as ``valid`` masks over the fixed
  capacity (XLA needs static shapes);
* distributed operators (:mod:`repro.dataframe.ops_dist`) exchange rows
  with ``shard_map`` + ``all_to_all`` / ``psum`` — the role MPI/GLOO/UCX
  play in Cylon.

The Global Table (GT) of the paper == a Table whose columns are jax global
arrays sharded over the mesh; "zero-copy" handoff to DL training is a
compiled gather on those same buffers (bridge/loader.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class Table:
    columns: Dict[str, jnp.ndarray]
    valid: jnp.ndarray  # bool [N]
    mesh: Optional[Mesh] = None
    axis: str = "data"

    # -- construction -------------------------------------------------------

    @staticmethod
    def from_columns(columns: Dict[str, Any], mesh: Optional[Mesh] = None,
                     axis: str = "data", valid=None) -> "Table":
        cols = {k: jnp.asarray(v) for k, v in columns.items()}
        n = next(iter(cols.values())).shape[0]
        for k, v in cols.items():
            if v.shape[0] != n:
                raise ValueError(f"column {k} length {v.shape[0]} != {n}")
        if valid is None:
            valid = jnp.ones((n,), bool)
        t = Table(cols, jnp.asarray(valid, bool), mesh, axis)
        if mesh is not None:
            t = t.reshard(mesh, axis)
        return t

    def reshard(self, mesh: Mesh, axis: str = "data") -> "Table":
        """Distribute rows over the mesh axis (pads to divisibility)."""
        size = mesh.shape[axis]
        n = self.num_rows
        pad = (-n) % size

        def place(c):
            if pad:
                padding = [(0, pad)] + [(0, 0)] * (c.ndim - 1)
                c = jnp.pad(c, padding)
            spec = P(axis, *([None] * (c.ndim - 1)))
            return jax.device_put(c, NamedSharding(mesh, spec))

        cols = {k: place(v) for k, v in self.columns.items()}
        valid = place(self.valid if not pad else self.valid)
        if pad:
            valid = place(jnp.pad(self.valid, (0, pad), constant_values=False))
        return Table(cols, valid, mesh, axis)

    # -- basics --------------------------------------------------------------

    @property
    def num_rows(self) -> int:
        return int(self.valid.shape[0])

    @property
    def num_valid(self) -> int:
        return int(jnp.sum(self.valid))

    @property
    def column_names(self):
        return list(self.columns)

    def col(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    def with_columns(self, columns, valid=None) -> "Table":
        return Table(dict(columns), self.valid if valid is None else valid,
                     self.mesh, self.axis)

    def project(self, names: Sequence[str]) -> "Table":
        return self.with_columns({k: self.columns[k] for k in names})

    def to_numpy(self) -> Dict[str, np.ndarray]:
        """Gather valid rows to host (postprocessing / tests)."""
        mask = np.asarray(self.valid)
        return {k: np.asarray(v)[mask] for k, v in self.columns.items()}

    def head(self, n: int = 5) -> Dict[str, np.ndarray]:
        data = self.to_numpy()
        return {k: v[:n] for k, v in data.items()}
