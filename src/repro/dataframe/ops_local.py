"""Local (per-shard) dataframe operators: the jnp analogue of Cylon's local
operator set.  All mask-aware and static-shape."""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

_KNUTH = jnp.uint32(2654435761)


def hash_u32(keys: jnp.ndarray) -> jnp.ndarray:
    """Multiplicative hash of integer keys -> uint32."""
    k = keys.astype(jnp.uint32)
    h = k * _KNUTH
    h ^= h >> 16
    return h


def filter_rows(columns: Dict, valid: jnp.ndarray, mask: jnp.ndarray):
    """Logical filter: rows stay in place, validity shrinks (static shape)."""
    return columns, valid & mask


def sort_by_key(columns: Dict, valid: jnp.ndarray, key: str, *, descending=False):
    """Local sort by key; invalid rows sort to the end (stable)."""
    keys = columns[key]
    big = jnp.iinfo(keys.dtype).max if jnp.issubdtype(keys.dtype, jnp.integer) else jnp.inf
    eff = jnp.where(valid, keys, big)
    if descending:
        eff = jnp.where(valid, -keys, big)
    order = jnp.argsort(eff, stable=True)
    cols = {k: jnp.take(v, order, axis=0) for k, v in columns.items()}
    return cols, jnp.take(valid, order)


def compact(columns: Dict, valid: jnp.ndarray):
    """Move valid rows to the front (stable), keep capacity."""
    order = jnp.argsort(~valid, stable=True)
    cols = {k: jnp.take(v, order, axis=0) for k, v in columns.items()}
    return cols, jnp.take(valid, order)


def local_groupby_sum(columns: Dict, valid: jnp.ndarray, key: str,
                      value_cols: Sequence[str], num_groups_cap: int):
    """Group-by-key sum into fixed slots (keys assumed pre-partitioned so
    equal keys are co-located).  Sort-based segmenting — exact, no hash
    collisions; distinct keys beyond ``num_groups_cap`` are dropped."""
    cols, valid = sort_by_key(columns, valid, key)
    keys = cols[key]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), keys[1:] != keys[:-1]]
    ) & valid
    seg = jnp.cumsum(first.astype(jnp.int32)) - 1  # group index per row
    seg = jnp.where(valid & (seg < num_groups_cap), seg, num_groups_cap)
    out = {}
    for c in value_cols:
        v = jnp.where(valid, cols[c], 0)
        out[c] = jax.ops.segment_sum(v, seg, num_segments=num_groups_cap + 1)[:-1]
    key_of_slot = (
        jnp.zeros((num_groups_cap + 1,), keys.dtype)
        .at[seg].max(jnp.where(valid, keys, 0), mode="drop")[:-1]
    )
    count = jax.ops.segment_sum(valid.astype(jnp.int32), seg,
                                num_segments=num_groups_cap + 1)[:-1]
    return key_of_slot, out, count


def local_hash_join(
    left_cols: Dict, left_valid: jnp.ndarray,
    right_cols: Dict, right_valid: jnp.ndarray,
    key: str, suffix: str = "_r",
) -> Tuple[Dict, jnp.ndarray]:
    """Inner equality join; right side treated as a (deduplicated) build
    side — each left row matches at most one right row (first by key order),
    the common case for the paper's feature-table joins.  Output capacity ==
    left capacity (static)."""
    lk = left_cols[key]
    rk = right_cols[key]
    big = jnp.iinfo(rk.dtype).max
    rk_eff = jnp.where(right_valid, rk, big)
    order = jnp.argsort(rk_eff)
    rk_sorted = jnp.take(rk_eff, order)
    pos = jnp.searchsorted(rk_sorted, lk)
    pos = jnp.clip(pos, 0, rk_sorted.shape[0] - 1)
    match = (jnp.take(rk_sorted, pos) == lk) & left_valid
    ridx = jnp.take(order, pos)
    out = dict(left_cols)
    for k, v in right_cols.items():
        if k == key:
            continue
        name = k if k not in left_cols else k + suffix
        out[name] = jnp.take(v, ridx, axis=0)
    return out, match
