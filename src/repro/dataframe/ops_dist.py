"""Distributed dataframe operators: shuffle / sort / join / groupby /
reduce over the mesh, built on ``shard_map`` + ``jax.lax`` collectives.

This is Cylon's distributed-operator set re-founded on the TPU network:
``all_to_all`` plays MPI_Alltoall (shuffle), ``all_gather`` serves splitter
exchange (sample sort), ``psum`` serves reductions.  Static-shape semantics:
every worker sends a fixed-capacity bucket to every other worker; overflow
rows are dropped and *counted* (returned so callers/tests can assert zero).
"""
from __future__ import annotations

import functools
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.dataframe import ops_local as L
from repro.dataframe.table import Table

from repro.common.compat import axis_size


def _specs_for(table: Table):
    return {k: P(table.axis) if v.ndim == 1 else P(table.axis, *([None] * (v.ndim - 1)))
            for k, v in table.columns.items()}


def _bucket_exchange(cols: Dict, valid, dest: jnp.ndarray, axis: str, cap: int):
    """Per-shard: route rows to destination shards with per-dest capacity
    ``cap``; returns received (cols, valid, n_dropped)."""
    PIDX = axis_size(axis)
    # position of each row within its destination bucket
    onehot = jax.nn.one_hot(jnp.where(valid, dest, PIDX), PIDX + 1, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=-1)
    keep = valid & (pos < cap)
    dropped = jnp.sum(valid & ~keep)
    slot = jnp.where(keep, dest * cap + pos, PIDX * cap)  # sentinel slot

    def scatter(col):
        buf_shape = (PIDX * cap + 1,) + col.shape[1:]
        buf = jnp.zeros(buf_shape, col.dtype)
        return buf.at[slot].set(jnp.where(
            keep.reshape((-1,) + (1,) * (col.ndim - 1)), col, 0), mode="drop")[:-1]

    sent = {k: scatter(v) for k, v in cols.items()}
    sent_valid = jnp.zeros((PIDX * cap + 1,), bool).at[slot].set(keep, mode="drop")[:-1]

    def a2a(x):
        return jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=True)

    recv = {k: a2a(v) for k, v in sent.items()}
    recv_valid = a2a(sent_valid)
    total_dropped = jax.lax.psum(dropped, axis)
    return recv, recv_valid, total_dropped


def _wrap(table: Table, fn, extra_tables: Sequence[Table] = (), **out_extra):
    """Run fn under shard_map over the table's mesh axis."""
    mesh = table.mesh
    axis = table.axis
    in_specs = []
    args = []
    for t in (table, *extra_tables):
        in_specs.append((_specs_for(t), P(axis)))
        args.append((t.columns, t.valid))
    return mesh, axis, in_specs, args


def shuffle(table: Table, key: str, *, capacity_factor: float = 2.0):
    """Hash-partition rows by key (Cylon shuffle). Equal keys co-locate."""
    mesh, axis = table.mesh, table.axis
    nshards = mesh.shape[axis]
    per = table.num_rows // nshards
    cap = max(int(per / nshards * capacity_factor), 16)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(_specs_for(table), P(axis)),
        out_specs=(_specs_for(table), P(axis), P()),
    )
    def _shuf(cols, valid):
        dest = (L.hash_u32(cols[key]) % jnp.uint32(axis_size(axis))).astype(jnp.int32)
        recv, rvalid, dropped = _bucket_exchange(cols, valid, dest, axis, cap)
        return recv, rvalid, dropped[None]

    cols, valid, dropped = _shuf(table.columns, table.valid)
    out = Table(cols, valid, mesh, axis)
    return out, int(dropped[0])


def sort(table: Table, key: str, *, capacity_factor: float = 2.5,
         oversample: int = 8):
    """Distributed sample sort: local sort -> splitter sampling
    (all_gather) -> range partition (all_to_all) -> local merge."""
    mesh, axis = table.mesh, table.axis
    nshards = mesh.shape[axis]
    per = table.num_rows // nshards
    cap = max(int(per * capacity_factor / nshards), 16)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(_specs_for(table), P(axis)),
        out_specs=(_specs_for(table), P(axis), P()),
    )
    def _sort(cols, valid):
        nsh = axis_size(axis)
        cols, valid = L.sort_by_key(cols, valid, key)
        keys = cols[key]
        big = jnp.iinfo(keys.dtype).max
        eff = jnp.where(valid, keys, big)
        # sample oversample*nshards candidates per shard
        n = keys.shape[0]
        idx = jnp.linspace(0, n - 1, oversample * nsh).astype(jnp.int32)
        samples = jnp.take(eff, idx)
        all_samples = jax.lax.all_gather(samples, axis, tiled=True)
        all_samples = jnp.sort(all_samples)
        m = all_samples.shape[0]
        splitters = jnp.take(
            all_samples, ((jnp.arange(1, nsh)) * m // nsh).astype(jnp.int32)
        )
        dest = jnp.searchsorted(splitters, eff, side="right").astype(jnp.int32)
        dest = jnp.clip(dest, 0, nsh - 1)
        recv, rvalid, dropped = _bucket_exchange(cols, valid, dest, axis, cap)
        recv, rvalid = L.sort_by_key(recv, rvalid, key)
        return recv, rvalid, dropped[None]

    cols, valid, dropped = _sort(table.columns, table.valid)
    return Table(cols, valid, mesh, axis), int(dropped[0])


def join(left: Table, right: Table, key: str, *, capacity_factor: float = 2.0):
    """Distributed hash join: co-partition both sides by key hash, then
    local join (right side = build side, at-most-one match per left row)."""
    mesh, axis = left.mesh, left.axis
    nshards = mesh.shape[axis]
    capL = max(int(left.num_rows // nshards / nshards * capacity_factor), 16)
    capR = max(int(right.num_rows // nshards / nshards * capacity_factor), 16)

    out_cols_proto = dict(left.columns)
    for k in right.columns:
        if k != key:
            out_cols_proto[k if k not in left.columns else k + "_r"] = right.columns[k]
    out_spec = {k: P(axis) if v.ndim == 1 else P(axis, *([None] * (v.ndim - 1)))
                for k, v in out_cols_proto.items()}

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(_specs_for(left), P(axis), _specs_for(right), P(axis)),
        out_specs=(out_spec, P(axis), P()),
    )
    def _join(lc, lv, rc, rv):
        nsh = axis_size(axis)
        ldest = (L.hash_u32(lc[key]) % jnp.uint32(nsh)).astype(jnp.int32)
        rdest = (L.hash_u32(rc[key]) % jnp.uint32(nsh)).astype(jnp.int32)
        lrecv, lrv, ldrop = _bucket_exchange(lc, lv, ldest, axis, capL)
        rrecv, rrv, rdrop = _bucket_exchange(rc, rv, rdest, axis, capR)
        out, ov = L.local_hash_join(lrecv, lrv, rrecv, rrv, key)
        return out, ov, (ldrop + rdrop)[None]

    cols, valid, dropped = _join(left.columns, left.valid, right.columns, right.valid)
    return Table(cols, valid, mesh, axis), int(dropped[0])


def groupby_sum(table: Table, key: str, value_cols: Sequence[str],
                *, groups_cap_per_shard: int = 4096):
    """Distributed group-by-sum: shuffle by key, then local segment-sum."""
    shuffled, dropped = shuffle(table, key)
    mesh, axis = table.mesh, table.axis

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(_specs_for(shuffled), P(axis)),
        out_specs=(P(axis), {c: P(axis) for c in value_cols}, P(axis)),
    )
    def _gb(cols, valid):
        k, sums, count = L.local_groupby_sum(cols, valid, key, value_cols,
                                             groups_cap_per_shard)
        return k, sums, count

    keys, sums, count = _gb(shuffled.columns, shuffled.valid)
    cols = {key: keys, **sums, "_count": count}
    return Table(cols, count > 0, mesh, axis), dropped


def reduce_sum(table: Table, cols: Sequence[str]) -> Dict[str, float]:
    mesh, axis = table.mesh, table.axis

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(_specs_for(table.project(list(cols))), P(axis)),
        out_specs={c: P() for c in cols},
    )
    def _red(c, valid):
        return {k: jax.lax.psum(jnp.sum(jnp.where(valid, v, 0)), axis)[None]
                for k, v in c.items()}

    out = _red(table.project(list(cols)).columns, table.valid)
    return {k: float(v[0]) for k, v in out.items()}
