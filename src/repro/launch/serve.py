"""Serving driver: a continuous-batching ServeEngine running as a
long-running *service stage* on the pilot runtime (the paper's inference
task kind living beside data engineering and training on one scheduler).

The engine prefills admitted prompts in ONE batched full-sequence forward
(no token-by-token replay), packs their KV rows into free slots of a
fixed ``[max_slots, max_len]`` cache, and fuses every occupied slot into
a single decode step.  The service stage holds its lease, is excluded
from the pipeline completion barrier, and yields to higher-priority
training work via checkpoint/resume preemption (see ``repro.serve``).

``--fleet N`` switches to the multi-engine gateway: an ``EngineRouter``
load-balances the same request stream over N engines (optionally
prefill/decode-disaggregated with ``--disaggregate``) and this driver
becomes a streaming front-end — it polls each request's live token list
and emits deltas as they land, the way a gateway would flush SSE chunks.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
      --batch 4 --prompt-len 32 --gen 16 [--slots 4]
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
      --batch 12 --fleet 3 --disaggregate
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.core import Session, stage
from repro.core.pilot import PilotDescription
from repro.serve import Request, ServeEngine


def _stream(requests, *, poll_s: float = 0.02, timeout: float = 600.0,
            quiet: bool = False) -> None:
    """Gateway-style streaming loop: ``Request.tokens`` is the live
    stream (the engine appends in place; ``_finish`` only stamps
    terminal state), so polling its length and flushing the delta is
    exactly what an SSE front-end would do per chunk."""
    seen = [0] * len(requests)
    deadline = time.time() + timeout
    while True:
        live = False
        for i, r in enumerate(requests):
            n = len(r.tokens)
            if n > seen[i] and not quiet:
                done = " done" if r.done() else ""
                print(f"[stream] {r.rid}: +{n - seen[i]} tok "
                      f"({n} total){done}", flush=True)
            seen[i] = n
            if not r.done():
                live = True
            elif r.error is not None:
                raise RuntimeError(f"{r.rid} failed: {r.error}")
        if not live:
            return
        if time.time() > deadline:
            raise RuntimeError("streaming front-end timed out")
        time.sleep(poll_s)


def run_fleet(args, cfg) -> dict:
    """Multi-engine gateway: EngineRouter over ``--fleet`` engines with
    load-aware admission; ``--disaggregate`` splits prefill/decode roles
    and migrates finished prompts by KV-page handoff."""
    from repro.serve import build_fleet

    slots = args.slots or min(args.batch, 4)
    max_len = args.prompt_len + args.gen + 1
    router = build_fleet(cfg, RunConfig(), num_engines=args.fleet,
                         disaggregate=args.disaggregate, seed=0,
                         max_slots=slots, max_len=max_len,
                         name_prefix="gateway")
    router.start()
    try:
        rng = np.random.default_rng(1)
        t0 = time.time()
        requests = [
            router.submit(Request(
                rng.integers(1, cfg.vocab_size, args.prompt_len),
                max_new_tokens=args.gen))
            for _ in range(args.batch)]
        _stream(requests, quiet=args.quiet)
        wall = time.time() - t0
        stats = router.stats()
    finally:
        router.close()
    n_tok = sum(len(r.tokens) for r in requests)
    ttft = sorted(r.ttft_s for r in requests)
    res = {
        "requests": len(requests),
        "engines": args.fleet,
        "disaggregate": args.disaggregate,
        "generated_tokens": n_tok,
        "tokens_per_s": n_tok / max(wall, 1e-9),
        "ttft_p50_s": ttft[len(ttft) // 2],
        "routed": stats.get("routed", 0),
        "handoffs": stats.get("handoffs_routed", 0),
        "router": stats,
    }
    spread = {k.split("routed_to.")[1]: v for k, v in stats.items()
              if k.startswith("routed_to.")}
    print(f"[serve] {cfg.name} fleet={args.fleet}"
          f"{' disaggregated' if args.disaggregate else ''}: "
          f"{res['tokens_per_s']:.1f} tok/s over {len(requests)} reqs; "
          f"p50 ttft {res['ttft_p50_s']*1e3:.0f}ms; routed {spread}"
          + (f"; handoffs {res['handoffs']}" if args.disaggregate else ""))
    return res


@stage(kind="inference", service=True, name="engine")
def engine_service(ctx, arch: str = "tinyllama-1.1b", smoke: bool = True,
                   max_slots: int = 4, max_len: int = 49, seed: int = 0):
    """Module-level service body: builds the ServeEngine INSIDE the
    executing process (the picklable-task contract for
    ``transport="subprocess"`` — a closure over a parent-side engine
    would capture unpicklable device buffers; see README
    "Cross-process execution")."""
    cfg = get_config(arch, smoke=smoke)
    engine = ServeEngine(cfg, RunConfig(), max_slots=max_slots,
                         max_len=max_len, seed=seed)
    return engine.run_service(ctx.control, resume_state=ctx.resume_state)


def run(args) -> dict:
    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encoder_decoder or cfg.input_kind == "embeds":
        raise SystemExit("serve driver targets token-LM archs")
    if args.fleet > 1 or args.disaggregate:
        if args.disaggregate and args.fleet < 2:
            raise SystemExit("--disaggregate needs --fleet >= 2")
        return run_fleet(args, cfg)
    slots = args.slots or min(args.batch, 4)
    max_len = args.prompt_len + args.gen + 1
    serve_stage = engine_service.bind(
        arch=args.arch, smoke=args.smoke, max_slots=slots, max_len=max_len,
        seed=0)

    # the Session's agents OWN their transports: close() drains the worker
    # pool, so the service lease is back before the pilot is recycled —
    # and close() runs on EVERY exit path (context manager), so a failed
    # serve task can no longer leak the pilot's devices
    with Session(pods=[PilotDescription(name="serve-pod")],
                 max_workers_per_pilot=2, transport=args.transport) as session:
        handle = session.serve(serve_stage, name="serve")

        rng = np.random.default_rng(1)
        t0 = time.time()
        requests = [
            handle.submit_request(Request(
                rng.integers(1, cfg.vocab_size, args.prompt_len),
                max_new_tokens=args.gen))
            for _ in range(args.batch)]
        task = handle.task
        deadline = time.time() + 600
        for r in requests:
            while not r.wait(timeout=1.0):
                # surface an engine failure immediately instead of letting
                # orphaned requests run the clock out
                if task.finalized and task.error:
                    raise RuntimeError(f"serve task failed: {task.error}")
                if time.time() > deadline:
                    raise RuntimeError(f"request {r.rid} did not finish")
        wall = time.time() - t0
        if not handle.stop(drain=True, timeout=60):
            raise RuntimeError("service stage did not drain")
        if task.error:
            raise RuntimeError(task.error)

        stats = task.result
        lat = sorted(r.latency_s for r in requests)
        ttft = sorted(r.ttft_s for r in requests)
        n_tok = sum(len(r.tokens) for r in requests)
        res = {
            "requests": len(requests),
            "generated_tokens": n_tok,
            "tokens_per_s": n_tok / max(wall, 1e-9),
            "latency_p50_s": lat[len(lat) // 2],
            "latency_max_s": lat[-1],
            "ttft_p50_s": ttft[len(ttft) // 2],
            "slot_occupancy": stats["slot_occupancy"],
            "engine": stats,
            "runtime_overheads": task.overhead_s,
            "preemptions": task.preemptions,
        }
        print(f"[serve] {cfg.name}: {res['tokens_per_s']:.1f} tok/s over "
              f"{len(requests)} reqs ({slots} slots, occupancy "
              f"{res['slot_occupancy']:.2f}); p50 latency "
              f"{res['latency_p50_s']*1e3:.0f}ms, p50 ttft "
              f"{res['ttft_p50_s']*1e3:.0f}ms; overheads {task.overhead_s}")
        return res


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests to submit")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--slots", type=int, default=0,
                    help="KV-cache slots (0 = min(batch, 4))")
    ap.add_argument("--fleet", type=int, default=1,
                    help="number of engines behind the router gateway")
    ap.add_argument("--disaggregate", action="store_true",
                    help="split the fleet into prefill/decode engines "
                         "joined by KV-page handoff")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-request streaming deltas")
    ap.add_argument("--transport", default="in-process",
                    choices=["in-process", "subprocess"],
                    help="where the service stage executes: this process, "
                         "or a worker daemon process with its own JAX "
                         "runtime (repro.core.exec)")
    return ap


if __name__ == "__main__":
    run(build_parser().parse_args())
