"""Batched serving driver: prefill once, decode tokens with a KV cache,
under the pilot runtime (the paper's inference-task kind).

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.params import init_params
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.core.agent import RemoteAgent
from repro.core.pilot import PilotDescription, PilotManager
from repro.core.task import TaskDescription
from repro.core.transport import InProcessTransport
from repro.models.lm import lm_apply
from repro.train.state import cache_specs, model_specs
from repro.train.step import make_decode_step


def run(args) -> dict:
    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encoder_decoder or cfg.input_kind == "embeds":
        raise SystemExit("serve driver targets token-LM archs")
    run_cfg = RunConfig()
    max_len = args.prompt_len + args.gen

    def serve_task(comm):
        params = init_params(jax.random.PRNGKey(0), model_specs(cfg))
        B = args.batch
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (B, args.prompt_len), 0, cfg.vocab_size
        )
        # prefill: run the full prompt once and collect the KV cache by
        # replaying tokens through the decode path (cache-building prefill)
        cache = init_params(jax.random.PRNGKey(2), cache_specs(cfg, B, max_len))
        decode = jax.jit(make_decode_step(cfg, run_cfg), donate_argnums=(2,))
        t0 = time.time()
        next_tok = prompts[:, :1]
        for t in range(args.prompt_len):
            tok = prompts[:, t:t + 1]
            next_tok, logits, cache = decode(
                params, tok, cache, jnp.asarray(t, jnp.int32))
        prefill_s = time.time() - t0
        # decode loop
        generated = []
        t0 = time.time()
        for t in range(args.gen):
            next_tok, logits, cache = decode(
                params, next_tok[:, None], cache,
                jnp.asarray(args.prompt_len + t, jnp.int32))
            generated.append(np.asarray(next_tok))
        jax.block_until_ready(logits)
        decode_s = time.time() - t0
        toks = np.stack(generated, axis=1)
        return {
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "tokens_per_s": args.gen * args.batch / max(decode_s, 1e-9),
            "generated_shape": list(toks.shape),
        }

    pm = PilotManager()
    agent = RemoteAgent(pm.submit_pilot(PilotDescription()),
                        transport=InProcessTransport(max_workers=1))
    task, = agent.submit([TaskDescription(name="serve", fn=serve_task,
                                          kind="inference")])
    if task.error:
        raise RuntimeError(task.error)
    res = task.result
    res["runtime_overheads"] = task.overhead_s
    print(f"[serve] {cfg.name}: prefill {res['prefill_s']:.2f}s, "
          f"decode {res['tokens_per_s']:.1f} tok/s "
          f"(batch {args.batch}); overheads {task.overhead_s}")
    return res


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    return ap


if __name__ == "__main__":
    run(build_parser().parse_args())
