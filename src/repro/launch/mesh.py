"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def _mesh(dev_array, axes):
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5 explicit-axes API
        return jax.sharding.Mesh(
            dev_array, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.sharding.Mesh(dev_array, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = (data, model) = 256 chips.
    Multi-pod: (2, 16, 16) = (pod, data, model) = 512 chips.

    The dry-run container exposes 512 host placeholder devices; the
    single-pod mesh uses the first 256 so both meshes build from one
    process.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)"
        )
    import numpy as np

    dev_array = np.asarray(devices).reshape(shape)
    return _mesh(dev_array, axes)


def make_mesh(shape, axes):
    """Small helper for tests / examples on few host devices."""
    import numpy as np

    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    dev_array = np.asarray(devices).reshape(shape)
    return _mesh(dev_array, axes)
