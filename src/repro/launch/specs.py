"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs`` returns (step_kind, args, in_specs) where ``args`` is the
tuple passed to the step function and ``in_specs`` the matching
PartitionSpec tree — weak-type-correct, shardable, zero allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.params import abstract_params
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.distributed.sharding import merge_rules, param_specs_tree, spec_for
from repro.train.state import abstract_train_state, cache_specs, train_state_specs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _batch_specs(cfg: ModelConfig, B: int, S: int, *, labels: bool):
    """(abstract batch dict, logical-axes dict)."""
    batch: Dict[str, Any] = {}
    axes: Dict[str, Tuple] = {}
    if cfg.is_encoder_decoder:
        dec = max(S // cfg.dec_len_ratio, 8)
        batch["frames"] = _sds((B, S, cfg.d_model), cfg.compute_dtype)
        axes["frames"] = ("act_batch", None, None)
        batch["tokens"] = _sds((B, dec), jnp.int32)
        axes["tokens"] = ("act_batch", None)
        if labels:
            batch["labels"] = _sds((B, dec), jnp.int32)
            axes["labels"] = ("act_batch", None)
        return batch, axes
    if cfg.input_kind == "embeds":
        batch["embeds"] = _sds((B, S, cfg.d_model), cfg.compute_dtype)
        axes["embeds"] = ("act_batch", None, None)
        if cfg.mrope_sections:
            batch["positions"] = _sds((B, S, 3), jnp.int32)
            axes["positions"] = ("act_batch", None, None)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
        axes["tokens"] = ("act_batch", None)
    if labels:
        batch["labels"] = _sds((B, S), jnp.int32)
        axes["labels"] = ("act_batch", None)
    return batch, axes


def _axes_to_specs(axes_tree, shapes_tree, mesh, rules):
    return jax.tree.map(
        lambda ax, s: spec_for(ax, s.shape, mesh, rules), axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def input_specs(
    cfg: ModelConfig, shape: ShapeConfig, run_cfg: RunConfig, mesh, rules=None
):
    """-> (kind, args_tuple, in_specs_tuple)."""
    overrides = dict(rules) if rules else {}
    if cfg.fsdp_over_pod:
        overrides["embed"] = ("pod", "data")
    rules = merge_rules(overrides)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        state = abstract_train_state(cfg, run_cfg)
        state_specs = param_specs_tree(train_state_specs(cfg, run_cfg), mesh, rules)
        batch, axes = _batch_specs(cfg, B, S, labels=True)
        batch_specs = {
            k: spec_for(axes[k], v.shape, mesh, rules) for k, v in batch.items()
        }
        out_specs = (state_specs, {"loss": P(), "grad_norm": P()})
        return "train", (state, batch), (state_specs, batch_specs), out_specs
    if shape.kind == "prefill":
        params = abstract_params(_params_only(cfg, run_cfg))
        p_specs = param_specs_tree(_params_only(cfg, run_cfg), mesh, rules)
        batch, axes = _batch_specs(cfg, B, S, labels=False)
        batch_specs = {
            k: spec_for(axes[k], v.shape, mesh, rules) for k, v in batch.items()
        }
        out_specs = spec_for(("act_batch", "act_vocab"), (B, cfg.padded_vocab), mesh, rules)
        return "prefill", (params, batch), (p_specs, batch_specs), out_specs
    if shape.kind == "decode":
        params = abstract_params(_params_only(cfg, run_cfg))
        p_specs = param_specs_tree(_params_only(cfg, run_cfg), mesh, rules)
        cspecs = cache_specs(cfg, B, S)
        cache = abstract_params(cspecs)
        c_specs = param_specs_tree(cspecs, mesh, rules)
        tokens = _sds((B, 1), jnp.int32)
        t_spec = spec_for(("act_batch", None), (B, 1), mesh, rules)
        clen = _sds((), jnp.int32)
        out_specs = (
            spec_for(("act_batch",), (B,), mesh, rules),
            spec_for(("act_batch", None, "act_vocab"), (B, 1, cfg.padded_vocab), mesh, rules),
            c_specs,
        )
        return (
            "decode",
            (params, tokens, cache, clen),
            (p_specs, t_spec, c_specs, P()),
            out_specs,
        )
    raise ValueError(shape.kind)


def _params_only(cfg: ModelConfig, run_cfg: RunConfig):
    return train_state_specs(cfg, run_cfg)["params"]
