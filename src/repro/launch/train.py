"""End-to-end Deep RC training driver (Session API).

The full paper pipeline as ONE stage graph under a Session:

  synthetic corpus -> Cylon-analogue Table (dedup/shuffle on a worker mesh)
  -> zero-copy Data Bridge -> LM train loop (pjit, microbatched, AdamW)
  -> async checkpointing (+restart) -> postprocess (eval perplexity)

Under ``--kind-pods`` the same graph runs with its data-engineering stage
placed on a data pod and its DL stages on a DL pod — per-stage placement,
the dependency edge crossing pilots.

CLI:
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
      --steps 50 --batch 8 --seq 128
  ... --arch tinyllama-1.1b --steps 300        # ~100M-class full run
  ... --resume                                  # restart from checkpoint
  ... --kind-pods                               # data vs DL kind-split pilots
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import store
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.core import Session, stage
from repro.core.pilot import PilotDescription, PilotManager
from repro.dataframe.table import Table
from repro.launch.mesh import make_mesh
from repro.train.state import init_train_state, train_state_specs
from repro.train.step import make_train_step
from repro.distributed.sharding import param_specs_tree, merge_rules


def make_corpus(vocab: int, n_tokens: int, seed: int = 0) -> np.ndarray:
    """Synthetic Zipf-ish corpus with local structure (learnable bigrams)."""
    rng = np.random.default_rng(seed)
    base = rng.zipf(1.3, size=n_tokens).clip(max=vocab - 1)
    # inject deterministic bigram structure so loss can actually drop
    base[1::2] = (base[::2][: len(base[1::2])] * 7 + 3) % vocab
    return base.astype(np.int32)


def run(args) -> dict:
    cfg = get_config(args.arch, smoke=args.smoke)
    run_cfg = RunConfig(num_microbatches=args.microbatches,
                        learning_rate=args.lr)
    ckpt_dir = args.ckpt_dir or os.path.join("results", "ckpt", cfg.name)

    # kind-aware pods: split the machine into a data-engineering pod and a
    # DL pod (PilotDescription(task_kinds=...)); the Session's placement
    # policy routes each STAGE to the pod admitting its kind, so the DAG
    # below stays ONE pipeline whose dependency edges cross pilots.
    # Falls back to one shared pod when the machine cannot back two pools.
    pm = PilotManager()  # inventory; the Session materializes pods lazily
    kind_pods = args.kind_pods and pm.free_devices() >= 2
    if kind_pods:
        n_data = max(1, pm.free_devices() // 4)
        pods = [
            PilotDescription(num_devices=n_data, name="pod-data",
                             task_kinds=("data_engineering",)),
            PilotDescription(name="pod-dl",
                             task_kinds=("train", "inference")),
        ]
    else:
        pods = None
    session = Session(manager=pm, pods=pods, max_workers_per_pilot=2)

    # the three stage bodies below close over the driver's args/cfg by
    # design and run on the Session's default in-process transport; they
    # are not subprocess-portable (PKL001 records that decision)
    @stage(kind="data_engineering")
    def preprocess(ctx):  # noqa: PKL001 — in-process driver stage
        corpus = make_corpus(cfg.vocab_size, args.batch * args.seq * (args.steps + 8))
        n_rows = len(corpus) // args.seq
        rows = corpus[: n_rows * args.seq].reshape(n_rows, args.seq)
        table = Table.from_columns(
            {"tokens": rows, "row_id": np.arange(n_rows, dtype=np.int32)}
        )
        return table

    @stage(kind="train", checkpoint=ckpt_dir)
    def train(ctx):  # noqa: PKL001 — in-process driver stage
        table = ctx.upstream["preprocess"]
        state = init_train_state(jax.random.PRNGKey(args.seed), cfg, run_cfg)
        start_step = 0
        # ctx.resume_step is threaded in by the agent on checkpoint-aware
        # retry (the stage declares checkpoint=); --resume covers the
        # cold-start case where the user restarts the whole driver
        resume_from = ctx.resume_step
        if resume_from is None and args.resume:
            resume_from = store.latest_step(ckpt_dir)
        if resume_from is not None:
            state = store.restore(ckpt_dir, state, step=resume_from)
            start_step = int(state["step"])
            print(f"[train] resumed from step {start_step}")
        step_fn = jax.jit(make_train_step(cfg, run_cfg), donate_argnums=(0,))
        ckpt = store.AsyncCheckpointer(ckpt_dir, keep=2)
        tokens = table.col("tokens")
        n_rows = tokens.shape[0]
        losses = []
        t0 = time.time()
        for i in range(start_step, args.steps):
            lo = (i * args.batch) % max(n_rows - args.batch, 1)
            chunk = jax.lax.dynamic_slice_in_dim(tokens, lo, args.batch, 0)
            batch = {"tokens": chunk, "labels": jnp.roll(chunk, -1, axis=1)}
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            if args.ckpt_every and (i + 1) % args.ckpt_every == 0:
                ckpt.save(i + 1, state)
            if (i + 1) % max(args.steps // 10, 1) == 0:
                dt = (time.time() - t0) / (i + 1 - start_step)
                print(f"[train] step {i+1}/{args.steps} loss={losses[-1]:.4f} "
                      f"({dt:.2f}s/step)", flush=True)
        ckpt.save(args.steps, state)
        ckpt.close()
        return {"losses": losses, "state_step": int(state["step"]),
                "train_s": time.time() - t0}

    @stage(kind="inference")
    def postprocess(ctx):  # noqa: PKL001 — in-process driver stage
        r = ctx.upstream["train"]
        first = np.mean(r["losses"][:5]) if len(r["losses"]) >= 5 else r["losses"][0]
        last = np.mean(r["losses"][-5:])
        return {"first_loss": float(first), "last_loss": float(last),
                "improved": bool(last < first), "train_s": r["train_s"],
                "steps": len(r["losses"])}

    # ONE pipeline regardless of pod layout: under --kind-pods the
    # preprocess stage resolves to pod-data and train/postprocess to
    # pod-dl, with the dependency edge crossing agents — no manual split,
    # no blocking handoff.  Session.close() (the context manager) recycles
    # agents AND pilots on every exit path, including failures.
    with session:
        pipe = session.start(preprocess >> train >> postprocess,
                             name=f"train-{cfg.name}")
        pipe.wait()
        if pipe.error is not None:
            raise RuntimeError(f"pipeline {pipe.name} {pipe.error}")
        out = pipe.results
    res = out["postprocess"]
    res["overheads"] = {k: v for k, v in pipe.tasks["train"].overhead_s.items()}
    res["placement"] = pipe.stage_placements()
    res["kind_pods"] = {p.uid: sorted(p.task_kinds) for p in session.pilots} \
        if kind_pods else None
    print(f"[deep-rc] {cfg.name}: loss {res['first_loss']:.4f} -> "
          f"{res['last_loss']:.4f} in {res['steps']} steps "
          f"({res['train_s']:.1f}s); runtime overheads: {res['overheads']}; "
          f"placement: {res['placement']}")
    return res


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--kind-pods", action="store_true",
                    help="split data-engineering vs DL stages onto "
                         "kind-specialised pilots (needs >= 2 devices)")
    return ap


if __name__ == "__main__":
    run(build_parser().parse_args())
