import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: ``lower().compile()`` every (arch x shape x mesh) cell
with ShapeDtypeStruct inputs (no allocation) and extract the roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh single --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Each cell records memory_analysis, my trip-count-aware HLO cost analysis
(FLOPs / bytes / collective bytes per device) and the collective schedule
into a JSON file consumed by benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import (  # noqa: E402
    ARCHS, SHAPES, default_run_config, get_config, shape_applicable,
)
from repro.distributed import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs  # noqa: E402
from repro.train.step import (  # noqa: E402
    make_decode_step, make_prefill_step, make_train_step,
)

# TPU v5e hardware model (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s/link
HBM_PER_CHIP = 16 * 1024**3


def run_cell(arch: str, shape_name: str, mesh_kind: str, overrides=None) -> dict:
    # the dry-run lowers the GSPMD-sharded jnp oracle for decode attention
    # (sequence-split partial-softmax + psum); the Pallas kernel path is
    # the single-host serving engine's (kernels/ops.py resolves it)
    cfg = get_config(arch).with_overrides(decode_impl="ref")
    if overrides:
        cfg = cfg.with_overrides(**overrides)
    shape = SHAPES[shape_name]
    run_cfg = default_run_config(arch, shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size

    kind, args, in_specs, out_specs = input_specs(cfg, shape, run_cfg, mesh)
    if kind == "train":
        step = make_train_step(cfg, run_cfg)
    elif kind == "prefill":
        step = make_prefill_step(cfg, run_cfg)
    else:
        step = make_decode_step(cfg, run_cfg)

    # donate the mutable aggregate (train state / decode cache) so input and
    # output buffers alias — halves steady-state HBM for train and decode
    donate = {"train": (0,), "prefill": (), "decode": (2,)}[kind]
    t0 = time.time()
    with jax.set_mesh(mesh):
        jitted = jax.jit(step, in_shardings=in_specs, out_shardings=out_specs,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    txt = compiled.as_text()
    cost = hlo_analysis.analyze(txt)
    xla_cost = compiled.cost_analysis() or {}

    per_dev_bytes = (
        mem.argument_size_in_bytes + mem.output_size_in_bytes
        + mem.temp_size_in_bytes - mem.alias_size_in_bytes
    )
    # XLA:CPU float-normalization allocates f32 copies of big bf16 buffers
    # (no native bf16 dot on host); a TPU compile would not.  Report both.
    f32_dup = hlo_analysis.cpu_f32_dup_bytes(txt)
    # clamp: the dup detector can over-match fusion-internal values; the
    # adjusted figure never drops below the live args+outputs
    floor = mem.argument_size_in_bytes + mem.output_size_in_bytes - mem.alias_size_in_bytes
    adj_bytes = max(per_dev_bytes - f32_dup, floor)
    flops_dev = cost["flops_per_device"]
    bytes_dev = cost["bytes_per_device"]
    coll_dev = cost["collective_bytes_per_device"]
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "kind": kind,
        "n_chips": n_chips,
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_bytes": per_dev_bytes,
            "cpu_f32_dup_bytes": f32_dup,
            "per_device_bytes_tpu_adjusted": adj_bytes,
            "fits_16gb": bool(per_dev_bytes <= HBM_PER_CHIP),
            "fits_16gb_tpu_adjusted": bool(adj_bytes <= HBM_PER_CHIP),
        },
        "cost": cost,
        "xla_flops_per_device_uncorrected": xla_cost.get("flops", -1.0),
        "roofline": {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_dev / ICI_BW,
        },
        "collective_schedule": hlo_analysis.collective_schedule(txt),
    }
    terms = result["roofline"]
    result["roofline"]["dominant"] = max(terms, key=lambda k: terms[k])
    return result


def cell_filename(arch, shape_name, mesh_kind):
    return f"{arch}__{shape_name}__{mesh_kind}.json"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--subprocess-per-cell", action="store_true",
                    help="isolate each cell in a child process (RSS containment)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides (perf experiments)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [
            (a, s, m)
            for a in ARCHS
            for s in SHAPES
            for m in meshes
            if shape_applicable(a, s)
        ]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch/--shape required unless --all")
        cells = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    for arch, shape_name, mesh_kind in cells:
        path = os.path.join(args.out, cell_filename(arch, shape_name, mesh_kind))
        if args.skip_existing and os.path.exists(path):
            print(f"skip {path}")
            continue
        if args.subprocess_per_cell:
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape_name, "--mesh", mesh_kind,
                "--out", args.out,
            ]
            if args.override:
                cmd += ["--override", args.override]
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                failures += 1
                print(f"FAIL (subprocess) {arch} {shape_name} {mesh_kind}")
                print(r.stdout[-2000:], r.stderr[-2000:])
            else:
                print(r.stdout.strip().splitlines()[-1] if r.stdout.strip() else "ok")
            continue
        t0 = time.time()
        try:
            overrides = json.loads(args.override) if args.override else None
            res = run_cell(arch, shape_name, mesh_kind, overrides)
        except Exception as e:  # noqa: BLE001 — record the failure, keep going
            res = {
                "arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "ok": False, "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
            failures += 1
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        status = "OK " if res.get("ok") else "FAIL"
        dom = res.get("roofline", {}).get("dominant", "-")
        print(
            f"{status} {arch:22s} {shape_name:12s} {mesh_kind:6s} "
            f"t={time.time()-t0:6.1f}s dominant={dom}",
            flush=True,
        )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
