"""Optimizers in pure JAX: AdamW (dtype-configurable states) and Adafactor
(factored second moment — what makes arctic-480b's optimizer fit HBM).

Both expose ``<name>_specs`` (Param trees for the dry-run / sharded init)
and ``<name>_init`` / ``<name>_update``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.common.params import Param, is_param
from repro.configs.base import RunConfig

PyTree = Any


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_specs(param_specs: PyTree, run_cfg: RunConfig) -> PyTree:
    dt = run_cfg.opt_state_dtype

    def per_param(p: Param):
        return {
            "m": Param(p.shape, p.axes, dt, init="zeros"),
            "v": Param(p.shape, p.axes, dt, init="zeros"),
        }

    return jax.tree.map(per_param, param_specs, is_leaf=is_param)


def adamw_update(
    grads: PyTree, opt_state: PyTree, params: PyTree, step: jnp.ndarray,
    run_cfg: RunConfig, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
):
    lr, wd = run_cfg.learning_rate, run_cfg.weight_decay
    t = step.astype(jnp.float32) + 1.0
    corr1 = 1.0 - b1 ** t
    corr2 = 1.0 - b2 ** t

    def upd(g, s, p):
        gf = g.astype(jnp.float32)
        m = b1 * s["m"].astype(jnp.float32) + (1 - b1) * gf
        v = b2 * s["v"].astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m / corr1
        vhat = v / corr2
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        dt = s["m"].dtype
        return new_p, {"m": m.astype(dt), "v": v.astype(dt)}

    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(opt_state)
    flat_p = jax.tree.leaves(params)
    new_p, new_s = zip(*[upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)])
    return jax.tree.unflatten(treedef, new_p), jax.tree.unflatten(treedef, new_s)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, 2018) — factored second moments
# ---------------------------------------------------------------------------


def _factored(p: Param) -> bool:
    return len(p.shape) >= 2 and p.shape[-1] >= 8 and p.shape[-2] >= 8


def adafactor_specs(param_specs: PyTree, run_cfg: RunConfig) -> PyTree:
    def per_param(p: Param):
        if _factored(p):
            return {
                "vr": Param(p.shape[:-1], p.axes[:-1], jnp.float32, init="zeros"),
                "vc": Param(p.shape[:-2] + p.shape[-1:], p.axes[:-2] + p.axes[-1:],
                            jnp.float32, init="zeros"),
            }
        return {"v": Param(p.shape, p.axes, jnp.float32, init="zeros")}

    return jax.tree.map(per_param, param_specs, is_leaf=is_param)


def adafactor_update(
    grads: PyTree, opt_state: PyTree, params: PyTree, step: jnp.ndarray,
    run_cfg: RunConfig, b2: float = 0.999, eps: float = 1e-30, clip: float = 1.0,
):
    lr = run_cfg.learning_rate

    def upd(g, s, p):
        # Keep tensor-sized math in the gradient dtype: the f32 upcast of a
        # multi-GB grad leaf (arctic's expert stacks) would spike HBM.  The
        # factored stats (vr/vc — tiny) stay f32; reductions accumulate f32
        # inside the fused reduce without materializing an f32 copy.
        g2_mean_r = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-1)
        if "vr" in s:
            g2_mean_c = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-2)
            vr = b2 * s["vr"] + (1 - b2) * (g2_mean_r + eps)
            vc = b2 * s["vc"] + (1 - b2) * (g2_mean_c + eps)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            precond = (vr[..., None] / denom[..., None]) * vc[..., None, :]
            scale = jax.lax.rsqrt(jnp.maximum(precond, eps)).astype(g.dtype)
            update = g * scale
            new_s = {"vr": vr, "vc": vc}
        else:
            v = b2 * s["v"] + (1 - b2) * (
                jnp.square(g.astype(jnp.float32)) + eps
            )
            update = g * jax.lax.rsqrt(jnp.maximum(v, eps)).astype(g.dtype)
            new_s = {"v": v}
        # update clipping (RMS) — reduction in f32, scaling in g dtype
        rms = jnp.sqrt(jnp.mean(jnp.square(update.astype(jnp.float32))) + eps)
        factor = (1.0 / jnp.maximum(1.0, rms / clip)).astype(g.dtype)
        new_p = (p - (lr * factor) * update.astype(p.dtype)).astype(p.dtype)
        return new_p, new_s

    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(opt_state)
    flat_p = jax.tree.leaves(params)
    new_p, new_s = zip(*[upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)])
    return jax.tree.unflatten(treedef, new_p), jax.tree.unflatten(treedef, new_s)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def opt_specs(param_specs: PyTree, run_cfg: RunConfig) -> PyTree:
    if run_cfg.optimizer == "adafactor":
        return adafactor_specs(param_specs, run_cfg)
    return adamw_specs(param_specs, run_cfg)


def opt_update(grads, opt_state, params, step, run_cfg: RunConfig):
    if run_cfg.optimizer == "adafactor":
        return adafactor_update(grads, opt_state, params, step, run_cfg)
    return adamw_update(grads, opt_state, params, step, run_cfg)
