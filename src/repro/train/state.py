"""Train-state construction (concrete + abstract) and sharding trees."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.params import (
    Param, abstract_params, init_params, is_param,
)
from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.sharding import param_specs_tree
from repro.models import encdec
from repro.models.lm import lm_cache_specs, lm_specs
from repro.train.optimizer import opt_specs


def model_specs(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return encdec.encdec_specs(cfg)
    return lm_specs(cfg)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.is_encoder_decoder:
        enc_len = max(max_len // cfg.dec_len_ratio, 1500)
        # decoder self-cache is max_len; cross cache fixed at whisper's 1500
        return encdec.encdec_cache_specs(cfg, batch, max_len, enc_len=1500)
    return lm_cache_specs(cfg, batch, max_len)


def train_state_specs(cfg: ModelConfig, run_cfg: RunConfig) -> Dict[str, Any]:
    p = model_specs(cfg)
    # parameters may be stored in a non-fp32 dtype (e.g. arctic bf16)
    p = jax.tree.map(
        lambda q: Param(q.shape, q.axes, cfg.param_dtype, q.init, q.scale),
        p, is_leaf=is_param,
    )
    return {
        "params": p,
        "opt": opt_specs(p, run_cfg),
        "step": Param((), (), jnp.int32, init="zeros"),
    }


def init_train_state(key: jax.Array, cfg: ModelConfig, run_cfg: RunConfig):
    return init_params(key, train_state_specs(cfg, run_cfg))


def abstract_train_state(cfg: ModelConfig, run_cfg: RunConfig):
    return abstract_params(train_state_specs(cfg, run_cfg))


def state_shardings(cfg: ModelConfig, run_cfg: RunConfig, mesh, rules=None):
    return param_specs_tree(train_state_specs(cfg, run_cfg), mesh, rules)
