"""train_step / serve_step factories.

``make_train_step`` builds the jit-able update: microbatched grad
accumulation (lax.scan), fp32 loss, global-norm clipping, AdamW/Adafactor,
optional int8 gradient compression on the DP all-reduce
(distributed/collectives.py).  ``make_prefill_step`` / ``make_decode_step``
build the serving steps: batched prefill (optionally writing the KV cache
in one full-sequence forward) and single-token decode (which also
greedy-samples; accepts per-slot cache lengths for continuous batching).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.sharding import constrain
from repro.models import encdec
from repro.models.lm import lm_apply
from repro.train.optimizer import opt_update

PyTree = Any


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """logits [B,S,V] (any float dtype), labels [B,S] int32 -> mean nats."""
    logits = constrain(logits.astype(jnp.float32), ("act_batch", None, "act_vocab"))
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - jax.lax.stop_gradient(m)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    label_logit = jnp.take_along_axis(shifted, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - label_logit)


def _forward_loss(cfg: ModelConfig, params, batch: Dict, remat: bool):
    if cfg.is_encoder_decoder:
        enc_out = encdec.encode(cfg, params, batch["frames"], remat=remat)
        logits = encdec.decode_train(cfg, params, enc_out, batch["tokens"], remat=remat)
        loss = cross_entropy(logits, batch["labels"])
        return loss, logits
    inputs = batch.get("tokens", batch.get("embeds"))
    positions = batch.get("positions")
    logits, _, aux = lm_apply(cfg, params, inputs, positions, remat=remat)
    loss = cross_entropy(logits, batch["labels"]) + 0.01 * aux
    return loss, logits


def make_loss_fn(cfg: ModelConfig, run_cfg: RunConfig):
    remat = run_cfg.remat != "none"

    def loss_fn(params, batch):
        loss, _ = _forward_loss(cfg, params, batch, remat)
        return loss

    return loss_fn


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-6))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), tree), norm


def make_train_step(cfg: ModelConfig, run_cfg: RunConfig):
    loss_fn = make_loss_fn(cfg, run_cfg)
    n_micro = run_cfg.num_microbatches

    def split_micro(batch):
        def rs(x):
            b = x.shape[0]
            y = x.reshape((n_micro, b // n_micro) + x.shape[1:])
            return y

        return jax.tree.map(rs, batch)

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params = state["params"]
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = split_micro(batch)
            acc_dt = cfg.grad_accum_dtype

            def acc_body(carry, mb):
                loss_acc, g_acc = carry
                mb = jax.tree.map(
                    lambda x: constrain(x, ("act_batch",) + (None,) * (x.ndim - 1)), mb
                )
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g = jax.tree.map(lambda a, b: (a + b.astype(acc_dt)).astype(acc_dt), g_acc, g)
                return (loss_acc + l, g), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (loss, grads), _ = jax.lax.scan(acc_body, (jnp.zeros(()), g0), micro)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)

        if run_cfg.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, run_cfg.grad_clip)
        else:
            gnorm = jnp.zeros(())
        new_params, new_opt = opt_update(
            grads, state["opt"], params, state["step"], run_cfg
        )
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ModelConfig, run_cfg: Optional[RunConfig] = None,
                      *, with_cache: bool = False, max_len: Optional[int] = None):
    """Prefill step factory.

    Default mode returns the last-position logits (what a serving system
    samples from) — returning the full [B,S,V] tensor would materialize
    hundreds of GB at 32k x 100k-vocab.

    ``with_cache=True`` builds the serving prefill: ONE jitted full-sequence
    causal forward (the flash/chunked pass, not a token-replay loop) that
    also writes the prompt's K/V into a fresh ``[B, max_len]`` cache.
    ``prefill_step(params, tokens, lengths)`` takes right-padded prompts
    ``tokens [B,P]`` with true lengths ``lengths [B]`` and returns
    ``(next_token [B], last_logits [B,V], cache)`` where ``last_logits`` is
    read at each row's final *valid* position.  Positions past a row's
    length hold junk K/V but sit beyond that row's cache length, so they
    are masked in every subsequent decode and overwritten as the row
    generates.  Token-LM archs with attention-family temporal blocks only
    (recurrent state caches need a step-scan prefill).
    """
    if with_cache:
        if cfg.is_encoder_decoder or cfg.input_kind != "tokens":
            raise NotImplementedError(
                "cache-writing prefill targets token-LM archs")
        if max_len is None:
            raise ValueError("with_cache=True requires max_len")
        from repro.configs.base import block_pattern
        from repro.models.lm import lm_cache_specs
        from repro.common.params import is_param

        head, unit, _, tail = block_pattern(cfg)
        kinds = {tk for tk, _ in (*head, *unit, *tail)}
        if not kinds <= {"attn", "mla"}:
            # 'local' is excluded: the windowed ring cache keeps the last
            # positions of the PADDED sequence, so right-padding junk from
            # shorter rows would land inside the attention window where
            # the per-slot length mask cannot exclude it
            raise NotImplementedError(
                f"cache-writing prefill supports full-attention blocks "
                f"only, got {sorted(kinds)} (recurrent state caches need a "
                f"step-scan prefill; windowed ring caches need per-row "
                f"length-aware writes)")

        def prefill_step(params, tokens: jnp.ndarray, lengths: jnp.ndarray):
            B, P = tokens.shape
            specs = lm_cache_specs(cfg, B, max_len)
            cache = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                 specs, is_leaf=is_param)
            # ragged cache-writing prefill at base 0: per-row lengths ride
            # as chunk_lens, so padding tokens never write K/V and each
            # row attends exactly its own prompt
            logits, new_cache, _ = lm_apply(
                cfg, params, tokens, None, cache,
                jnp.zeros((B,), jnp.int32),
                chunk_lens=lengths.astype(jnp.int32), remat=False)
            last = jnp.take_along_axis(
                logits, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1
            )[:, 0]
            last = constrain(last, ("act_batch", "act_vocab"))
            next_token = jnp.argmax(last, axis=-1).astype(jnp.int32)
            return next_token, last, new_cache

        return prefill_step

    def prefill_step(params, batch: Dict) -> jnp.ndarray:
        if cfg.is_encoder_decoder:
            enc_out = encdec.encode(cfg, params, batch["frames"], remat=False)
            logits = encdec.decode_train(cfg, params, enc_out, batch["tokens"],
                                         remat=False, last_only=True)
        else:
            inputs = batch.get("tokens", batch.get("embeds"))
            logits, _, _ = lm_apply(cfg, params, inputs, batch.get("positions"),
                                    remat=False, last_only=True)
        out = logits[:, -1, :]
        return constrain(out, ("act_batch", "act_vocab"))

    return prefill_step


def make_prefill_chunk_step(cfg: ModelConfig,
                            run_cfg: Optional[RunConfig] = None):
    """Chunked-prefill step factory (Sarathi-style serving prefill).

    ``chunk_step(params, tokens, base, chunk_lens, cache, block_table=None)``
    appends a ``[B, T]`` token slab into an EXISTING cache: row ``b``'s
    first ``chunk_lens[b]`` tokens land at offset ``base[b]`` (its cached
    prefix length) and attend the full warm prefix through the ragged
    prefill kernel — rows with ``chunk_lens[b] == 0`` are inert.  Works on
    both the contiguous slot cache and the paged pool (``block_table``
    selects paged).  Returns ``(next_token [B], last_logits [B, V],
    new_cache)`` with the last logits read at each row's final valid chunk
    position (junk for inert rows — callers gate on their own bookkeeping).
    Token-LM archs with full-attention temporal blocks only, mirroring
    ``make_prefill_step(with_cache=True)``.
    """
    if cfg.is_encoder_decoder or cfg.input_kind != "tokens":
        raise NotImplementedError(
            "chunked prefill targets token-LM archs")
    from repro.configs.base import block_pattern

    head, unit, _, tail = block_pattern(cfg)
    kinds = {tk for tk, _ in (*head, *unit, *tail)}
    if not kinds <= {"attn", "mla"}:
        raise NotImplementedError(
            f"chunked prefill supports full-attention blocks only, got "
            f"{sorted(kinds)} (recurrent state caches need a step-scan "
            f"prefill; windowed ring caches need per-row length-aware "
            f"writes)")

    def chunk_step(params, tokens, base, chunk_lens, cache,
                   block_table=None):
        base = jnp.asarray(base, jnp.int32)
        chunk_lens = jnp.asarray(chunk_lens, jnp.int32)
        logits, new_cache, _ = lm_apply(
            cfg, params, tokens, None, cache, base,
            block_table=block_table, chunk_lens=chunk_lens, remat=False)
        last = jnp.take_along_axis(
            logits, jnp.maximum(chunk_lens - 1, 0)[:, None, None], axis=1
        )[:, 0]
        last = constrain(last, ("act_batch", "act_vocab"))
        next_token = jnp.argmax(last, axis=-1).astype(jnp.int32)
        return next_token, last, new_cache

    return chunk_step


def make_decode_step(cfg: ModelConfig, run_cfg: Optional[RunConfig] = None):
    """One new token against a pre-filled KV cache.  ``block_table``
    ([B, max_pages] int32) selects the paged-cache path: ``cache`` then
    holds shared page pools (``lm_paged_cache_specs``) instead of
    contiguous per-row caches."""

    def decode_step(params, tokens, cache, cache_len, block_table=None):
        if cfg.is_encoder_decoder:
            logits, new_cache = encdec.decode_step(cfg, params, tokens, cache, cache_len)
        else:
            positions = None
            if cfg.mrope_sections:
                Bsz = tokens.shape[0]
                positions = jnp.broadcast_to(
                    cache_len[None, None, None], (Bsz, 1, 3)
                ).astype(jnp.int32)
            logits, new_cache, _ = lm_apply(
                cfg, params, tokens, positions, cache, cache_len,
                block_table=block_table, remat=False
            )
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, logits, new_cache

    return decode_step
