"""Broad-except lint (BLE001-equivalent, no ruff dependency).

Flags ``except:``, ``except Exception:`` and ``except BaseException:``
(alone or inside a tuple) unless the handler line carries
``# noqa: BLE001`` — the repo's marker for a deliberate isolation
boundary (task runner, service loop, observer fan-out).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional

from repro.analysis.findings import Finding, rel

_BROAD = {"Exception", "BaseException"}


def _broad_name(node: Optional[ast.expr]) -> Optional[str]:
    if node is None:
        return "bare except"
    if isinstance(node, ast.Name) and node.id in _BROAD:
        return node.id
    if isinstance(node, ast.Attribute) and node.attr in _BROAD:
        return node.attr
    if isinstance(node, ast.Tuple):
        for elt in node.elts:
            name = _broad_name(elt)
            if name is not None:
                return name
    return None


def check_file(path: Path, root: Path) -> List[Finding]:
    source = path.read_text()
    lines = source.splitlines()
    tree = ast.parse(source, filename=str(path))
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        name = _broad_name(node.type)
        if name is None:
            continue
        line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
        if "noqa: BLE001" in line:
            continue
        out.append(Finding(
            pass_name="excepts", rule="broad-except",
            file=rel(path, root), line=node.lineno,
            symbol=name,
            message=f"broad `except {name}` without `# noqa: BLE001` "
                    f"isolation-boundary marker",
        ))
    return out


def run(paths: List[Path], root: Path) -> List[Finding]:
    findings: List[Finding] = []
    for p in sorted(paths):
        findings.extend(check_file(p, root))
    return findings
