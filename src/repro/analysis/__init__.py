"""Static-analysis toolkit for the repro runtime.

Three analysis passes plus one lint, each producing :class:`Finding`
records that the CLI (``python -m repro.analysis``) diffs against a
checked-in baseline (``analysis-baseline.json``):

- ``locks``   — AST lock-discipline checker driven by ``# guarded-by:``
  declarations on shared attributes (see :mod:`repro.analysis.locks`),
  paired with a runtime lock-order recorder for tests
  (:mod:`repro.analysis.lockorder`).
- ``jit``     — call-graph walk rooted at every ``jax.jit``-ed function
  flagging host syncs, Python branches on traced values, and unhashable
  static args (:mod:`repro.analysis.jit_boundary`).
- ``kernels`` — ``jax.eval_shape`` abstract evaluation of the
  ``kernels/ops.py`` dispatch surface across the full config matrix and
  both KV layouts, no accelerator required
  (:mod:`repro.analysis.kernel_contracts`).
- ``excepts`` — rejects new broad ``except Exception`` handlers outside
  ``# noqa: BLE001``-annotated isolation boundaries
  (:mod:`repro.analysis.excepts`).
"""
from repro.analysis.findings import Finding, load_baseline, write_baseline

__all__ = ["Finding", "load_baseline", "write_baseline"]
