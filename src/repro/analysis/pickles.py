"""Picklable-task-contract pass.

Task functions can be handed to a cross-process transport
(:mod:`repro.core.exec`), where they travel to the worker **by
reference** — ``module.qualname`` resolved in a fresh interpreter.  Two
shapes break that silently at the submit site furthest from the
definition:

- a ``@stage``-decorated function **nested inside another function**
  (its qualname contains ``<locals>`` and it typically closes over the
  enclosing frame), and
- a ``lambda`` passed as the task body (``fn=lambda ...`` in a
  ``TaskDescription`` / ``Stage``, or as the first argument of a
  ``.submit(...)`` call on something named like a transport).

Both are fine for strictly in-process execution — mark the definition
line (or the decorator line) with ``# noqa: PKL001`` to record that the
stage is deliberately pinned to the in-process transport.  Unmarked
occurrences are findings: they make the surrounding driver silently
un-portable to ``transport="subprocess"``.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Set

from repro.analysis.findings import Finding, rel

_MARKER = "noqa: PKL001"
#: callables that consume a task body by keyword
_TASK_CTORS = {"TaskDescription", "Stage"}


def _is_stage_decorator(dec: ast.expr) -> bool:
    target = dec.func if isinstance(dec, ast.Call) else dec
    if isinstance(target, ast.Name):
        return target.id == "stage"
    if isinstance(target, ast.Attribute):
        return target.attr == "stage"
    return False


def _marked(node: ast.stmt, marked_lines: Set[int]) -> bool:
    """Marker accepted anywhere from the first decorator through the
    first body line (black may move the comment around the def)."""
    first = min([node.lineno] + [d.lineno for d in
                                 getattr(node, "decorator_list", [])])
    last = node.body[0].lineno if getattr(node, "body", None) else node.lineno
    return any(ln in marked_lines for ln in range(first, last + 1))


class _Checker(ast.NodeVisitor):
    def __init__(self, label: str, marked_lines: Set[int]):
        self.label = label
        self.marked = marked_lines
        self.findings: List[Finding] = []
        self._depth = 0  # function nesting depth

    def _visit_fn(self, node) -> None:
        if (self._depth > 0
                and any(_is_stage_decorator(d) for d in node.decorator_list)
                and not _marked(node, self.marked)):
            self.findings.append(Finding(
                pass_name="pickles", rule="stage-nested",
                file=self.label, line=node.lineno, symbol=node.name,
                message=f"`@stage` function `{node.name}` is nested inside "
                        "another function; it cannot cross a subprocess "
                        "transport (qualname has <locals>) — move it to "
                        "module level, or mark the def `# noqa: PKL001` "
                        "if the driver pins the in-process transport",
            ))
        self._depth += 1
        self.generic_visit(node)
        self._depth -= 1

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_Call(self, node: ast.Call) -> None:
        target = node.func
        name = target.id if isinstance(target, ast.Name) else (
            target.attr if isinstance(target, ast.Attribute) else None)
        lam = None
        if name in _TASK_CTORS:
            for kw in node.keywords:
                if kw.arg == "fn" and isinstance(kw.value, ast.Lambda):
                    lam = kw.value
        elif (name == "submit" and isinstance(target, ast.Attribute)
                and node.args and isinstance(node.args[0], ast.Lambda)):
            lam = node.args[0]
        if lam is not None and lam.lineno not in self.marked \
                and node.lineno not in self.marked:
            self.findings.append(Finding(
                pass_name="pickles", rule="lambda-task",
                file=self.label, line=lam.lineno, symbol=name,
                message=f"lambda passed as a task body to `{name}`; "
                        "lambdas cannot travel to a subprocess worker — "
                        "use a module-level function, or mark the line "
                        "`# noqa: PKL001` for in-process-only call sites",
            ))
        self.generic_visit(node)


def check_file(path: Path, root: Path) -> List[Finding]:
    source = path.read_text()
    marked = {i for i, line in enumerate(source.splitlines(), start=1)
              if _MARKER in line}
    checker = _Checker(rel(path, root), marked)
    checker.visit(ast.parse(source, filename=str(path)))
    return checker.findings


def run(paths: List[Path], root: Path) -> List[Finding]:
    findings: List[Finding] = []
    for p in sorted(paths):
        findings.extend(check_file(p, root))
    return findings
