"""Kernel contract pass — device-free shape/dtype verification.

``jax.eval_shape`` abstractly evaluates the serving steps that feed every
``kernels/ops.py`` dispatch (prefill -> flash_attention, chunked prefill
-> prefill_attention / prefill_attention_paged, decode ->
decode_attention / decode_attention_paged, rmsnorm throughout) across

- the full config matrix: all 11 ``configs/*`` modules (10 registered
  archs' smoke configs + the ``base`` default ``ModelConfig``),
- the power-of-two prefill/decode bucket grid the ServeEngine retraces
  over, and
- both KV layouts (contiguous ``lm_cache_specs`` and paged
  ``lm_paged_cache_specs``).

Contracts checked: ``next_token [B] int32``; ``last_logits [B, V]`` /
decode ``logits [B, 1, V]``; the returned cache tree preserves the spec
tree's structure, shapes and dtypes (a layout change would silently
retrace every step).  Archs outside the serving envelope (encoder-
decoder, embed-input, recurrent-state) must refuse with a clean
``NotImplementedError`` — any other exception is a finding.

BlockSpec grid-divisibility is mirrored statically from the Pallas
kernels: ``H_pad % KV_pad`` (GQA group packing in flash/decode index
maps), flash's ``S % block_q`` tiling for real sequence shapes, and
paged-pool coverage ``num_pages * page_size >= max_len``.
"""
from __future__ import annotations

import importlib
from typing import Any, Dict, List, Tuple

from repro.analysis.findings import Finding

_PREFILL_BUCKETS = (8, 16)        # powers of two, like ServeEngine buckets
_CHUNK_BUCKETS = (4, 8)           # chunked-prefill token buckets
_B = 2
_MAX_LEN = 32
_PAGE_SIZE = 8


def _finding(rule: str, symbol: str, message: str) -> Finding:
    return Finding(pass_name="kernels", rule=rule, file="", line=0,
                   symbol=symbol, message=message)


def config_matrix() -> List[Tuple[str, Any]]:
    """All 11 config modules: registered archs (smoke-sized) + base."""
    from repro.configs import ARCHS, ModelConfig

    out: List[Tuple[str, Any]] = []
    for arch in sorted(ARCHS):
        mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
        out.append((arch, mod.smoke_config()))
    out.append(("base", ModelConfig(
        name="base-default", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        attention="gqa", mlp_act="swiglu",
    )))
    return out


def _serving_supported(cfg) -> bool:
    from repro.configs.base import block_pattern

    if cfg.is_encoder_decoder or cfg.input_kind != "tokens":
        return False
    head, unit, _, tail = block_pattern(cfg)
    kinds = {tk for tk, _ in (*head, *unit, *tail)}
    return kinds <= {"attn", "mla"}


def _tree_sig(tree) -> List[Tuple[str, Tuple, str]]:
    import jax

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), tuple(leaf.shape), str(leaf.dtype))
            for path, leaf in flat]


def _sig_mismatch(expect, got) -> str:
    e, g = dict((k, (s, d)) for k, s, d in expect), dict(
        (k, (s, d)) for k, s, d in got)
    for k in sorted(set(e) | set(g)):
        if e.get(k) != g.get(k):
            return (f"cache leaf {k}: expected "
                    f"{e.get(k, 'missing')}, got {g.get(k, 'missing')}")
    return ""


def _check_supported(arch: str, cfg, findings: List[Finding]) -> None:
    import jax
    import jax.numpy as jnp

    from repro.common.params import abstract_params
    from repro.models.lm import lm_cache_specs, lm_paged_cache_specs, lm_specs
    from repro.train.step import (make_decode_step, make_prefill_chunk_step,
                                  make_prefill_step)

    sds = jax.ShapeDtypeStruct
    params = abstract_params(lm_specs(cfg))
    V = cfg.padded_vocab
    cache_abs = abstract_params(lm_cache_specs(cfg, _B, _MAX_LEN))
    cache_sig = _tree_sig(cache_abs)

    # prefill (writes the contiguous cache) across the bucket grid
    prefill = make_prefill_step(cfg, with_cache=True, max_len=_MAX_LEN)
    for P in _PREFILL_BUCKETS:
        label = f"{arch}/contiguous/prefill@P{P}"
        try:
            nt, lg, cache = jax.eval_shape(
                prefill, params, sds((_B, P), jnp.int32),
                sds((_B,), jnp.int32))
        except Exception as e:  # noqa: BLE001 - checker isolation boundary
            findings.append(_finding(
                "kernel-contract", label, f"abstract eval failed: {e!r}"))
            continue
        if tuple(nt.shape) != (_B,) or nt.dtype != jnp.int32:
            findings.append(_finding(
                "kernel-contract", label,
                f"next_token: expected [{_B}] int32, got "
                f"{tuple(nt.shape)} {nt.dtype}"))
        if tuple(lg.shape) != (_B, V):
            findings.append(_finding(
                "kernel-contract", label,
                f"last_logits: expected [{_B}, {V}], got {tuple(lg.shape)}"))
        bad = _sig_mismatch(cache_sig, _tree_sig(cache))
        if bad:
            findings.append(_finding("kernel-contract", label, bad))

    # decode, both KV layouts
    decode = make_decode_step(cfg)
    layouts = [("contiguous", cache_abs, None)]
    max_pages = _MAX_LEN // _PAGE_SIZE
    paged_abs = abstract_params(
        lm_paged_cache_specs(cfg, _B * max_pages, _PAGE_SIZE))
    layouts.append(
        ("paged", paged_abs, sds((_B, max_pages), jnp.int32)))
    for layout, cache_in, bt in layouts:
        label = f"{arch}/{layout}/decode"
        in_sig = _tree_sig(cache_in)
        try:
            nt, lg, nc = jax.eval_shape(
                decode, params, sds((_B, 1), jnp.int32), cache_in,
                sds((_B,), jnp.int32), bt)
        except Exception as e:  # noqa: BLE001 - checker isolation boundary
            findings.append(_finding(
                "kernel-contract", label, f"abstract eval failed: {e!r}"))
            continue
        if tuple(nt.shape) != (_B,) or nt.dtype != jnp.int32:
            findings.append(_finding(
                "kernel-contract", label,
                f"next_token: expected [{_B}] int32, got "
                f"{tuple(nt.shape)} {nt.dtype}"))
        if tuple(lg.shape) != (_B, 1, V):
            findings.append(_finding(
                "kernel-contract", label,
                f"decode logits: expected [{_B}, 1, {V}], got "
                f"{tuple(lg.shape)}"))
        bad = _sig_mismatch(in_sig, _tree_sig(nc))
        if bad:
            findings.append(_finding(
                "kernel-contract", label,
                f"decode must preserve the cache layout ({bad})"))

    # chunked prefill (ragged cache-writing append -> ops.prefill_attention
    # / prefill_attention_paged) across chunk buckets x both layouts
    chunk_step = make_prefill_chunk_step(cfg)
    for T in _CHUNK_BUCKETS:
        for layout, cache_in, bt in layouts:
            label = f"{arch}/{layout}/prefill_chunk@T{T}"
            in_sig = _tree_sig(cache_in)
            try:
                nt, lg, nc = jax.eval_shape(
                    chunk_step, params, sds((_B, T), jnp.int32),
                    sds((_B,), jnp.int32), sds((_B,), jnp.int32),
                    cache_in, bt)
            except Exception as e:  # noqa: BLE001 - checker isolation boundary
                findings.append(_finding(
                    "kernel-contract", label,
                    f"abstract eval failed: {e!r}"))
                continue
            if tuple(nt.shape) != (_B,) or nt.dtype != jnp.int32:
                findings.append(_finding(
                    "kernel-contract", label,
                    f"next_token: expected [{_B}] int32, got "
                    f"{tuple(nt.shape)} {nt.dtype}"))
            if tuple(lg.shape) != (_B, V):
                findings.append(_finding(
                    "kernel-contract", label,
                    f"last_logits: expected [{_B}, {V}], got "
                    f"{tuple(lg.shape)}"))
            bad = _sig_mismatch(in_sig, _tree_sig(nc))
            if bad:
                findings.append(_finding(
                    "kernel-contract", label,
                    f"chunked prefill must append in place, preserving "
                    f"the cache layout ({bad})"))


def _check_unsupported(arch: str, cfg, findings: List[Finding]) -> None:
    """Out-of-envelope archs must refuse cleanly, not mis-trace."""
    from repro.models.lm import lm_paged_cache_specs
    from repro.train.step import make_prefill_chunk_step, make_prefill_step

    for name, build in (
            ("prefill", lambda: make_prefill_step(
                cfg, with_cache=True, max_len=_MAX_LEN)),
            ("prefill_chunk", lambda: make_prefill_chunk_step(cfg))):
        try:
            build()
        except NotImplementedError:
            continue
        except Exception as e:  # noqa: BLE001 - checker isolation boundary
            findings.append(_finding(
                "kernel-contract", f"{arch}/contiguous/{name}",
                f"expected clean NotImplementedError refusal, got {e!r}"))
        else:
            findings.append(_finding(
                "kernel-contract", f"{arch}/contiguous/{name}",
                "cache-writing prefill must refuse non-token-LM / "
                "non-attention archs with NotImplementedError"))
    try:
        lm_paged_cache_specs(cfg, _B * (_MAX_LEN // _PAGE_SIZE), _PAGE_SIZE)
    except NotImplementedError:
        pass  # clean refusal: paged layout is attention-family only
    except Exception as e:  # noqa: BLE001 - checker isolation boundary
        findings.append(_finding(
            "kernel-contract", f"{arch}/paged/specs",
            f"expected NotImplementedError or success, got {e!r}"))


def blockspec_findings(arch: str, cfg) -> List[Finding]:
    """Static mirror of the Pallas BlockSpec/grid divisibility rules."""
    out: List[Finding] = []
    H, KV = cfg.padded_gqa()
    if KV == 0 or H % KV != 0:
        out.append(_finding(
            "blockspec", f"{arch}/gqa",
            f"padded head grid H={H}, KV={KV}: kernel index maps need "
            f"H %% KV == 0 (uniform GQA groups)"))
    # flash_attention S % block raggedness is no longer a finding: the
    # wrapper pads S to an lcm(block_q, block_k) multiple and masks the
    # tail keys inside the kernel (kv_len), so any S lowers correctly
    num_pages, page_size = _B * (_MAX_LEN // _PAGE_SIZE), _PAGE_SIZE
    if num_pages * page_size < _MAX_LEN:
        out.append(_finding(
            "blockspec", f"{arch}/paged-pool",
            f"page pool {num_pages}x{page_size} cannot cover "
            f"max_len={_MAX_LEN}"))
    return out


def run() -> List[Finding]:
    findings: List[Finding] = []
    for arch, cfg in config_matrix():
        findings.extend(blockspec_findings(arch, cfg))
        if _serving_supported(cfg):
            _check_supported(arch, cfg, findings)
        else:
            _check_unsupported(arch, cfg, findings)
    return findings
