"""Finding records and the baseline diff protocol.

A finding's *key* deliberately excludes the line number: edits above a
known (baselined) finding must not make it read as "new".  The committed
baseline is a JSON list of keys; the CLI exits dirty only when a finding's
key is absent from the baseline.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, List, Sequence, Set


@dataclass(frozen=True)
class Finding:
    pass_name: str   # locks | jit | kernels | excepts
    rule: str        # e.g. guarded-attr, host-sync, kernel-contract
    file: str        # repo-relative posix path ('' for matrix findings)
    line: int        # 1-based; 0 when not tied to a source line
    symbol: str      # Class.attr, function name, or config/layout key
    message: str

    def key(self) -> str:
        return f"{self.pass_name}:{self.rule}:{self.file}:{self.symbol}"

    def render(self) -> str:
        loc = f"{self.file}:{self.line}" if self.file else self.symbol
        return f"[{self.pass_name}/{self.rule}] {loc}: {self.symbol}: {self.message}"


@dataclass
class PassResult:
    name: str
    findings: List[Finding] = field(default_factory=list)
    stats: dict = field(default_factory=dict)


def load_baseline(path: Path) -> Set[str]:
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("findings", []))


def write_baseline(path: Path, findings: Iterable[Finding]) -> None:
    keys = sorted({f.key() for f in findings})
    path.write_text(json.dumps({"findings": keys}, indent=2) + "\n")


def diff_against_baseline(
    findings: Sequence[Finding], baseline: Set[str]
) -> tuple[List[Finding], Set[str]]:
    """Returns (new findings, stale baseline keys)."""
    keys = {f.key() for f in findings}
    new = [f for f in findings if f.key() not in baseline]
    stale = baseline - keys
    return new, stale


def rel(path: Path, root: Path) -> str:
    try:
        return path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return path.as_posix()
