"""Jit-boundary pass.

Builds a call graph rooted at every ``jax.jit``-ed function and checks
the code reachable under tracing for

- **host syncs** (rule ``host-sync``): ``.item()`` on a tracer,
  ``float()/int()/bool()/np.asarray()/np.array()`` applied to a traced
  value, and wall-clock reads (``time.time`` / ``perf_counter`` /
  ``monotonic``) anywhere in jit scope;
- **Python branching on traced values** (rule ``traced-branch``):
  ``if``/``while`` whose test depends on a tracer (``is None`` /
  membership tests and shape/dtype-derived values are static and
  exempt);
- **unhashable static args** (rule ``static-unhashable``): a
  ``static_argnames`` parameter fed a ``list``/``set``/``dict`` display
  at a call site (lists are unhashable -> retrace error at runtime).

Root discovery understands the repo's three idioms:
``@functools.partial(jax.jit, static_argnames=...)`` decorators,
direct ``jax.jit(fn)`` calls on local defs, and the factory pattern
``jax.jit(make_X(cfg, ...))`` — resolved through imports to ``make_X``'s
returned inner ``def``s (``make_decode_step``, ``make_prefill_step``,
``make_train_step``).

Tracedness is propagated interprocedurally: a function called with a
traced argument is analysed with those parameters traced (memoised).
Closure variables (``cfg``, ``run_cfg``, ``max_len``) are static, which
is what makes config-dependent Python dispatch legal under jit.

``# jit-ok`` on the offending line suppresses a finding.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.findings import Finding, rel

_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}
_TIME_FUNCS = {"time", "perf_counter", "monotonic", "process_time"}
_ARRAY_NS = {"jnp", "jax", "lax", "np_like"}
_STATIC_BUILTINS = {"len", "isinstance", "getattr", "hasattr", "type",
                    "range", "sorted", "min", "max", "enumerate", "zip",
                    "tuple", "list", "dict", "set", "str", "repr"}
_CAST_BUILTINS = {"float", "int", "bool", "complex"}


@dataclass
class _Module:
    name: str                       # dotted module path
    path: Path
    tree: ast.Module
    lines: List[str]
    functions: Dict[str, ast.FunctionDef] = field(default_factory=dict)
    # local alias -> ("module", dotted) or ("from", module, name)
    imports: Dict[str, Tuple] = field(default_factory=dict)
    # var name -> Call exprs assigned to it (``step = make_decode_step(...)``)
    var_calls: Dict[str, List[ast.Call]] = field(default_factory=dict)


def _index_module(name: str, path: Path) -> _Module:
    source = path.read_text()
    tree = ast.parse(source, filename=str(path))
    mod = _Module(name=name, path=path, tree=tree, lines=source.splitlines())
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name not in mod.functions:
            mod.functions[node.name] = node
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)):
            mod.var_calls.setdefault(node.targets[0].id, []).append(node.value)
    for node in tree.body:
        if isinstance(node, ast.Import):
            for a in node.names:
                mod.imports[a.asname or a.name.split(".")[0]] = ("module", a.name)
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                mod.imports[a.asname or a.name] = ("from", node.module, a.name)
    return mod


def _is_jax_jit(node: ast.expr) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == "jit"
            and isinstance(node.value, ast.Name) and node.value.id == "jax")


def _static_argnames(call: ast.Call) -> Set[str]:
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            if isinstance(kw.value, (ast.Tuple, ast.List)):
                for elt in kw.value.elts:
                    if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                        names.add(elt.value)
            elif isinstance(kw.value, ast.Constant) and isinstance(kw.value.value, str):
                names.add(kw.value.value)
    return names


def _returned_defs(fn: ast.FunctionDef) -> List[ast.FunctionDef]:
    """Inner defs that a factory returns (the actual jitted callables)."""
    local_defs: Dict[str, List[ast.FunctionDef]] = {}
    for n in ast.walk(fn):
        if isinstance(n, ast.FunctionDef) and n is not fn:
            local_defs.setdefault(n.name, []).append(n)
    returned = {node.value.id for node in ast.walk(fn)
                if isinstance(node, ast.Return)
                and isinstance(node.value, ast.Name)}
    out: List[ast.FunctionDef] = []
    for name in returned:
        # a factory may define several same-named variants on different
        # branches (make_prefill_step): every one is a jit root
        out.extend(local_defs.get(name, []))
    return out


class JitBoundaryPass:
    def __init__(self, files: Dict[str, Path], root: Path) -> None:
        self.root = root
        self.modules: Dict[str, _Module] = {
            name: _index_module(name, p) for name, p in files.items()
        }
        self.findings: List[Finding] = []
        self._seen_keys: Set[Tuple] = set()
        self._memo: Set[Tuple] = set()
        self._stack: Set[Tuple[str, int]] = set()

    # -- root discovery --------------------------------------------------
    def discover_roots(self) -> List[Tuple[_Module, ast.FunctionDef, Set[str]]]:
        roots: List[Tuple[_Module, ast.FunctionDef, Set[str]]] = []
        seen: Set[Tuple[str, int]] = set()

        def add(mod: _Module, fn: ast.FunctionDef, static: Set[str]) -> None:
            key = (mod.name, fn.lineno)
            if key not in seen:
                seen.add(key)
                roots.append((mod, fn, static))

        for mod in self.modules.values():
            # decorator form
            for fn in [n for n in ast.walk(mod.tree)
                       if isinstance(n, ast.FunctionDef)]:
                for dec in fn.decorator_list:
                    if _is_jax_jit(dec):
                        add(mod, fn, set())
                    elif (isinstance(dec, ast.Call)
                          and self._is_partial(dec.func, mod)
                          and dec.args and _is_jax_jit(dec.args[0])):
                        add(mod, fn, _static_argnames(dec))
            # call form: jax.jit(<Name>) / jax.jit(make_X(...))
            for call in [n for n in ast.walk(mod.tree)
                         if isinstance(n, ast.Call) and _is_jax_jit(n.func)]:
                if not call.args:
                    continue
                static = _static_argnames(call)
                target = call.args[0]
                if isinstance(target, ast.Name):
                    fn = mod.functions.get(target.id) or self._local_def(
                        mod, target.id)
                    if fn is not None:
                        add(mod, fn, static)
                    else:
                        # jax.jit(step) where step = make_X(...)
                        for assigned in mod.var_calls.get(target.id, ()):
                            factory = self._resolve_callable(
                                mod, assigned.func)
                            if factory is not None:
                                fmod, fdef = factory
                                for inner in _returned_defs(fdef):
                                    add(fmod, inner, static)
                elif isinstance(target, ast.Call):
                    factory = self._resolve_callable(mod, target.func)
                    if factory is not None:
                        fmod, fdef = factory
                        for inner in _returned_defs(fdef):
                            add(fmod, inner, static)
        return roots

    @staticmethod
    def _is_partial(func: ast.expr, mod: _Module) -> bool:
        if isinstance(func, ast.Name) and func.id == "partial":
            return True
        return (isinstance(func, ast.Attribute) and func.attr == "partial"
                and isinstance(func.value, ast.Name)
                and func.value.id == "functools")

    def _local_def(self, mod: _Module, name: str) -> Optional[ast.FunctionDef]:
        for n in ast.walk(mod.tree):
            if isinstance(n, ast.FunctionDef) and n.name == name:
                return n
        return None

    def _resolve_callable(
        self, mod: _Module, func: ast.expr
    ) -> Optional[Tuple[_Module, ast.FunctionDef]]:
        """Resolve a called name/attribute to (module, def) across imports."""
        if isinstance(func, ast.Name):
            if func.id in mod.functions:
                return (mod, mod.functions[func.id])
            imp = mod.imports.get(func.id)
            if imp and imp[0] == "from":
                target = self.modules.get(imp[1])
                if target and imp[2] in target.functions:
                    return (target, target.functions[imp[2]])
            # calling a variable bound to a factory's return value:
            # ``step = make_decode_step(cfg); ... step(params, ...)``
            for assigned in mod.var_calls.get(func.id, ()):
                if (isinstance(assigned.func, ast.Name)
                        and assigned.func.id == func.id):
                    continue  # self-referential rebind, e.g. f = f(...)
                factory = self._resolve_callable(mod, assigned.func)
                if factory is not None:
                    fmod, fdef = factory
                    inner = _returned_defs(fdef)
                    if inner:
                        return (fmod, inner[0])
        elif isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name):
            imp = mod.imports.get(func.value.id)
            modname = None
            if imp and imp[0] == "module":
                modname = imp[1]
            elif imp and imp[0] == "from":
                modname = f"{imp[1]}.{imp[2]}"
            if modname:
                target = self.modules.get(modname)
                if target and func.attr in target.functions:
                    return (target, target.functions[func.attr])
        return None

    # -- analysis --------------------------------------------------------
    def run(self) -> List[Finding]:
        for mod, fn, static in self.discover_roots():
            traced = {a.arg for a in fn.args.args + fn.args.kwonlyargs
                      if a.arg not in static and a.arg != "self"}
            self._analyze(mod, fn, traced)
            self._check_static_call_sites(mod, fn, static)
        return self.findings

    def _emit(self, mod: _Module, line: int, rule: str, symbol: str,
              message: str) -> None:
        if line <= len(mod.lines) and "# jit-ok" in mod.lines[line - 1]:
            return
        key = (mod.name, line, rule, symbol)
        if key in self._seen_keys:
            return
        self._seen_keys.add(key)
        self.findings.append(Finding(
            pass_name="jit", rule=rule, file=rel(mod.path, self.root),
            line=line, symbol=symbol, message=message))

    def _analyze(self, mod: _Module, fn: ast.FunctionDef,
                 traced_params: Set[str]) -> None:
        memo_key = (mod.name, fn.lineno, frozenset(traced_params))
        if memo_key in self._memo:
            return
        stack_key = (mod.name, fn.lineno)
        if stack_key in self._stack:
            return
        self._memo.add(memo_key)
        self._stack.add(stack_key)
        try:
            _FunctionAnalyzer(self, mod, fn, traced_params).run()
        finally:
            self._stack.discard(stack_key)

    def _check_static_call_sites(self, mod: _Module, fn: ast.FunctionDef,
                                 static: Set[str]) -> None:
        """Unhashable values bound to static params at call sites of the
        jitted function (by keyword, or positionally via the def)."""
        if not static:
            return
        pos_names = [a.arg for a in fn.args.args]
        for call in [n for n in ast.walk(mod.tree)
                     if isinstance(n, ast.Call)
                     and isinstance(n.func, ast.Name)
                     and n.func.id == fn.name]:
            bound: List[Tuple[str, ast.expr]] = []
            for i, arg in enumerate(call.args):
                if i < len(pos_names):
                    bound.append((pos_names[i], arg))
            for kw in call.keywords:
                if kw.arg:
                    bound.append((kw.arg, kw.value))
            for name, value in bound:
                if name in static and isinstance(
                        value, (ast.List, ast.Set, ast.Dict, ast.ListComp,
                                ast.SetComp, ast.DictComp)):
                    self._emit(
                        mod, value.lineno, "static-unhashable",
                        f"{fn.name}({name}=...)",
                        f"static arg `{name}` of jitted `{fn.name}` bound "
                        f"to an unhashable "
                        f"{type(value).__name__.lower().replace('comp', ' comprehension')} "
                        f"-> TypeError at trace time")


class _FunctionAnalyzer:
    """Single-function walk: propagates tracedness, reports findings,
    descends into resolvable callees that receive traced arguments."""

    def __init__(self, owner: JitBoundaryPass, mod: _Module,
                 fn: ast.FunctionDef, traced_params: Set[str],
                 local_defs: Optional[Dict[str, ast.FunctionDef]] = None) -> None:
        self.o = owner
        self.mod = mod
        self.fn = fn
        self.traced: Set[str] = set(traced_params)
        # closures defined in an enclosing scope remain callable here
        self.local_defs: Dict[str, ast.FunctionDef] = dict(local_defs or {})

    def run(self) -> None:
        # two passes so names assigned late but read in earlier loop
        # bodies still pick up tracedness
        for _ in range(2):
            for stmt in self.fn.body:
                self._stmt(stmt)

    # -- statements ------------------------------------------------------
    def _stmt(self, node: ast.stmt) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # closures are analysed at their call sites (with the real arg
            # tracedness) or, when passed as callbacks to scan/checkpoint
            # etc., with every parameter traced — see _call
            self.local_defs[node.name] = node
            return
        if isinstance(node, ast.Assign):
            t = self._expr(node.value)
            for tgt in node.targets:
                self._bind(tgt, t)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            self._bind(node.target, self._expr(node.value))
        elif isinstance(node, ast.AugAssign):
            t = self._expr(node.value) or self._expr(node.target)
            self._bind(node.target, t)
        elif isinstance(node, (ast.If, ast.While)):
            t = self._expr(node.test)
            if t and not self._exempt_test(node.test):
                self.o._emit(
                    self.mod, node.test.lineno, "traced-branch",
                    self.fn.name,
                    "Python `if`/`while` on a traced value inside jit "
                    "(use lax.cond/jnp.where, or hoist to a static arg)")
            for stmt in node.body + node.orelse:
                self._stmt(stmt)
        elif isinstance(node, ast.For):
            self._bind(node.target, self._expr(node.iter))
            for stmt in node.body + node.orelse:
                self._stmt(stmt)
        elif isinstance(node, (ast.With,)):
            for item in node.items:
                self._expr(item.context_expr)
            for stmt in node.body:
                self._stmt(stmt)
        elif isinstance(node, ast.Try):
            for stmt in node.body:
                self._stmt(stmt)
            for h in node.handlers:
                for stmt in h.body:
                    self._stmt(stmt)
            for stmt in node.orelse + node.finalbody:
                self._stmt(stmt)
        elif isinstance(node, ast.Return) and node.value is not None:
            self._expr(node.value)
        elif isinstance(node, ast.Expr):
            self._expr(node.value)
        elif isinstance(node, (ast.Assert,)):
            pass  # asserts on shapes are trace-time checks, fine
        elif isinstance(node, ast.Raise):
            pass

    def _bind(self, target: ast.expr, traced: bool) -> None:
        if isinstance(target, ast.Name):
            if traced:
                self.traced.add(target.id)
            else:
                self.traced.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, traced)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, traced)
        # subscript/attribute writes don't change name tracedness

    def _analyze_local(self, cdef: ast.FunctionDef,
                       traced_params: Optional[Set[str]]) -> None:
        """Analyse a closure.  ``traced_params=None`` = callback semantics
        (every parameter traced).  Closure variables inherit the enclosing
        scope's tracedness; sibling closures stay callable."""
        key = (self.mod.name, cdef.lineno)
        if key in self.o._stack:
            return
        params = {a.arg for a in cdef.args.args + cdef.args.kwonlyargs}
        if traced_params is None:
            traced_params = set(params)
        inherited = self.traced - params
        memo_key = (self.mod.name, cdef.lineno,
                    frozenset(traced_params | inherited))
        if memo_key in self.o._memo:
            return
        self.o._memo.add(memo_key)
        self.o._stack.add(key)
        try:
            sub = _FunctionAnalyzer(self.o, self.mod, cdef,
                                    traced_params | inherited,
                                    local_defs=self.local_defs)
            sub.run()
        finally:
            self.o._stack.discard(key)

    @staticmethod
    def _exempt_test(test: ast.expr) -> bool:
        if isinstance(test, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                for op in test.ops):
            return True
        if isinstance(test, ast.BoolOp):
            return all(_FunctionAnalyzer._exempt_test(v) for v in test.values)
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            return _FunctionAnalyzer._exempt_test(test.operand)
        return False

    # -- expressions -----------------------------------------------------
    def _expr(self, node: Optional[ast.expr]) -> bool:
        """Returns True if the expression's value is (possibly) traced,
        reporting findings encountered on the way."""
        if node is None or isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.traced
        if isinstance(node, ast.Attribute):
            base = self._expr(node.value)
            if node.attr in _STATIC_ATTRS:
                return False
            return base
        if isinstance(node, ast.Subscript):
            self._expr(node.slice)
            return self._expr(node.value)
        if isinstance(node, (ast.BinOp,)):
            return self._expr(node.left) | self._expr(node.right)
        if isinstance(node, ast.UnaryOp):
            return self._expr(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self._expr(v) for v in node.values)
        if isinstance(node, ast.Compare):
            t = self._expr(node.left)
            for c in node.comparators:
                t |= self._expr(c)
            # identity / pytree-membership tests on tracers produce static
            # Python bools (they inspect structure, not values)
            if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                   for op in node.ops):
                return False
            return t
        if isinstance(node, ast.IfExp):
            t = self._expr(node.test)
            return self._expr(node.body) | self._expr(node.orelse) | t
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self._expr(e) for e in node.elts)
        if isinstance(node, ast.Dict):
            return any(self._expr(v) for v in list(node.keys) + list(node.values)
                       if v is not None)
        if isinstance(node, ast.Starred):
            return self._expr(node.value)
        if isinstance(node, ast.Lambda):
            return False
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            t = any(self._expr(g.iter) for g in node.generators)
            for g in node.generators:
                self._bind(g.target, t)
            return self._expr(node.elt) | t
        if isinstance(node, ast.DictComp):
            t = any(self._expr(g.iter) for g in node.generators)
            for g in node.generators:
                self._bind(g.target, t)
            return self._expr(node.key) | self._expr(node.value) | t
        if isinstance(node, ast.JoinedStr):
            return False
        if isinstance(node, ast.Call):
            return self._call(node)
        return False

    def _call(self, node: ast.Call) -> bool:
        args_traced = [self._expr(a) for a in node.args]
        kw_traced = {kw.arg: self._expr(kw.value) for kw in node.keywords}
        any_traced = any(args_traced) or any(kw_traced.values())
        func = node.func

        # a closure passed as a callback (lax.scan body, jax.checkpoint,
        # cond branch): its parameters are tracers
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Name) and arg.id in self.local_defs:
                self._analyze_local(self.local_defs[arg.id], None)

        # direct call of a closure: map real arg tracedness onto params
        if isinstance(func, ast.Name) and func.id in self.local_defs:
            cdef = self.local_defs[func.id]
            params = [a.arg for a in cdef.args.args]
            traced_params = {params[i] for i, t in enumerate(args_traced)
                             if t and i < len(params)}
            traced_params |= {k for k, t in kw_traced.items() if t and k}
            self._analyze_local(cdef, traced_params)
            return any_traced

        # wall-clock reads are a host dependency no matter the args
        if (isinstance(func, ast.Attribute) and func.attr in _TIME_FUNCS
                and isinstance(func.value, ast.Name)
                and func.value.id == "time"):
            self.o._emit(self.mod, node.lineno, "host-sync", self.fn.name,
                         f"`time.{func.attr}()` inside jit scope traces to a "
                         f"constant (and forces nothing at run time)")
            return False

        if isinstance(func, ast.Attribute):
            # tracer.item() / tracer.tolist()
            if func.attr in ("item", "tolist") and self._expr(func.value):
                self.o._emit(self.mod, node.lineno, "host-sync", self.fn.name,
                             f"`.{func.attr}()` on a traced value blocks on "
                             f"device transfer (ConcretizationTypeError "
                             f"under jit)")
                return False
            # np.asarray / np.array on tracers
            if (isinstance(func.value, ast.Name) and func.value.id == "np"
                    and func.attr in ("asarray", "array") and any_traced):
                self.o._emit(self.mod, node.lineno, "host-sync", self.fn.name,
                             f"`np.{func.attr}` on a traced value pulls the "
                             f"tracer to host")
                return False
            # jnp./jax./lax. calls: fine, result traced
            base = func.value
            if isinstance(base, ast.Name) and base.id in _ARRAY_NS:
                return True
            if (isinstance(base, ast.Attribute)
                    and isinstance(base.value, ast.Name)
                    and base.value.id == "jax"):
                return True
            # method on a traced object (reshape/astype/at...) -> traced
            if self._expr(func.value):
                return True

        if isinstance(func, ast.Name):
            if func.id in _CAST_BUILTINS and any_traced:
                self.o._emit(self.mod, node.lineno, "host-sync", self.fn.name,
                             f"`{func.id}()` on a traced value forces "
                             f"concretization (ConcretizationTypeError "
                             f"under jit)")
                return False
            if func.id in _STATIC_BUILTINS:
                return False

        # descend into resolvable callees when they receive tracers
        resolved = self.o._resolve_callable(self.mod, func)
        if resolved is not None:
            cmod, cdef = resolved
            params = [a.arg for a in cdef.args.args]
            traced_params: Set[str] = set()
            for i, t in enumerate(args_traced):
                if t and i < len(params):
                    traced_params.add(params[i])
            for name, t in kw_traced.items():
                if t and name:
                    traced_params.add(name)
            if traced_params:
                self.o._analyze(cmod, cdef, traced_params)
            return any_traced
        return any_traced


def run(files: Dict[str, Path], root: Path) -> List[Finding]:
    return JitBoundaryPass(files, root).run()
