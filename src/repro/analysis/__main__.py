"""CLI: ``python -m repro.analysis [--baseline analysis-baseline.json]``.

Runs the lock-discipline, jit-boundary, kernel-contract and broad-except
passes and diffs the findings against the checked-in baseline.  Exit
status 0 = clean (no finding outside the baseline), 1 = dirty.  Stale
baseline keys (fixed findings still listed) are reported but do not fail
the run — prune them with ``--write-baseline``.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path
from typing import Dict, List

from repro.analysis import excepts, jit_boundary, kernel_contracts, locks, \
    pickles, timeouts
from repro.analysis.findings import (
    Finding,
    diff_against_baseline,
    load_baseline,
    write_baseline,
)

REPO_ROOT = Path(__file__).resolve().parents[3]
SRC_ROOT = REPO_ROOT / "src" / "repro"

# classes named in the lock-discipline contract live in these files
LOCK_FILES = [
    SRC_ROOT / "core" / "agent.py",
    SRC_ROOT / "core" / "exec" / "transport.py",
    SRC_ROOT / "core" / "exec" / "worker.py",
    SRC_ROOT / "core" / "pipeline.py",
    SRC_ROOT / "core" / "pilot.py",
    SRC_ROOT / "core" / "session.py",
    SRC_ROOT / "core" / "task.py",
    SRC_ROOT / "serve" / "engine.py",
    SRC_ROOT / "serve" / "router.py",
]

# blocking-call timeout discipline applies to the runtime files (the
# lock-discipline set plus the wire/persistence layers)
TIMEOUT_FILES = LOCK_FILES + [
    SRC_ROOT / "core" / "exec" / "protocol.py",
    SRC_ROOT / "core" / "transport.py",
    SRC_ROOT / "checkpoint" / "store.py",
]

ALL_PASSES = ("locks", "jit", "kernels", "excepts", "pickles", "timeouts")


def _src_modules() -> Dict[str, Path]:
    mods: Dict[str, Path] = {}
    for path in sorted(SRC_ROOT.rglob("*.py")):
        relparts = path.relative_to(SRC_ROOT.parent).with_suffix("").parts
        if relparts[-1] == "__init__":
            relparts = relparts[:-1]
        if not relparts:
            continue
        mods[".".join(relparts)] = path
    return mods


def run_passes(names) -> List[Finding]:
    findings: List[Finding] = []
    for name in names:
        t0 = time.perf_counter()
        if name == "locks":
            got = locks.run([p for p in LOCK_FILES if p.exists()], REPO_ROOT)
        elif name == "jit":
            got = jit_boundary.run(_src_modules(), REPO_ROOT)
        elif name == "kernels":
            got = kernel_contracts.run()
        elif name == "excepts":
            got = excepts.run(sorted(SRC_ROOT.rglob("*.py")), REPO_ROOT)
        elif name == "pickles":
            got = pickles.run(sorted(SRC_ROOT.rglob("*.py")), REPO_ROOT)
        elif name == "timeouts":
            got = timeouts.run([p for p in TIMEOUT_FILES if p.exists()],
                               REPO_ROOT)
        else:
            raise SystemExit(f"unknown pass {name!r}; known: {ALL_PASSES}")
        dt = time.perf_counter() - t0
        print(f"pass {name:8s}: {len(got)} finding(s) in {dt:.2f}s")
        findings.extend(got)
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--baseline", type=Path,
                    default=REPO_ROOT / "analysis-baseline.json")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept current findings as the new baseline")
    ap.add_argument("--passes", default=",".join(ALL_PASSES),
                    help=f"comma-separated subset of {ALL_PASSES}")
    args = ap.parse_args(argv)

    names = [n.strip() for n in args.passes.split(",") if n.strip()]
    findings = run_passes(names)

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"wrote {len({f.key() for f in findings})} key(s) to "
              f"{args.baseline}")
        return 0

    baseline = load_baseline(args.baseline)
    new, stale = diff_against_baseline(findings, baseline)
    for f in new:
        print(f"NEW {f.render()}")
    for key in sorted(stale):
        print(f"stale baseline entry (fixed? prune with --write-baseline): "
              f"{key}")
    if new:
        print(f"DIRTY: {len(new)} new finding(s) vs baseline "
              f"{args.baseline.name}")
        return 1
    print(f"clean: {len(findings)} finding(s), all baselined "
          f"({len(baseline)} baseline key(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
