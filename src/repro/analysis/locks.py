"""Lock-discipline pass.

Shared attributes are declared at their assignment site with an inline
comment::

    self._stats = {}  # guarded-by: _lock

(dataclass field lines work the same way).  Every subsequent read or
write of a declared attribute inside the class must then occur

- under ``with self.<lock>:`` (a ``threading.Condition`` counts — its
  context manager holds the underlying lock), or
- inside a method whose name ends in ``_locked``, or whose ``def`` line
  carries ``# caller-locked`` (the repo's convention for helpers that
  document "caller holds the lock"), or
- inside ``__init__``/``__post_init__`` (publication happens-before any
  cross-thread access).

Nested ``def``s reset the held-lock set — a closure handed to a thread,
callback list, or executor escapes the ``with`` block that created it.
``lambda``s inherit it: the repo uses them as immediate
``Condition.wait_for`` predicates that run under the lock.

``# lock-ok`` on an access line suppresses the finding (for accesses
that are safe for a reason the AST cannot see — e.g. reading a counter
for a log line where staleness is acceptable by design).
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Set

from repro.analysis.findings import Finding, rel

_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_]\w*)")
_LOCK_OK = "# lock-ok"
_CALLER_LOCKED = "# caller-locked"
_EXEMPT_METHODS = {"__init__", "__post_init__"}


def _comment_maps(source: str):
    guarded: Dict[int, str] = {}
    lock_ok: Set[int] = set()
    caller_locked: Set[int] = set()
    for i, line in enumerate(source.splitlines(), start=1):
        m = _GUARDED_RE.search(line)
        if m:
            guarded[i] = m.group(1)
        if _LOCK_OK in line:
            lock_ok.add(i)
        if _CALLER_LOCKED in line:
            caller_locked.add(i)
    return guarded, lock_ok, caller_locked


def _self_attr(node: ast.expr):
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name) and node.value.id == "self"):
        return node.attr
    return None


class _ClassChecker(ast.NodeVisitor):
    """Walks one method body tracking the set of held self-locks."""

    def __init__(self, cls_name: str, guarded_attrs: Dict[str, str],
                 lock_ok: Set[int], file_label: str):
        self.cls_name = cls_name
        self.guarded_attrs = guarded_attrs          # attr -> lock name
        self.lock_names = set(guarded_attrs.values())
        self.lock_ok = lock_ok
        self.file_label = file_label
        self.findings: List[Finding] = []
        self._held: Set[str] = set()

    # -- scope handling -------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        added: Set[str] = set()
        for item in node.items:
            attr = _self_attr(item.context_expr)
            if attr is not None and attr in self.lock_names:
                added.add(attr)
            else:
                self.visit(item.context_expr)
        prev = self._held
        self._held = prev | added
        for stmt in node.body:
            self.visit(stmt)
        self._held = prev

    def _visit_nested(self, node, reset: bool) -> None:
        prev = self._held
        if reset:
            self._held = set()
        self.generic_visit(node)
        self._held = prev

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_nested(node, reset=node.lineno not in self.lock_ok)

    def visit_AsyncFunctionDef(self, node) -> None:
        self._visit_nested(node, reset=node.lineno not in self.lock_ok)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        # wait_for predicates run under the Condition's lock
        self._visit_nested(node, reset=False)

    # -- access detection -----------------------------------------------
    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = _self_attr(node)
        if attr is not None and attr in self.guarded_attrs:
            lock = self.guarded_attrs[attr]
            if lock not in self._held and node.lineno not in self.lock_ok:
                self.findings.append(Finding(
                    pass_name="locks", rule="guarded-attr",
                    file=self.file_label, line=node.lineno,
                    symbol=f"{self.cls_name}.{attr}",
                    message=f"access to `self.{attr}` (guarded-by: {lock}) "
                            f"without holding `self.{lock}`",
                ))
        self.generic_visit(node)


def _collect_guarded(cls: ast.ClassDef, guarded_lines: Dict[int, str]) -> Dict[str, str]:
    """attr name -> lock name, from declaration comments anywhere in the class."""
    out: Dict[str, str] = {}
    for node in ast.walk(cls):
        if isinstance(node, ast.AnnAssign):
            targets = [node.target]
        elif isinstance(node, ast.Assign):
            targets = node.targets
        else:
            continue
        lock = guarded_lines.get(node.lineno)
        if lock is None:
            continue
        for t in targets:
            attr = _self_attr(t)
            if attr is not None:
                out[attr] = lock
            elif isinstance(t, ast.Name):      # dataclass field line
                out[t.id] = lock
    return out


def check_file(path: Path, root: Path, classes: Set[str] | None = None) -> List[Finding]:
    source = path.read_text()
    guarded_lines, lock_ok, caller_locked = _comment_maps(source)
    tree = ast.parse(source, filename=str(path))
    label = rel(path, root)
    findings: List[Finding] = []
    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        if classes is not None and cls.name not in classes:
            continue
        guarded = _collect_guarded(cls, guarded_lines)
        if not guarded:
            continue
        for meth in cls.body:
            if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if meth.name in _EXEMPT_METHODS or meth.name.endswith("_locked"):
                continue
            def_lines = range(meth.lineno, meth.body[0].lineno + 1)
            if any(ln in caller_locked for ln in def_lines):
                continue
            checker = _ClassChecker(cls.name, guarded, lock_ok, label)
            for stmt in meth.body:
                checker.visit(stmt)
            findings.extend(checker.findings)
    return findings


def run(paths: List[Path], root: Path) -> List[Finding]:
    findings: List[Finding] = []
    for p in sorted(paths):
        findings.extend(check_file(p, root))
    return findings
