"""Unbounded-blocking lint (TMO001): blocking calls need timeouts.

Every hang the resilience layer guards against (a stalled worker, a
dropped RPC reply, a crashed engine holding waiters) turns into a
*deadlock* if the waiting side blocks forever.  This pass walks the
runtime files and flags blocking calls issued with **no timeout**:

- ``Condition.wait()`` / ``Event.wait()`` / ``Request.wait()`` /
  ``Task.wait()`` — any zero-argument ``.wait()``;
- ``Future.result()`` — zero-argument ``.result()``;
- ``Thread.join()`` / ``Process.join()`` — zero-argument ``.join()``
  (a ``str.join`` always takes its iterable, so it never matches);
- ``Channel.recv()`` / ``socket.recv`` — zero-argument ``.recv()``;
- ``ServiceControl.wait_for_work()`` — zero-argument;
- any of the above called with an explicit ``timeout=None``.

A deliberately unbounded wait (a worker's main RPC read loop, a parked
engine waiting for its restart signal) is annotated with
``# noqa: TMO001`` on the call line, mirroring the broad-except pass's
``# noqa: BLE001`` marker; everything else must pass a timeout so the
enclosing retry/deadline policy can actually fire.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional

from repro.analysis.findings import Finding, rel

#: method names that block until an event that may never come
_BLOCKING = {"wait", "result", "join", "recv", "wait_for_work"}


def _timeout_of(call: ast.Call) -> Optional[ast.expr]:
    """The expression bounding the call, or None when unbounded.

    The blocking APIs above all take the timeout as their first
    positional argument or as ``timeout=``.
    """
    if call.args:
        return call.args[0]
    for kw in call.keywords:
        if kw.arg == "timeout":
            return kw.value
    return None


def _is_none(node: Optional[ast.expr]) -> bool:
    return (node is None
            or (isinstance(node, ast.Constant) and node.value is None))


def check_file(path: Path, root: Path) -> List[Finding]:
    source = path.read_text()
    lines = source.splitlines()
    tree = ast.parse(source, filename=str(path))
    out: List[Finding] = []
    # map every call to its enclosing function for a stable symbol
    parents: dict = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        if not isinstance(func, ast.Attribute) or func.attr not in _BLOCKING:
            continue
        if not _is_none(_timeout_of(node)):
            continue
        line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
        if "noqa: TMO001" in line:
            continue
        scope = node
        while scope in parents and not isinstance(
                scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scope = parents[scope]
        fn_name = (scope.name if isinstance(
            scope, (ast.FunctionDef, ast.AsyncFunctionDef)) else "<module>")
        try:
            call_text = ast.unparse(func)
        except Exception:  # noqa: BLE001 — lint must not die on odd AST
            call_text = func.attr
        out.append(Finding(
            pass_name="timeouts", rule="unbounded-blocking",
            file=rel(path, root), line=node.lineno,
            symbol=f"{fn_name}:{call_text}",
            message=f"`{call_text}()` blocks with no timeout — pass one "
                    f"(or mark a deliberate unbounded wait with "
                    f"`# noqa: TMO001`)",
        ))
    return out


def run(paths: List[Path], root: Path) -> List[Finding]:
    findings: List[Finding] = []
    for p in sorted(paths):
        findings.extend(check_file(p, root))
    return findings
