"""Runtime lock-order recorder (enabled under tests).

Wraps ``threading.Lock``/``threading.Condition`` instances in recording
proxies.  Every acquisition while other recorded locks are held adds a
directed edge ``held -> acquiring``; a cycle in that graph is a
lock-order inversion — two threads that interleave the other way
deadlock.  Recording is cheap enough for tests but is NOT installed in
production paths: tests call :func:`instrument` (or
:func:`instrument_runtime`) on the objects they drive.

The proxy forwards the full Condition protocol (``wait`` / ``wait_for``
/ ``notify`` / ``notify_all``); ``wait`` blocks the thread, so the held
set needs no adjustment across the internal release/reacquire.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Set, Tuple


class LockOrderRecorder:
    def __init__(self) -> None:
        self._meta = threading.Lock()
        self._edges: Dict[str, Set[str]] = {}
        self._sites: Dict[Tuple[str, str], str] = {}
        self._tls = threading.local()

    # -- proxy callbacks -------------------------------------------------
    def _held(self) -> List[str]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    def on_acquired(self, name: str) -> None:
        held = self._held()
        with self._meta:
            for h in held:
                if h != name:
                    self._edges.setdefault(h, set()).add(name)
        held.append(name)

    def on_released(self, name: str) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] == name:
                del held[i]
                break

    # -- instrumentation -------------------------------------------------
    def wrap(self, lock, name: str) -> "_RecordingLock":
        return _RecordingLock(lock, name, self)

    def instrument(self, obj, attr: str, name: Optional[str] = None) -> None:
        """Swap ``obj.<attr>`` (a Lock or Condition) for a recording proxy."""
        setattr(obj, attr, self.wrap(getattr(obj, attr),
                                     name or f"{type(obj).__name__}.{attr}"))

    # -- queries ---------------------------------------------------------
    def edges(self) -> Dict[str, Set[str]]:
        with self._meta:
            return {k: set(v) for k, v in self._edges.items()}

    def cycles(self) -> List[List[str]]:
        """All elementary cycles reachable in the order graph (DFS)."""
        edges = self.edges()
        cycles: List[List[str]] = []
        seen_keys: Set[Tuple[str, ...]] = set()

        def dfs(node: str, path: List[str], on_path: Set[str]) -> None:
            for nxt in sorted(edges.get(node, ())):
                if nxt in on_path:
                    cyc = path[path.index(nxt):] + [nxt]
                    # canonicalise by rotating to the smallest element
                    body = cyc[:-1]
                    i = body.index(min(body))
                    key = tuple(body[i:] + body[:i])
                    if key not in seen_keys:
                        seen_keys.add(key)
                        cycles.append(list(key) + [key[0]])
                elif nxt not in path:
                    dfs(nxt, path + [nxt], on_path | {nxt})

        for start in sorted(edges):
            dfs(start, [start], {start})
        return cycles

    def assert_no_cycles(self) -> None:
        cycles = self.cycles()
        if cycles:
            pretty = "; ".join(" -> ".join(c) for c in cycles)
            raise AssertionError(f"lock-order cycle(s) recorded: {pretty}")


class _RecordingLock:
    """Proxy over a Lock or Condition that reports to a recorder."""

    def __init__(self, inner, name: str, recorder: LockOrderRecorder) -> None:
        self._inner = inner
        self._name = name
        self._recorder = recorder

    def acquire(self, *args, **kwargs):
        got = self._inner.acquire(*args, **kwargs)
        if got:
            self._recorder.on_acquired(self._name)
        return got

    def release(self) -> None:
        self._inner.release()
        self._recorder.on_released(self._name)

    def __enter__(self):
        self._inner.__enter__()
        self._recorder.on_acquired(self._name)
        return self

    def __exit__(self, *exc) -> bool:
        self._recorder.on_released(self._name)
        return bool(self._inner.__exit__(*exc))

    # Condition protocol — the underlying wait() releases and reacquires
    # the inner lock while this thread is blocked, so the recorded held
    # set is accurate again by the time wait() returns.
    def wait(self, timeout: Optional[float] = None):
        return self._inner.wait(timeout)

    def wait_for(self, predicate, timeout: Optional[float] = None):
        return self._inner.wait_for(predicate, timeout)

    def notify(self, n: int = 1) -> None:
        self._inner.notify(n)

    def notify_all(self) -> None:
        self._inner.notify_all()

    def locked(self) -> bool:
        return self._inner.locked()

    def __repr__(self) -> str:
        return f"<recorded {self._name} {self._inner!r}>"


def instrument_runtime(recorder: LockOrderRecorder, *, agent=None,
                       pipeline=None, manager=None, session=None,
                       engine=None) -> None:
    """Instrument the runtime's lock sites across the agent <-> pipeline
    <-> pilot boundary (and optionally session/engine)."""
    if agent is not None:
        recorder.instrument(agent, "_cond", "agent._cond")
        recorder.instrument(agent, "_result_lock", "agent._result_lock")
    if pipeline is not None:
        recorder.instrument(pipeline, "_lock", "pipeline._lock")
    if manager is not None:
        recorder.instrument(manager, "_lock", "manager._lock")
        for pilot in getattr(manager, "pilots", []):
            recorder.instrument(pilot, "_lock", f"pilot[{pilot.uid}]._lock")
    if session is not None:
        recorder.instrument(session, "_lock", "session._lock")
    if engine is not None:
        recorder.instrument(engine, "_lock", "engine._lock")
