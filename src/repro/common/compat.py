"""Version compatibility shims for the jax API surface this repo spans.

The container pins jax 0.4.x; newer call sites are gated here so the same
source runs on both the pinned toolchain and current releases.
"""
from __future__ import annotations

import jax


def axis_size(name: str) -> int:
    """Static size of a mapped axis inside shard_map/pmap tracing.

    ``jax.lax.axis_size`` only exists on newer jax; on 0.4.x the
    long-standing idiom ``psum(1, axis)`` constant-folds to the axis size
    (no collective is emitted for a non-tracer operand).
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


@jax.custom_jvp
def optimization_barrier(x):
    """Differentiable ``lax.optimization_barrier``.

    jax 0.4.x ships the primitive without an AD rule, so taking gradients
    through a barriered residual raises NotImplementedError.  The barrier
    only needs to pin the primal (saved-residual) values; tangents pass
    through untouched, which also makes the JVP trivially transposable for
    reverse mode.
    """
    return jax.lax.optimization_barrier(x)


@optimization_barrier.defjvp
def _optimization_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return jax.lax.optimization_barrier(x), t


def current_mesh():
    """The mesh of the enclosing jit/mesh context, or None.

    ``jax.sharding.get_abstract_mesh`` is jax >= 0.5; on 0.4.x the active
    physical mesh lives on the thread-resources env.
    """
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    try:
        from jax._src.mesh import thread_resources
        return thread_resources.env.physical_mesh
    except Exception:  # noqa: BLE001 — private-API fallback only
        return None
