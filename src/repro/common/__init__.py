from repro.common.params import (  # noqa: F401
    Param,
    abstract_params,
    init_params,
    is_param,
    map_params,
    param_bytes,
    param_count,
)
