"""Parameter-spec system.

Models declare their parameters as a pytree of :class:`Param` leaves, each
carrying a shape, dtype, *logical axis names* and an initializer tag.  The
same tree drives three things:

* ``init_params``      — materialize values (for smoke tests / examples),
* ``abstract_params``  — ShapeDtypeStructs (for the AOT dry-run; no memory),
* ``logical_to_mesh``  — PartitionSpecs via the sharding rules
  (:mod:`repro.distributed.sharding`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Param:
    """Declaration of a single parameter tensor."""

    shape: tuple
    axes: tuple  # logical axis name (or None) per dim; len == len(shape)
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | embed | scaled
    scale: Optional[float] = None  # stddev override for normal/scaled

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"Param axes {self.axes} rank mismatch vs shape {self.shape}"
            )


def _fan_in(shape: tuple) -> int:
    # the contraction dim is by convention the second-to-last for matrices,
    # the last dim is the output.  For vectors there is no fan-in.
    if len(shape) <= 1:
        return 1
    return int(np.prod(shape[:-1]))


def _init_one(key: jax.Array, p: Param) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    if p.init == "embed":
        std = p.scale if p.scale is not None else 0.02
        return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(p.dtype)
    if p.init in ("normal", "scaled"):
        std = p.scale if p.scale is not None else 1.0 / math.sqrt(max(_fan_in(p.shape), 1))
        return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(p.dtype)
    raise ValueError(f"unknown init {p.init}")


def is_param(x: Any) -> bool:
    return isinstance(x, Param)


def init_params(key: jax.Array, specs: PyTree) -> PyTree:
    """Materialize a Param tree into concrete arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_param)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, p) for k, p in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs: PyTree) -> PyTree:
    """Param tree -> ShapeDtypeStruct tree (zero allocation)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), specs, is_leaf=is_param
    )


def param_count(specs: PyTree) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_param)
    return int(sum(np.prod(p.shape) for p in leaves))


def param_bytes(specs: PyTree) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_param)
    return int(sum(np.prod(p.shape) * np.dtype(p.dtype).itemsize for p in leaves))


def map_params(fn: Callable[[Param], Any], specs: PyTree) -> PyTree:
    return jax.tree.map(fn, specs, is_leaf=is_param)
