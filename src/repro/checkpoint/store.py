"""Sharded checkpoint store: save/restore train state for
checkpoint/restart and *elastic* restart (restore onto a different mesh).

Layout: ``<dir>/step_<N>/manifest.json`` + one ``<leaf>.npy.zst`` per
pytree leaf (zstd-compressed).  Per-leaf files bound writer memory and
let a restore reshard leaf-by-leaf onto a new mesh — the moral equivalent
of an OCDBT/array-store layout at container scale.  ``AsyncCheckpointer``
snapshots device arrays to host, then writes on a background thread so
the train loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import queue
import re
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

try:
    import zstandard
except ImportError:  # container image without python-zstandard
    zstandard = None
import zlib

PyTree = Any


def _compress(data: bytes) -> tuple:
    if zstandard is not None:
        return "zstd", zstandard.ZstdCompressor(level=3).compress(data)
    return "zlib", zlib.compress(data, 3)


def _decompress(codec: str, buf: bytes) -> bytes:
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError(
                "checkpoint was written with zstd but zstandard is not installed")
        return zstandard.ZstdDecompressor().decompress(buf)
    if codec == "zlib":
        return zlib.decompress(buf)
    raise ValueError(f"unknown checkpoint codec {codec!r}")

_SEP = "__"


def _flatten(tree: PyTree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(directory: str, step: int, state: PyTree) -> str:
    """Synchronous save. Returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    manifest = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        codec, payload = _compress(arr.tobytes(order="C"))
        fn = re.sub(r"[^\w.\-]", "_", key) + (
            ".npy.zst" if codec == "zstd" else ".npy.zz")
        manifest["leaves"][key] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "codec": codec,
        }
        with open(os.path.join(tmp, fn), "wb") as f:
            f.write(payload)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        import shutil

        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for m in (re.match(r"step_(\d+)$", d) for d in os.listdir(directory))
        if m
    ]
    return max(steps) if steps else None


def restore(directory: str, like: PyTree, *, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of ``like``.  ``shardings`` (same
    structure) re-places each leaf — pass shardings derived from a
    *different* mesh to do an elastic restart."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out: Dict[str, Any] = {}
    for key, meta in manifest["leaves"].items():
        if key not in flat_like:
            continue
        with open(os.path.join(path, meta["file"]), "rb") as f:
            buf = _decompress(meta.get("codec", "zstd"), f.read())
        arr = np.frombuffer(buf, dtype=np.dtype(meta["dtype"])).reshape(meta["shape"]).copy()
        if key in flat_shard and flat_shard[key] is not None:
            out[key] = jax.device_put(arr, flat_shard[key])
        else:
            out[key] = jax.device_put(arr)
    missing = set(flat_like) - set(out)
    if missing:
        raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]} ...")
    # unflatten back into `like`'s treedef
    leaves_in_order = []
    for path_, _ in jax.tree_util.tree_flatten_with_path(like)[0]:
        key = _SEP.join(_path_str(p) for p in path_)
        leaves_in_order.append(out[key])
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves_in_order)


class AsyncCheckpointer:
    """Snapshot-on-call, write-in-background checkpointing."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def save(self, step: int, state: PyTree) -> None:
        if self._err:
            raise self._err
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._q.put((step, host_state))  # blocks only if 2 writes queued

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, state = item
            try:
                save(self.directory, step, state)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._err = e

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for m in (re.match(r"step_(\d+)$", d) for d in os.listdir(self.directory))
            if m
        )
        import shutil

        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        import time

        while not self._q.empty():
            time.sleep(0.01)
        if self._err:
            raise self._err

    def close(self):
        self._q.put(None)
        self._thread.join(timeout=30)
        if self._err:
            raise self._err
