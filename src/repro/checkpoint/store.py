"""Sharded checkpoint store: save/restore train state for
checkpoint/restart and *elastic* restart (restore onto a different mesh).

Layout: ``<dir>/step_<N>/manifest.json`` + one ``<leaf>.npy.zst`` per
pytree leaf (zstd-compressed).  Per-leaf files bound writer memory and
let a restore reshard leaf-by-leaf onto a new mesh — the moral equivalent
of an OCDBT/array-store layout at container scale.  ``AsyncCheckpointer``
snapshots device arrays to host, then writes on a background thread so
the train loop never blocks on disk.

Crash consistency: the manifest carries a crc32 + byte count per leaf,
every file (and the step directory) is fsynced before the atomic rename,
and readers verify.  A step torn by a crash mid-write — truncated leaf,
half-written manifest, bytes that never hit the platter — is *skipped
with a warning* by ``latest_step()``/``restore()``, which fall back to
the newest intact step instead of raising out of the very retry path
checkpoints exist to serve.  ``verify_step`` is the explicit probe.
"""
from __future__ import annotations

import json
import os
import queue
import re
import threading
import warnings
from typing import Any, Dict, Optional

import jax
import numpy as np


def _fault_injector():
    # lazy lookup, not an import: repro.core.resilience pulls in the
    # session facade (which imports this module back), and a store that
    # never runs under chaos shouldn't pay for it.  If nobody imported
    # the faults module, nobody armed an injector.
    import sys

    mod = sys.modules.get("repro.core.resilience.faults")
    return mod.active() if mod is not None else None

try:
    import zstandard
except ImportError:  # container image without python-zstandard
    zstandard = None
import zlib

PyTree = Any


def _compress(data: bytes) -> tuple:
    if zstandard is not None:
        return "zstd", zstandard.ZstdCompressor(level=3).compress(data)
    return "zlib", zlib.compress(data, 3)


def _decompress(codec: str, buf: bytes) -> bytes:
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError(
                "checkpoint was written with zstd but zstandard is not installed")
        return zstandard.ZstdDecompressor().decompress(buf)
    if codec == "zlib":
        return zlib.decompress(buf)
    raise ValueError(f"unknown checkpoint codec {codec!r}")

_SEP = "__"


def _flatten(tree: PyTree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointCorrupt(RuntimeError):
    """A checkpoint step failed verification (torn write / bit rot)."""


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    # directory fsync makes the rename itself durable; best-effort on
    # filesystems that refuse O_RDONLY dir fds
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _tear(path: str, manifest: dict, at_byte: int, leaf: int) -> None:
    """Simulate a crash that left ``path`` torn: truncate one file.

    ``leaf < 0`` tears the manifest itself; otherwise the ``leaf``-th
    leaf file (manifest order) is cut at ``at_byte``.
    """
    if leaf < 0:
        victim = os.path.join(path, "manifest.json")
    else:
        files = [m["file"] for m in manifest["leaves"].values()]
        victim = os.path.join(path, files[leaf % len(files)])
    size = os.path.getsize(victim)
    with open(victim, "r+b") as f:
        f.truncate(min(max(0, at_byte), max(0, size - 1)))


def save(directory: str, step: int, state: PyTree) -> str:
    """Synchronous save. Returns the checkpoint path.

    Durability order: leaf files + manifest are written and fsynced
    inside ``step_N.tmp``, the tmp dir is fsynced, then the atomic
    rename publishes the step and the parent dir is fsynced.  A crash
    at any point leaves either no ``step_N`` or a fully-synced one —
    and if the platter still lies, the per-leaf crc32s catch it on read.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    manifest = {"step": step, "format": 2, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        codec, payload = _compress(arr.tobytes(order="C"))
        fn = re.sub(r"[^\w.\-]", "_", key) + (
            ".npy.zst" if codec == "zstd" else ".npy.zz")
        manifest["leaves"][key] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "codec": codec, "bytes": len(payload),
            "crc32": _zlib_crc32(payload),
        }
        fpath = os.path.join(tmp, fn)
        with open(fpath, "wb") as f:
            f.write(payload)
        _fsync_file(fpath)
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    _fsync_file(mpath)
    _fsync_dir(tmp)
    if os.path.exists(path):
        import shutil

        shutil.rmtree(path)
    os.rename(tmp, path)
    _fsync_dir(directory)
    inj = _fault_injector()
    if inj is not None:
        act = inj.fire("checkpoint.save", step=step)
        if act is not None and act["action"] == "tear":
            _tear(path, manifest, int(act.get("at_byte", 0)),
                  int(act.get("leaf", 0)))
    return path


def _zlib_crc32(payload: bytes) -> int:
    return zlib.crc32(payload) & 0xFFFFFFFF


def verify_step(directory: str, step: int) -> bool:
    """True iff ``step`` is structurally intact on disk.

    Checks: readable manifest, every leaf file present, and — for
    format-2 manifests — byte count and crc32 of each leaf's on-disk
    payload.  Pre-format-2 steps get the structural check only.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        for meta in manifest["leaves"].values():
            fpath = os.path.join(path, meta["file"])
            if "bytes" in meta and os.path.getsize(fpath) != meta["bytes"]:
                return False
            if "crc32" in meta:
                with open(fpath, "rb") as f:
                    if _zlib_crc32(f.read()) != meta["crc32"]:
                        return False
            elif not os.path.exists(fpath):
                return False
    except (OSError, ValueError, KeyError):
        return False
    return True


def _steps_on_disk(directory: str):
    if not os.path.isdir(directory):
        return []
    return sorted(
        (int(m.group(1))
         for m in (re.match(r"step_(\d+)$", d) for d in os.listdir(directory))
         if m),
        reverse=True,
    )


def latest_step(directory: str, *, verify: bool = True) -> Optional[int]:
    """Newest step — by default the newest *intact* step.

    A torn/corrupt step is skipped with a warning rather than returned:
    callers feed this straight into retry resume logic, and resuming
    from a poisoned step would crash the retry it exists to serve.
    """
    for step in _steps_on_disk(directory):
        if not verify or verify_step(directory, step):
            return step
        warnings.warn(
            f"checkpoint step {step} under {directory} is torn/corrupt; "
            f"falling back to an older step", RuntimeWarning, stacklevel=2)
    return None


def _read_step(path: str, manifest: dict, flat_like: Dict[str, Any],
               flat_shard: Dict[str, Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for key, meta in manifest["leaves"].items():
        if key not in flat_like:
            continue
        with open(os.path.join(path, meta["file"]), "rb") as f:
            payload = f.read()
        if "crc32" in meta and _zlib_crc32(payload) != meta["crc32"]:
            raise CheckpointCorrupt(
                f"crc mismatch for leaf {key!r} in {path}")
        try:
            buf = _decompress(meta.get("codec", "zstd"), payload)
            arr = np.frombuffer(buf, dtype=np.dtype(meta["dtype"])) \
                .reshape(meta["shape"]).copy()
        except Exception as e:  # noqa: BLE001 - any decode error = torn leaf
            raise CheckpointCorrupt(
                f"torn leaf {key!r} in {path}: {e}") from e
        if key in flat_shard and flat_shard[key] is not None:
            out[key] = jax.device_put(arr, flat_shard[key])
        else:
            out[key] = jax.device_put(arr)
    return out


def restore(directory: str, like: PyTree, *, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of ``like``.  ``shardings`` (same
    structure) re-places each leaf — pass shardings derived from a
    *different* mesh to do an elastic restart.

    With ``step=None`` a torn/corrupt newest step is skipped (with a
    warning) in favour of the newest intact one; an explicitly
    requested step raises :class:`CheckpointCorrupt` instead.
    """
    candidates = [step] if step is not None else _steps_on_disk(directory)
    if not candidates:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    flat_like = _flatten(like)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    last_err: Optional[Exception] = None
    for cand in candidates:
        path = os.path.join(directory, f"step_{cand:08d}")
        try:
            try:
                with open(os.path.join(path, "manifest.json")) as f:
                    manifest = json.load(f)
            except (OSError, ValueError) as e:
                raise CheckpointCorrupt(
                    f"unreadable manifest in {path}: {e}") from e
            out = _read_step(path, manifest, flat_like, flat_shard)
        except CheckpointCorrupt as e:
            if step is not None:
                raise
            warnings.warn(
                f"skipping torn/corrupt checkpoint step {cand}: {e}",
                RuntimeWarning, stacklevel=2)
            last_err = e
            continue
        missing = set(flat_like) - set(out)
        if missing:
            raise KeyError(
                f"checkpoint missing leaves: {sorted(missing)[:5]} ...")
        # unflatten back into `like`'s treedef
        leaves_in_order = []
        for path_, _ in jax.tree_util.tree_flatten_with_path(like)[0]:
            key = _SEP.join(_path_str(p) for p in path_)
            leaves_in_order.append(out[key])
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves_in_order)
    raise CheckpointCorrupt(
        f"every checkpoint step under {directory} is torn/corrupt "
        f"(last error: {last_err})")


class AsyncCheckpointer:
    """Snapshot-on-call, write-in-background checkpointing."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def save(self, step: int, state: PyTree) -> None:
        if self._err:
            raise self._err
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._q.put((step, host_state))  # blocks only if 2 writes queued

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, state = item
            try:
                save(self.directory, step, state)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._err = e

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for m in (re.match(r"step_(\d+)$", d) for d in os.listdir(self.directory))
            if m
        )
        import shutil

        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        import time

        while not self._q.empty():
            time.sleep(0.01)
        if self._err:
            raise self._err

    def close(self):
        self._q.put(None)
        self._thread.join(timeout=30)
        if self._err:
            raise self._err
