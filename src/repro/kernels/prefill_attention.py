"""Ragged cache-writing prefill attention — Pallas TPU kernels.

The prefill counterpart of ``decode_attention``: a ``[B, T]`` slab of
fresh prompt tokens (per-row ragged — row ``b`` carries ``chunk_lens[b]``
valid tokens, the rest right-padding) is appended into each row's KV
cache at its own ``base[b]`` offset and attended causally against the
full cached prefix ``[0, base[b] + chunk_lens[b])`` in one fused op.
``base`` is a *traced* per-row vector, so rows at different prefill
offsets batch into a single call — the property the serving engine's
chunked (Sarathi-style) prefill scheduler relies on: a long prompt is
prefilled in bounded chunks interleaved with decode steps, each chunk a
plain ``base += chunk`` continuation.

Two layouts, mirroring the decode kernels:

* ``prefill_attention`` — contiguous cache rows ``[B, S, KV, D]``.  The
  fresh K/V is scattered into the cache (writes past a row's
  ``chunk_lens`` drop, so padding never clobbers neighbouring state),
  then the kernel streams KV blocks with the per-row lengths riding in
  as scalar-prefetch operands: blocks past a row's causal frontier or
  past its query chunk are skipped (``pl.when``), the ragged tail block
  is masked at element granularity.
* ``prefill_attention_paged`` — the shared page pool ``[num_pages,
  page_size, KV, D]`` addressed through per-row block tables: fresh K/V
  scatters through the table (sentinel entries drop), and the kernel's
  K/V BlockSpec index maps gather the physical page per (row,
  logical-page) grid step — PR 5's paged-read pattern, now on the
  prefill side.

Outputs at padding query rows (``i >= chunk_lens[b]``) are exact zeros
in both the kernels and the jnp oracles, so parity tests compare full
tensors.  Queries attend nothing outside ``kpos <= base + i`` — for a
valid query that is exactly the row's live prefix, so no per-element
length mask beyond causality is needed.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def write_chunk(cache: jnp.ndarray, new: jnp.ndarray, base: jnp.ndarray,
                chunk_lens: jnp.ndarray) -> jnp.ndarray:
    """Scatter ``new [B, T, ...]`` into ``cache [B, S, ...]`` at per-row
    offsets ``base [B]``; positions at or past ``chunk_lens[b]`` drop."""
    B, T = new.shape[0], new.shape[1]
    S = cache.shape[1]
    j = jnp.arange(T)[None, :]
    pos = jnp.where(j < chunk_lens[:, None], base[:, None] + j, S)
    rows = jnp.broadcast_to(jnp.arange(B)[:, None], (B, T))
    return cache.at[rows, pos].set(new.astype(cache.dtype), mode="drop")


def write_chunk_paged(pages: jnp.ndarray, block_table: jnp.ndarray,
                      new: jnp.ndarray, base: jnp.ndarray,
                      chunk_lens: jnp.ndarray) -> jnp.ndarray:
    """Scatter ``new [B, T, ...]`` through per-row block tables into the
    shared page pool.  Unallocated logical pages hit the sentinel
    (>= num_pages) and the write drops, as do padding positions."""
    num_pages, page_size = pages.shape[0], pages.shape[1]
    B, T = new.shape[0], new.shape[1]
    max_pages = block_table.shape[1]
    j = jnp.arange(T)[None, :]
    pos = base[:, None] + j
    lp = pos // page_size
    off = pos % page_size
    rows = jnp.arange(B)[:, None]
    phys = jnp.where(
        (j < chunk_lens[:, None]) & (lp < max_pages),
        block_table[rows, jnp.minimum(lp, max_pages - 1)],
        num_pages,
    )
    return pages.at[phys, off].set(new.astype(pages.dtype), mode="drop")


def _pf_kernel(base_ref, clen_ref, q_ref, k_ref, v_ref, o_ref,
               m_scr, l_scr, acc_scr, *,
               scale: float, block_q: int, block_k: int, heads: int):
    bh = pl.program_id(0)
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    ns = pl.num_programs(2)
    b = bh // heads
    base = base_ref[b]
    clen = clen_ref[b]

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    lo = kj * block_k
    # skip: KV blocks wholly past the tile's causal frontier, and query
    # tiles wholly past the row's ragged chunk length
    live = jnp.logical_and(lo <= base + (qi + 1) * block_q - 1,
                           qi * block_q < clen)

    @pl.when(live)
    def _compute():
        q = q_ref[0, ...].astype(jnp.float32)        # [bq, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # [bk, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                    # [bq, bk]
        qpos = base + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0)
        kpos = lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= qpos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        # a fully-masked row (padding query) must contribute l = 0
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(kj == ns - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        out = acc_scr[...] / l
        row = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, out.shape, 0)
        # padding query rows are exact zeros (oracle parity)
        o_ref[0, ...] = jnp.where(row < clen, out, 0.0).astype(o_ref.dtype)


def _prep_q(q, block_q):
    """[B, T, H, D] (model-native) -> padded [B*H, Tp, D] + grid sizes."""
    B, T, H, D = q.shape
    block_q = min(block_q, max(T, 1))
    Tp = pl.cdiv(T, block_q) * block_q
    q_r = q.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    if Tp != T:
        q_r = jnp.pad(q_r, ((0, 0), (0, Tp - T), (0, 0)))
    return q_r, block_q, Tp


def _vec(x, B):
    return jnp.broadcast_to(jnp.asarray(x, jnp.int32).reshape(-1), (B,))


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def prefill_attention(
    q: jnp.ndarray,          # [B, T, H, D]   fresh-chunk queries
    k_new: jnp.ndarray,      # [B, T, KV, D]  fresh K/V to append
    v_new: jnp.ndarray,
    k_cache: jnp.ndarray,    # [B, S, KV, D]  cache-native layout
    v_cache: jnp.ndarray,
    base: jnp.ndarray,       # [] or [B] int32: cached prefix per row
    chunk_lens: jnp.ndarray,  # [] or [B] int32: valid tokens in the chunk
    *,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """Returns ``(out [B, T, H, D], k_cache', v_cache')``."""
    B, T, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    base = _vec(base, B)
    clens = _vec(chunk_lens, B)
    kc = write_chunk(k_cache, k_new, base, clens)
    vc = write_chunk(v_cache, v_new, base, clens)

    block_k = min(block_k, S)
    while S % block_k:  # cache rows are power-of-two buckets on the
        block_k //= 2   # serving path; degrade gracefully otherwise
    q_r, block_q, Tp = _prep_q(q, block_q)
    grid = (B * H, Tp // block_q, S // block_k)

    def kv_map(bh, qi, kj, br, cr):
        return (bh // H, kj, (bh % H) // G, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, kj, br, cr: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, 1, D), kv_map),
            pl.BlockSpec((1, block_k, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D),
                               lambda bh, qi, kj, br, cr: (bh, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_pf_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, heads=H),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, Tp, D), q.dtype),
        interpret=interpret,
    )(base, clens, q_r, kc, vc)
    out = out[:, :T].reshape(B, H, T, D).transpose(0, 2, 1, 3)
    return out, kc, vc


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def prefill_attention_paged(
    q: jnp.ndarray,            # [B, T, H, D]
    k_new: jnp.ndarray,        # [B, T, KV, D]
    v_new: jnp.ndarray,
    k_pages: jnp.ndarray,      # [num_pages, page_size, KV, D]  shared pool
    v_pages: jnp.ndarray,
    block_table: jnp.ndarray,  # [B, max_pages] int32 (sentinel >= num_pages)
    base: jnp.ndarray,         # [] or [B] int32
    chunk_lens: jnp.ndarray,   # [] or [B] int32
    *,
    block_q: int = 128,
    interpret: bool = False,
):
    """Returns ``(out [B, T, H, D], k_pages', v_pages')``."""
    B, T, H, D = q.shape
    num_pages, page_size, KV, _ = k_pages.shape
    max_pages = block_table.shape[1]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    base = _vec(base, B)
    clens = _vec(chunk_lens, B)
    kp = write_chunk_paged(k_pages, block_table, k_new, base, clens)
    vp = write_chunk_paged(v_pages, block_table, v_new, base, clens)

    # clamp sentinels in-range: they only address positions at or past a
    # row's live prefix, which the causal mask / block skip discards
    bt = jnp.clip(block_table.astype(jnp.int32), 0, num_pages - 1)
    q_r, block_q, Tp = _prep_q(q, block_q)
    grid = (B * H, Tp // block_q, max_pages)

    def page_map(bh, qi, kj, br, cr, btr):
        return (btr[bh // H, kj], 0, (bh % H) // G, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D),
                         lambda bh, qi, kj, br, cr, btr: (bh, qi, 0)),
            pl.BlockSpec((1, page_size, 1, D), page_map),
            pl.BlockSpec((1, page_size, 1, D), page_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D),
                               lambda bh, qi, kj, br, cr, btr: (bh, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
    )
    def paged_kernel(base_ref, clen_ref, bt_ref, *rest):
        # bt_ref is consumed by the BlockSpec index maps above; the body
        # only needs the per-row base/chunk lengths
        del bt_ref
        _pf_kernel(base_ref, clen_ref, *rest, scale=scale,
                   block_q=block_q, block_k=page_size, heads=H)

    out = pl.pallas_call(
        paged_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, Tp, D), q.dtype),
        interpret=interpret,
    )(base, clens, bt, q_r, kp, vp)
    out = out[:, :T].reshape(B, H, T, D).transpose(0, 2, 1, 3)
    return out, kp, vp
