"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
the per-kernel shape/dtype sweeps assert against)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q [B,H,S,D]; k,v [B,KV,S,D] -> [B,H,S,D]; naive full-softmax."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def decode_attention_ref(q, k, v, cache_len, *, window: int = 0) -> jnp.ndarray:
    """q [B,H,D]; k,v [B,S,KV,D] (cache-native) -> [B,H,D].

    ``cache_len`` is [] or [B] int32 — a [B] vector gives each batch row
    its own valid prefix (the continuous-batching slot cache).  ``window``
    > 0 additionally masks positions before ``cache_len - window``.

    The cache is sequence-sharded over the model axis (flash-decoding
    style); the contraction over S becomes a partial-softmax + psum under
    GSPMD — the sharding constraint keeps the GQA-repeated heads on the
    model axis instead of replicated.
    """
    from repro.distributed.sharding import constrain

    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    cache_axes = ("cache_batch", "cache_seq", None, None)
    kf = k if KV == H else constrain(jnp.repeat(k, G, axis=2), cache_axes)
    vf = v if KV == H else constrain(jnp.repeat(v, G, axis=2), cache_axes)
    s = jnp.einsum(
        "bhd,bshd->bhs", q.astype(jnp.float32), kf.astype(jnp.float32)
    ) / math.sqrt(D)
    cl = jnp.asarray(cache_len)
    if cl.ndim == 1:
        cl = cl[:, None, None]  # per-row lengths broadcast over [B,H,S]
    pos = jnp.arange(S)[None, None, :]
    mask = pos < cl
    if window:
        mask &= pos >= cl - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bhs,bshd->bhd", p, vf.astype(jnp.float32)).astype(q.dtype)


def decode_attention_paged_ref(q, k_pages, v_pages, block_table, cache_len,
                               *, window: int = 0) -> jnp.ndarray:
    """Paged oracle: gather each row's pages through its block table into a
    contiguous [B, max_pages*page_size, KV, D] view, then run the masked
    reference.  Sentinel (out-of-range) table entries are clamped — they
    only address positions past ``cache_len``, which the mask discards."""
    num_pages, page_size, KV, D = k_pages.shape
    B, max_pages = block_table.shape
    bt = jnp.clip(block_table.astype(jnp.int32), 0, num_pages - 1)
    k = k_pages[bt].reshape(B, max_pages * page_size, KV, D)
    v = v_pages[bt].reshape(B, max_pages * page_size, KV, D)
    return decode_attention_ref(q, k, v, cache_len, window=window)


def prefill_attention_ref(q, k_new, v_new, k_cache, v_cache, base,
                          chunk_lens):
    """Ragged cache-writing prefill oracle, contiguous layout.

    q [B,T,H,D]; k_new, v_new [B,T,KV,D]; k_cache, v_cache [B,S,KV,D];
    base, chunk_lens [] or [B] int32.  Row ``b``'s first ``chunk_lens[b]``
    chunk tokens are appended at offset ``base[b]`` and each valid query
    ``i`` attends causally over ``[0, base[b] + i]``; padding query rows
    produce exact zeros.  Returns ``(out [B,T,H,D], k_cache', v_cache')``.
    """
    from repro.kernels.prefill_attention import write_chunk

    B = q.shape[0]
    base = jnp.broadcast_to(jnp.asarray(base, jnp.int32).reshape(-1), (B,))
    clens = jnp.broadcast_to(
        jnp.asarray(chunk_lens, jnp.int32).reshape(-1), (B,))
    kc = write_chunk(k_cache, k_new, base, clens)
    vc = write_chunk(v_cache, v_new, base, clens)
    return prefill_attend_ref(q, kc, vc, base, clens), kc, vc


def prefill_attend_ref(q, kc, vc, base, clens):
    """Masked causal attention of a [B,T] chunk over a contiguous
    [B,S,KV,D] cache at per-row offsets; padding rows exact zero."""
    T, H, D = q.shape[1], q.shape[2], q.shape[3]
    S, KV = kc.shape[1], kc.shape[2]
    G = H // KV
    kf = jnp.repeat(kc, G, axis=2).astype(jnp.float32)  # [B,S,H,D]
    vf = jnp.repeat(vc, G, axis=2).astype(jnp.float32)
    s = jnp.einsum(
        "bthd,bshd->bhts", q.astype(jnp.float32), kf) / math.sqrt(D)
    qpos = base[:, None] + jnp.arange(T)[None, :]          # [B,T]
    mask = jnp.arange(S)[None, None, :] <= qpos[:, :, None]  # [B,T,S]
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", p, vf)
    valid = jnp.arange(T)[None, :] < clens[:, None]        # [B,T]
    out = jnp.where(valid[:, :, None, None], out, 0.0)
    return out.astype(q.dtype)


def prefill_attention_paged_ref(q, k_new, v_new, k_pages, v_pages,
                                block_table, base, chunk_lens):
    """Paged prefill oracle: write the chunk through the block tables,
    gather each row's pages into a contiguous view, and reuse the
    contiguous oracle's attention (discarding its cache outputs).
    Returns ``(out [B,T,H,D], k_pages', v_pages')``."""
    from repro.kernels.prefill_attention import write_chunk_paged

    num_pages, page_size, KV, D = k_pages.shape
    B, max_pages = block_table.shape
    base = jnp.broadcast_to(jnp.asarray(base, jnp.int32).reshape(-1), (B,))
    clens = jnp.broadcast_to(
        jnp.asarray(chunk_lens, jnp.int32).reshape(-1), (B,))
    kp = write_chunk_paged(k_pages, block_table, k_new, base, clens)
    vp = write_chunk_paged(v_pages, block_table, v_new, base, clens)
    bt = jnp.clip(block_table.astype(jnp.int32), 0, num_pages - 1)
    k = kp[bt].reshape(B, max_pages * page_size, KV, D)
    v = vp[bt].reshape(B, max_pages * page_size, KV, D)
    return prefill_attend_ref(q, k, v, base, clens), kp, vp


def rmsnorm_ref(x, w, *, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def hash_u32_ref(keys):
    k = keys.astype(jnp.uint32)
    h = k * jnp.uint32(2654435761)
    return h ^ (h >> 16)


def hash_partition_histogram_ref(keys, *, num_buckets: int) -> jnp.ndarray:
    """Global histogram [num_buckets] (per-block results sum to this)."""
    bucket = (hash_u32_ref(keys) % jnp.uint32(num_buckets)).astype(jnp.int32)
    return jnp.zeros((num_buckets,), jnp.int32).at[bucket].add(1)
