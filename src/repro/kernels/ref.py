"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
the per-kernel shape/dtype sweeps assert against)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q [B,H,S,D]; k,v [B,KV,S,D] -> [B,H,S,D]; naive full-softmax."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def decode_attention_ref(q, k, v, cache_len) -> jnp.ndarray:
    """q [B,H,D]; k,v [B,KV,S,D] -> [B,H,D]."""
    B, H, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kf) / math.sqrt(D)
    mask = jnp.arange(S)[None, None, :] < cache_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p, vf).astype(q.dtype)


def rmsnorm_ref(x, w, *, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def hash_u32_ref(keys):
    k = keys.astype(jnp.uint32)
    h = k * jnp.uint32(2654435761)
    return h ^ (h >> 16)


def hash_partition_histogram_ref(keys, *, num_buckets: int) -> jnp.ndarray:
    """Global histogram [num_buckets] (per-block results sum to this)."""
    bucket = (hash_u32_ref(keys) % jnp.uint32(num_buckets)).astype(jnp.int32)
    return jnp.zeros((num_buckets,), jnp.int32).at[bucket].add(1)
