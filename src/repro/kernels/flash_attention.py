"""Causal GQA flash-attention forward — Pallas TPU kernel.

TPU adaptation of FlashAttention (arXiv:2205.14135): the CUDA version's
shared-memory tiling + warp reductions become VMEM BlockSpec tiles + VPU
reductions, with the MXU fed (block_q x head_dim) x (head_dim x block_k)
tiles (128-aligned).  The sequential minor grid dimension carries the
running-softmax state in VMEM scratch across KV blocks — the idiomatic
Pallas streaming pattern (grid minor dim iterates in order on TPU).

Layout: q [B, H, S, D]; k, v [B, KV, S, D] (GQA: H = KV * G — the kernel
maps query head h to kv head h // G via the BlockSpec index_map, so KV is
never materialized at H width).  Causal masking skips fully-masked KV
blocks (``pl.when``) — the compiled FLOPs follow the causal triangle.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, block_q: int, block_k: int, causal: bool,
               kv_len: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # ragged tail: skip KV blocks wholly past the true sequence length
    run = kj * block_k < kv_len
    if causal:
        run = jnp.logical_and(run, qi * block_q + block_q - 1 >= kj * block_k)

    @pl.when(run)
    def _compute():
        q = q_ref[0, ...].astype(jnp.float32)  # [bq, D]
        k = k_ref[0, ...].astype(jnp.float32)  # [bk, D]
        v = v_ref[0, ...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [bq, bk]
        kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = jnp.logical_and(mask, qpos >= kpos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        # a fully-masked row must contribute zero to the denominator
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, ...] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,  # [B, H, S, D]
    k: jnp.ndarray,  # [B, KV, S, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    # pad the ragged tail up to a whole block (masked inside the kernel)
    # rather than silently truncating S % block trailing tokens
    step = math.lcm(block_q, block_k)
    Sp = pl.cdiv(S, step) * step
    nq = Sp // block_q
    nk = Sp // block_k
    grid = (B * H, nq, nk)

    def q_map(bh, qi, kj):
        return (bh, qi, 0)

    def kv_map(bh, qi, kj):
        b, h = bh // H, bh % H
        return (b * KV + h // G, kj, 0)

    q_r = q.reshape(B * H, S, D)
    k_r = k.reshape(B * KV, S, D)
    v_r = v.reshape(B * KV, S, D)
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0))
        q_r, k_r, v_r = (jnp.pad(x, pad) for x in (q_r, k_r, v_r))

    out = pl.pallas_call(
        functools.partial(
            _fa_kernel, scale=scale, block_q=block_q, block_k=block_k,
            causal=causal, kv_len=S,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q_r, k_r, v_r)
    return out[:, :S].reshape(B, H, S, D)
