"""Fused RMSNorm — Pallas TPU kernel.

Row-tiled: each program loads a [block_rows, d] tile into VMEM, computes
the f32 mean-square + rsqrt on the VPU and applies the scale in one pass
(one HBM read + one write per element, vs 3 reads / 2 writes unfused).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def rmsnorm(
    x: jnp.ndarray,  # [..., d]
    w: jnp.ndarray,  # [d]
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    orig_shape = x.shape
    d = x.shape[-1]
    n = 1
    for s in x.shape[:-1]:
        n *= s
    x2 = x.reshape(n, d)
    block_rows = min(block_rows, n)
    # pad rows to a block multiple
    pad = (-n) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w)
    if pad:
        out = out[:n]
    return out.reshape(orig_shape)
