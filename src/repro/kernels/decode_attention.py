"""Flash-decoding (split-K) GQA decode attention — Pallas TPU kernel.

FlashDecoding (arXiv:2311.01282) splits the KV cache across the grid so a
single query token saturates the chip: each program reduces one KV span
into a partial (max, denom, weighted-V) triple; a cheap jnp combine merges
the partials.  GPU→TPU adaptation: per-SM split-K becomes grid programs
over VMEM-resident cache tiles; the GQA head group is packed into one MXU
matmul ([G, D] x [D, block_k]) instead of warp-level broadcast.

Layout: q [B, H, D]; k, v [B, KV, S, D]; cache_len scalar int32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref, *,
                scale: float, block_k: int):
    sj = pl.program_id(1)
    q = q_ref[0, ...].astype(jnp.float32)          # [G, D]
    k = k_ref[0, ...].astype(jnp.float32)          # [bk, D]
    v = v_ref[0, ...].astype(jnp.float32)          # [bk, D]
    cache_len = len_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                      # [G, bk]
    kpos = sj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < cache_len, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)         # [G, 1]
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # [G, D]
    m_ref[0, 0, ...] = m
    l_ref[0, 0, ...] = l
    acc_ref[0, 0, ...] = acc


@functools.partial(
    jax.jit, static_argnames=("block_k", "interpret")
)
def decode_attention(
    q: jnp.ndarray,        # [B, H, D]
    k: jnp.ndarray,        # [B, KV, S, D]
    v: jnp.ndarray,
    cache_len: jnp.ndarray,  # [] int32
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    block_k = min(block_k, S)
    ns = S // block_k
    grid = (B * KV, ns)

    q_r = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
    k_r = k.reshape(B * KV, S, D)
    v_r = v.reshape(B * KV, S, D)
    clen = jnp.broadcast_to(cache_len, (1,)).astype(jnp.int32)

    m_p, l_p, acc_p = pl.pallas_call(
        functools.partial(_dec_kernel, scale=scale, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.MemorySpace.ANY),
            pl.BlockSpec((1, G, D), lambda bh, sj: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, sj: (bh, sj, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, sj: (bh, sj, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, 1), lambda bh, sj: (bh, sj, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda bh, sj: (bh, sj, 0, 0)),
            pl.BlockSpec((1, 1, G, D), lambda bh, sj: (bh, sj, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * KV, ns, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * KV, ns, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * KV, ns, G, D), jnp.float32),
        ],
        interpret=interpret,
    )(clen, q_r, k_r, v_r)

    # cross-split combine (tiny: [B*KV, ns, G, ...])
    m_all = jnp.max(m_p, axis=1, keepdims=True)
    w = jnp.exp(m_p - m_all)
    l_tot = jnp.sum(l_p * w, axis=1)
    acc = jnp.sum(acc_p * w, axis=1)
    out = acc / jnp.maximum(l_tot, 1e-30)
    return out.reshape(B, KV * G, D).astype(q.dtype)


def _dec_kernel_shapes():  # for docs/tests
    return dict(block_k=512)
