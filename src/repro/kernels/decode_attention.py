"""Flash-decoding (split-K) GQA decode attention — Pallas TPU kernels.

FlashDecoding (arXiv:2311.01282) splits the KV cache across the grid so a
single query token saturates the chip: each program reduces one KV span
into a partial (max, denom, weighted-V) triple; a cheap jnp combine merges
the partials.  GPU→TPU adaptation: per-SM split-K becomes grid programs
over VMEM-resident cache tiles; the GQA head group is packed into one MXU
matmul ([G, D] x [D, block_k]) instead of warp-level broadcast.

Two layouts, one kernel family:

* ``decode_attention`` — contiguous cache rows ``[B, S, KV, D]`` (the
  model-native slot-cache layout, so the hot path never transposes).
  ``cache_len`` may be a scalar or a per-row ``[B]`` vector (continuous
  batching: every slot is at a different point in its sequence).  Lengths
  ride in as scalar-prefetch operands, masking happens at K-block
  granularity inside the kernel, and split-K blocks entirely past a row's
  valid prefix (or entirely before its attention window) are skipped —
  the skipped program writes neutral partials the combine ignores.
* ``decode_attention_paged`` — a shared page pool ``[num_pages,
  page_size, KV, D]`` addressed through a per-row block table
  ``[B, max_pages]``: the block table is a scalar-prefetch operand and the
  K/V BlockSpec index maps *gather the physical page* for each (row,
  logical-page) grid step, so one sequence's KV need not be contiguous in
  memory (vLLM-style PagedAttention, arXiv:2309.06180).  Out-of-range
  table entries (free slots use a sentinel) are clamped — they can only
  map to blocks past the row's length, which the mask discards.

Both take a static ``window`` (0 = full attention): positions outside
``[cache_len - window, cache_len)`` are masked by the same per-row length
logic.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _partial_softmax(q, k, v, kpos, cache_len, scale: float, window: int):
    """One split-K partial: q [G,D], k/v [bk,D], kpos [G,bk] int32 ->
    (m [G,1], l [G,1], acc [G,D]) fp32."""
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                      # [G, bk]
    mask = kpos < cache_len
    if window:
        mask &= kpos >= cache_len - window
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)         # [G, 1]
    p = jnp.exp(s - m)
    # a fully-masked block (all NEG_INF) must contribute l = 0, not bk:
    # exp(NEG_INF - NEG_INF) = 1 per position would poison the denominator
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # [G, D]
    return m, l, acc


def _write_neutral(m_ref, l_ref, acc_ref):
    m_ref[0, 0, ...] = jnp.full_like(m_ref[0, 0], NEG_INF)
    l_ref[0, 0, ...] = jnp.zeros_like(l_ref[0, 0])
    acc_ref[0, 0, ...] = jnp.zeros_like(acc_ref[0, 0])


def _combine_splits(m_p, l_p, acc_p, B, KV, G, D, dtype):
    """Merge split-K partials [B*KV, ns, G, ...] -> [B, H, D]."""
    m_all = jnp.max(m_p, axis=1, keepdims=True)
    w = jnp.exp(m_p - m_all)
    l_tot = jnp.sum(l_p * w, axis=1)
    acc = jnp.sum(acc_p * w, axis=1)
    out = acc / jnp.maximum(l_tot, 1e-30)
    return out.reshape(B, KV * G, D).astype(dtype)


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref, *,
                scale: float, block_k: int, window: int, kv: int):
    bh = pl.program_id(0)
    sj = pl.program_id(1)
    cache_len = len_ref[bh // kv]
    lo = sj * block_k
    live = lo < cache_len
    if window:
        live = jnp.logical_and(live, lo + block_k > cache_len - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, ...].astype(jnp.float32)          # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # [bk, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        kpos = lo + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], block_k), 1)
        m, l, acc = _partial_softmax(q, k, v, kpos, cache_len, scale, window)
        m_ref[0, 0, ...] = m
        l_ref[0, 0, ...] = l
        acc_ref[0, 0, ...] = acc

    @pl.when(jnp.logical_not(live))
    def _skip():  # split-K block entirely outside the valid prefix
        _write_neutral(m_ref, l_ref, acc_ref)


@functools.partial(
    jax.jit, static_argnames=("window", "block_k", "interpret")
)
def decode_attention(
    q: jnp.ndarray,          # [B, H, D]
    k: jnp.ndarray,          # [B, S, KV, D]  (cache-native layout)
    v: jnp.ndarray,
    cache_len: jnp.ndarray,  # [] or [B] int32
    *,
    window: int = 0,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    block_k = min(block_k, S)
    ns = pl.cdiv(S, block_k)
    if S % block_k:  # ragged tail: fall back to one block (S is max_len —
        block_k = S  # always a power-of-two bucket on the serving path)
        ns = 1

    q_r = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
    lens = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * KV, ns),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda bh, sj, lr: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda bh, sj, lr: (bh // KV, sj, bh % KV, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda bh, sj, lr: (bh // KV, sj, bh % KV, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, 1), lambda bh, sj, lr: (bh, sj, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda bh, sj, lr: (bh, sj, 0, 0)),
            pl.BlockSpec((1, 1, G, D), lambda bh, sj, lr: (bh, sj, 0, 0)),
        ],
    )
    m_p, l_p, acc_p = pl.pallas_call(
        functools.partial(_dec_kernel, scale=scale, block_k=block_k,
                          window=window, kv=KV),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B * KV, ns, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * KV, ns, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * KV, ns, G, D), jnp.float32),
        ],
        interpret=interpret,
    )(lens, q_r, k, v)
    return _combine_splits(m_p, l_p, acc_p, B, KV, G, D, q.dtype)


def _dec_paged_kernel(len_ref, bt_ref, q_ref, k_ref, v_ref,
                      m_ref, l_ref, acc_ref, *,
                      scale: float, page_size: int, window: int, kv: int):
    bh = pl.program_id(0)
    sj = pl.program_id(1)
    cache_len = len_ref[bh // kv]
    lo = sj * page_size
    live = lo < cache_len
    if window:
        live = jnp.logical_and(live, lo + page_size > cache_len - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, ...].astype(jnp.float32)          # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # [page, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        kpos = lo + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], page_size), 1)
        m, l, acc = _partial_softmax(q, k, v, kpos, cache_len, scale, window)
        m_ref[0, 0, ...] = m
        l_ref[0, 0, ...] = l
        acc_ref[0, 0, ...] = acc

    @pl.when(jnp.logical_not(live))
    def _skip():  # page past the valid prefix (incl. unallocated sentinels)
        _write_neutral(m_ref, l_ref, acc_ref)


@functools.partial(
    jax.jit, static_argnames=("window", "interpret")
)
def decode_attention_paged(
    q: jnp.ndarray,            # [B, H, D]
    k_pages: jnp.ndarray,      # [num_pages, page_size, KV, D]  shared pool
    v_pages: jnp.ndarray,
    block_table: jnp.ndarray,  # [B, max_pages] int32 (sentinel >= num_pages
    cache_len: jnp.ndarray,    #   marks unallocated logical pages)
    *,
    window: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, D = q.shape
    num_pages, page_size, KV, _ = k_pages.shape
    max_pages = block_table.shape[1]
    G = H // KV
    scale = 1.0 / math.sqrt(D)

    q_r = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
    lens = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,))
    # clamp sentinel entries in-range: they only ever address positions at
    # or past cache_len, which the in-kernel mask discards
    bt = jnp.clip(block_table.astype(jnp.int32), 0, num_pages - 1)

    def page_map(bh, sj, lr, btr):
        # gather the physical page through the block table (the paged read)
        return (btr[bh // KV, sj], 0, bh % KV, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * KV, max_pages),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda bh, sj, lr, btr: (bh, 0, 0)),
            pl.BlockSpec((1, page_size, 1, D), page_map),
            pl.BlockSpec((1, page_size, 1, D), page_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, 1), lambda bh, sj, lr, btr: (bh, sj, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda bh, sj, lr, btr: (bh, sj, 0, 0)),
            pl.BlockSpec((1, 1, G, D), lambda bh, sj, lr, btr: (bh, sj, 0, 0)),
        ],
    )
    m_p, l_p, acc_p = pl.pallas_call(
        functools.partial(_dec_paged_kernel, scale=scale,
                          page_size=page_size, window=window, kv=KV),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B * KV, max_pages, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * KV, max_pages, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * KV, max_pages, G, D), jnp.float32),
        ],
        interpret=interpret,
    )(lens, bt, q_r, k_pages, v_pages)
    return _combine_splits(m_p, l_p, acc_p, B, KV, G, D, q.dtype)


def _dec_kernel_shapes():  # for docs/tests
    return dict(block_k=512)
