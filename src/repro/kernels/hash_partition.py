"""Hash-partition histogram — Pallas TPU kernel (the dataframe shuffle's
partition step).

Cylon's radix partition is a CPU cache-conscious two-pass algorithm
(histogram, then scatter).  TPU adaptation: pass 1 (this kernel) computes
per-block bucket histograms fully vectorized — each program hashes a
[block] tile of keys in VMEM and accumulates `sum(bucket == p)` compare-
reduces on the VPU, writing a [P] histogram row.  Pass 2 (prefix sums +
gather reorder) stays in jnp: XLA already emits optimal cumsum/gather, and
TPU has no scatter unit a kernel could beat.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_KNUTH = 2654435761


def _hash(keys: jnp.ndarray) -> jnp.ndarray:
    k = keys.astype(jnp.uint32)
    h = k * jnp.uint32(_KNUTH)
    h = h ^ (h >> 16)
    return h


def _hist_kernel(keys_ref, hist_ref, *, num_buckets: int):
    keys = keys_ref[...]
    bucket = (_hash(keys) % jnp.uint32(num_buckets)).astype(jnp.int32)
    # vectorized per-bucket compare-reduce: [block] -> [P]
    pids = jax.lax.broadcasted_iota(jnp.int32, (num_buckets, keys.shape[0]), 0)
    hist = jnp.sum((bucket[None, :] == pids).astype(jnp.int32), axis=1)
    hist_ref[0, ...] = hist


@functools.partial(
    jax.jit, static_argnames=("num_buckets", "block", "interpret")
)
def hash_partition_histogram(
    keys: jnp.ndarray,  # [N] int
    *,
    num_buckets: int,
    block: int = 2048,
    interpret: bool = False,
) -> jnp.ndarray:
    """-> [num_blocks, num_buckets] per-block histograms (pass 1).

    ``jnp.cumsum`` over the flattened result gives scatter offsets; the
    caller reorders with a gather (see repro.dataframe.partition)."""
    n = keys.shape[0]
    block = min(block, n)
    pad = (-n) % block
    k2 = jnp.pad(keys, (0, pad), constant_values=-1) if pad else keys
    # padded keys hash somewhere; subtract them from the last block after
    nb = k2.shape[0] // block
    hist = pl.pallas_call(
        functools.partial(_hist_kernel, num_buckets=num_buckets),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, num_buckets), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, num_buckets), jnp.int32),
        interpret=interpret,
    )(k2)
    if pad:
        pad_bucket = (_hash(jnp.full((pad,), -1)) % jnp.uint32(num_buckets)).astype(jnp.int32)
        corr = jnp.zeros((num_buckets,), jnp.int32).at[pad_bucket].add(1)
        hist = hist.at[-1].add(-corr)
    return hist


def partition_order(keys: jnp.ndarray, num_buckets: int, *, block: int = 2048,
                    interpret: bool = False):
    """Full partition: returns (order, bucket_offsets) such that
    keys[order] is bucket-contiguous (pass 1 kernel + pass 2 jnp)."""
    hist = hash_partition_histogram(
        keys, num_buckets=num_buckets, block=block, interpret=interpret
    )
    bucket = (_hash(keys) % jnp.uint32(num_buckets)).astype(jnp.int32)
    order = jnp.argsort(bucket, stable=True)
    totals = jnp.sum(hist, axis=0)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(totals)[:-1].astype(jnp.int32)])
    return order, offsets
