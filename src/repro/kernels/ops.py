"""jit'd dispatch wrappers: ``impl="auto"`` -> Pallas on TPU, interpret-mode
Pallas or the jnp reference elsewhere.  The model code calls these; the
dry-run lowers the ref path (XLA:CPU cannot codegen Mosaic), real TPU runs
take the kernel path."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import hash_partition as _hp
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rms


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


def flash_attention(q, k, v, *, causal=True, impl: str = "auto", **kw):
    mode = _resolve(impl)
    if mode == "pallas":
        return _fa.flash_attention(q, k, v, causal=causal, **kw)
    if mode == "interpret":
        return _fa.flash_attention(q, k, v, causal=causal, interpret=True, **kw)
    return _ref.flash_attention_ref(q, k, v, causal=causal)


def decode_attention(q, k, v, cache_len, *, impl: str = "auto", **kw):
    mode = _resolve(impl)
    if mode == "pallas":
        return _dec.decode_attention(q, k, v, cache_len, **kw)
    if mode == "interpret":
        return _dec.decode_attention(q, k, v, cache_len, interpret=True, **kw)
    return _ref.decode_attention_ref(q, k, v, cache_len)


def rmsnorm(x, w, *, eps: float = 1e-5, impl: str = "auto", **kw):
    mode = _resolve(impl)
    if mode == "pallas":
        return _rms.rmsnorm(x, w, eps=eps, **kw)
    if mode == "interpret":
        return _rms.rmsnorm(x, w, eps=eps, interpret=True, **kw)
    return _ref.rmsnorm_ref(x, w, eps=eps)


def hash_partition_histogram(keys, *, num_buckets: int, impl: str = "auto", **kw):
    mode = _resolve(impl)
    if mode == "pallas":
        return _hp.hash_partition_histogram(keys, num_buckets=num_buckets, **kw)
    if mode == "interpret":
        return _hp.hash_partition_histogram(
            keys, num_buckets=num_buckets, interpret=True, **kw
        )
    # ref returns the global histogram; shape it like one block
    return _ref.hash_partition_histogram_ref(keys, num_buckets=num_buckets)[None]
