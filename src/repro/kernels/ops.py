"""jit'd dispatch wrappers: ``impl="auto"`` -> Pallas on TPU, interpret-mode
Pallas or the jnp reference elsewhere.  The model code calls these; the
dry-run lowers the ref path (XLA:CPU cannot codegen Mosaic), real TPU runs
take the kernel path."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import hash_partition as _hp
from repro.kernels import prefill_attention as _pf
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rms


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except (RuntimeError, IndexError):  # pragma: no cover - backend probe:
        # RuntimeError = no backend initialised, IndexError = zero devices
        return False


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


def flash_attention(q, k, v, *, causal=True, impl: str = "auto", **kw):
    mode = _resolve(impl)
    if mode == "pallas":
        return _fa.flash_attention(q, k, v, causal=causal, **kw)
    if mode == "interpret":
        return _fa.flash_attention(q, k, v, causal=causal, interpret=True, **kw)
    return _ref.flash_attention_ref(q, k, v, causal=causal)


def _resolve_decode(impl: str) -> str:
    """``auto`` = the Pallas flash-decode kernel on TPU, the jnp oracle
    elsewhere: XLA:CPU vectorizes the oracle's einsum, while emulated
    Pallas pays per-grid-program interpreter overhead that grows with
    ``slots x kv_heads x blocks`` — a measured 2-5x decode-step
    regression at 16 slots on the CPU container.  ``impl="interpret"``
    stays explicitly selectable (the kernel lowers to plain XLA under
    ``interpret=True``) and the CI parity suite + decode microbench run
    it on every PR, so the kernel path is exercised without TPUs."""
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    if impl not in ("pallas", "interpret", "ref"):
        raise ValueError(f"unknown decode impl {impl!r}: "
                         f"expected auto|pallas|interpret|ref")
    return impl


def decode_attention(q, k, v, cache_len, *, window: int = 0,
                     impl: str = "auto", **kw):
    """q [B,H,D]; k,v [B,S,KV,D]; cache_len [] or [B] int32 -> [B,H,D]."""
    mode = _resolve_decode(impl)
    if mode == "pallas":
        return _dec.decode_attention(q, k, v, cache_len, window=window, **kw)
    if mode == "interpret":
        return _dec.decode_attention(q, k, v, cache_len, window=window,
                                     interpret=True, **kw)
    return _ref.decode_attention_ref(q, k, v, cache_len, window=window)


def decode_attention_paged(q, k_pages, v_pages, block_table, cache_len, *,
                           window: int = 0, impl: str = "auto", **kw):
    """q [B,H,D]; pools [num_pages,page_size,KV,D]; block_table [B,max_pages]
    int32 (sentinel >= num_pages = unallocated); cache_len [B] -> [B,H,D]."""
    mode = _resolve_decode(impl)
    if mode == "pallas":
        return _dec.decode_attention_paged(
            q, k_pages, v_pages, block_table, cache_len, window=window, **kw)
    if mode == "interpret":
        return _dec.decode_attention_paged(
            q, k_pages, v_pages, block_table, cache_len, window=window,
            interpret=True, **kw)
    return _ref.decode_attention_paged_ref(
        q, k_pages, v_pages, block_table, cache_len, window=window)


def prefill_attention(q, k_new, v_new, k_cache, v_cache, base, chunk_lens,
                      *, impl: str = "auto", **kw):
    """Ragged cache-writing prefill, contiguous layout.  q [B,T,H,D];
    k_new, v_new [B,T,KV,D]; caches [B,S,KV,D]; base, chunk_lens [] or
    [B] int32 -> (out [B,T,H,D], k_cache', v_cache')."""
    mode = _resolve_decode(impl)
    if mode == "pallas":
        return _pf.prefill_attention(
            q, k_new, v_new, k_cache, v_cache, base, chunk_lens, **kw)
    if mode == "interpret":
        return _pf.prefill_attention(
            q, k_new, v_new, k_cache, v_cache, base, chunk_lens,
            interpret=True, **kw)
    return _ref.prefill_attention_ref(
        q, k_new, v_new, k_cache, v_cache, base, chunk_lens)


def prefill_attention_paged(q, k_new, v_new, k_pages, v_pages, block_table,
                            base, chunk_lens, *, impl: str = "auto", **kw):
    """Ragged cache-writing prefill through per-row block tables.
    q [B,T,H,D]; pools [num_pages,page_size,KV,D]; block_table
    [B,max_pages] int32 (sentinel >= num_pages = unallocated);
    base, chunk_lens [] or [B] int32 -> (out, k_pages', v_pages')."""
    mode = _resolve_decode(impl)
    if mode == "pallas":
        return _pf.prefill_attention_paged(
            q, k_new, v_new, k_pages, v_pages, block_table, base,
            chunk_lens, **kw)
    if mode == "interpret":
        return _pf.prefill_attention_paged(
            q, k_new, v_new, k_pages, v_pages, block_table, base,
            chunk_lens, interpret=True, **kw)
    return _ref.prefill_attention_paged_ref(
        q, k_new, v_new, k_pages, v_pages, block_table, base, chunk_lens)


def rmsnorm(x, w, *, eps: float = 1e-5, impl: str = "auto", **kw):
    mode = _resolve(impl)
    if mode == "pallas":
        return _rms.rmsnorm(x, w, eps=eps, **kw)
    if mode == "interpret":
        return _rms.rmsnorm(x, w, eps=eps, interpret=True, **kw)
    return _ref.rmsnorm_ref(x, w, eps=eps)


def hash_partition_histogram(keys, *, num_buckets: int, impl: str = "auto", **kw):
    mode = _resolve(impl)
    if mode == "pallas":
        return _hp.hash_partition_histogram(keys, num_buckets=num_buckets, **kw)
    if mode == "interpret":
        return _hp.hash_partition_histogram(
            keys, num_buckets=num_buckets, interpret=True, **kw
        )
    # ref returns the global histogram; shape it like one block
    return _ref.hash_partition_histogram_ref(keys, num_buckets=num_buckets)[None]
