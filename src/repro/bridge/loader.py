"""Deep RC Data Bridge: the zero-copy distributed data loader.

Paper §2.4: the Cylon Global Table is handed to the DL framework without a
materializing copy; workers prefetch batches in parallel; pinned memory +
DMA overlap host->device transfers.

TPU-native re-founding:

* ``ZeroCopyLoader`` — the GT's columns already live in HBM sharded over
  the mesh's data axis.  A batch is a compiled gather (slice or
  permutation-take) on those buffers: no host roundtrip, no copy of the
  table.  This *is* the zero-copy claim, made structural.
* ``HostPrefetcher`` — for host-resident sources (the paper's
  pinned-memory DMA case): a double-buffered ``device_put`` pipeline that
  keeps transfer N+1 in flight while step N computes.
"""
from __future__ import annotations

import collections
import threading
from typing import Callable, Dict, Iterator, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dataframe.table import Table


class ZeroCopyLoader:
    """Iterate (features, labels) minibatches straight off a distributed
    Table.  Batches are device-resident views (compiled gathers); an
    optional per-epoch on-device permutation provides shuffling."""

    def __init__(
        self,
        table: Table,
        feature_cols: Sequence[str],
        label_col: str,
        global_batch: int,
        *,
        shuffle: bool = True,
        seed: int = 0,
        drop_remainder: bool = True,
    ):
        self.table = table
        self.feature_cols = list(feature_cols)
        self.label_col = label_col
        self.global_batch = int(global_batch)
        self.shuffle = shuffle
        self.seed = seed
        n = table.num_rows
        self.steps_per_epoch = n // self.global_batch if drop_remainder else -(-n // self.global_batch)

        mesh = table.mesh
        if mesh is not None:
            out_shard = NamedSharding(mesh, P(table.axis))
        else:
            out_shard = None

        def gather_batch(cols, valid, perm, step):
            lo = step * self.global_batch
            idx = jax.lax.dynamic_slice_in_dim(perm, lo, self.global_batch)
            feats = jnp.stack(
                [jnp.take(cols[c], idx, axis=0).astype(jnp.float32)
                 for c in self.feature_cols], axis=-1,
            )
            labels = jnp.take(cols[self.label_col], idx, axis=0)
            mask = jnp.take(valid, idx, axis=0)
            return feats, labels, mask

        self._gather = jax.jit(
            gather_batch,
            out_shardings=(out_shard, out_shard, out_shard) if out_shard else None,
        )
        self._perm_fn = jax.jit(
            lambda key, n: jax.random.permutation(key, n),
            static_argnums=(1,),
        )

    def epoch(self, epoch_idx: int = 0) -> Iterator:
        n = self.table.num_rows
        if self.shuffle:
            perm = self._perm_fn(jax.random.PRNGKey(self.seed + epoch_idx), n)
        else:
            perm = jnp.arange(n)
        for step in range(self.steps_per_epoch):
            yield self._gather(self.table.columns, self.table.valid, perm, step)

    def __iter__(self):
        return self.epoch(0)


class HostPrefetcher:
    """Double-buffered host->device pipeline (the pinned-memory/DMA overlap
    of the paper, expressed as ahead-of-time ``device_put``)."""

    def __init__(self, host_iter: Iterator, sharding=None, depth: int = 2):
        self.host_iter = host_iter
        self.sharding = sharding
        self.depth = depth
        self._queue: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._exhausted = False

    def _put(self, item):
        if self.sharding is not None:
            return jax.tree.map(lambda x: jax.device_put(x, self.sharding), item)
        return jax.tree.map(jax.device_put, item)

    def _fill(self):
        while len(self._queue) < self.depth and not self._exhausted:
            try:
                item = next(self.host_iter)
            except StopIteration:
                self._exhausted = True
                return
            self._queue.append(self._put(item))  # transfer starts async

    def __iter__(self):
        return self

    def __next__(self):
        with self._lock:
            self._fill()
            if not self._queue:
                raise StopIteration
            out = self._queue.popleft()
            self._fill()  # keep next transfer in flight
            return out


def window_batches(
    table: Table,
    series_col: str,
    window: int,
    horizon: int,
    global_batch: int,
    *,
    key: Optional[jax.Array] = None,
):
    """Forecasting helper: sample (window -> horizon) slices from a time
    series column, entirely on device (used by the NeuralForecast-analogue
    pipelines)."""
    series = table.col(series_col)
    n = series.shape[0] - window - horizon
    if key is None:
        key = jax.random.PRNGKey(0)
    starts = jax.random.randint(key, (global_batch,), 0, max(n, 1))
    idx = starts[:, None] + jnp.arange(window + horizon)[None, :]
    data = jnp.take(series, idx, axis=0)
    return data[:, :window], data[:, window:]
