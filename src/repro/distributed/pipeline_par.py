"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis
(shard_map + non-cyclic collective_permute).

Layers split into S stages (one per pipe rank); microbatches stream through
with the classic fill-drain schedule expressed as ``lax.scan`` over
``n_micro + S - 1`` ticks: each tick every stage applies its layers to the
microbatch it holds and permutes the activation rightward.  A feature-flag
option validated at test scale (4-stage mesh); the assigned dry-run matrix
uses DP x TP x EP (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.compat import axis_size


def pipeline_forward(
    stage_fn: Callable,      # stage_fn(stage_params, x) -> y  (one stage)
    stage_params,            # pytree with leading [n_stages, ...] dims
    x_micro: jnp.ndarray,    # [n_micro, micro_batch, ...]
    mesh: Mesh,
    axis: str = "pipe",
) -> jnp.ndarray:
    """Returns [n_micro, micro_batch, ...] outputs (all stages applied)."""
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]

    def per_stage(params_stage, queue):
        S = axis_size(axis)
        stage = jax.lax.axis_index(axis)
        ticks = n_micro + S - 1
        feat_shape = queue.shape[1:]

        def tick(carry, t):
            hold, outputs = carry
            src = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(
                stage == 0,
                jax.lax.dynamic_index_in_dim(queue, src, keepdims=False),
                hold,
            )
            active = (t >= stage) & (t - stage < n_micro)
            y = stage_fn(jax.tree.map(lambda p: p[0], params_stage), x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # rightward non-cyclic handoff; stage 0 receives zeros
            passed = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(S - 1)]
            )
            out_idx = jnp.clip(t - (S - 1), 0, n_micro - 1)
            write = (stage == S - 1) & active
            outputs = jnp.where(
                write,
                jax.lax.dynamic_update_index_in_dim(outputs, y, out_idx, 0),
                outputs,
            )
            return (passed, outputs), None

        hold0 = jnp.zeros(feat_shape, queue.dtype)
        out0 = jnp.zeros((n_micro,) + feat_shape, queue.dtype)
        (_, outputs), _ = jax.lax.scan(tick, (hold0, out0), jnp.arange(ticks))
        return outputs[None]  # [1, n_micro, ...] per stage

    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(axis),
        check_rep=False,
    )
    stacked = fn(stage_params, x_micro)  # [S, n_micro, ...]
    return stacked[-1]
