"""Trip-count-aware cost analysis over compiled HLO text.

``compiled.cost_analysis()`` counts a ``while`` body **once**, so any model
using scan-over-layers (all of ours) would under-report FLOPs/bytes by ~L x.
This module re-derives the three roofline inputs by parsing the optimized,
SPMD-partitioned HLO text (``compiled.as_text()``):

* ``flops``            — dot / convolution FLOPs (+1 FLOP per element of
                         elementwise fusions), with while bodies multiplied by
                         their statically-known trip count;
* ``bytes``            — HBM-traffic proxy: sum of (operand + output) bytes of
                         materializing instructions (fusion/dot/conv/copy/
                         collective), trip-count scaled;
* ``collective_bytes`` — per collective kind (all-gather, all-reduce,
                         reduce-scatter, all-to-all, collective-permute), sum
                         of operand bytes, trip-count scaled.

All numbers are **per device** (the compiled module is the per-device SPMD
program).  The roofline layer multiplies by chip count where totals are
needed.  This is a static analysis of an XLA:CPU-optimized module standing in
for the TPU compile — fusion decisions differ, which we note in
EXPERIMENTS.md; dot/collective placement (what the roofline feeds on) is
decided by SPMD partitioning, which is shared infrastructure.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> float:
    """Bytes of a (possibly tuple) shape string."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dims = m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _first_shape_dims(shape_str: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return ()
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclasses.dataclass
class Instruction:
    name: str
    shape: str          # full result type string
    opcode: str
    operands: List[str]
    attrs: str          # raw trailing text (attributes)


@dataclasses.dataclass
class Computation:
    name: str
    instructions: Dict[str, Instruction]
    order: List[str]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: Dict[str, int] = dataclasses.field(default_factory=dict)

    def __iadd__(self, other: "Cost") -> "Cost":
        self.flops += other.flops
        self.bytes += other.bytes
        self.collective_bytes += other.collective_bytes
        for k, v in other.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v
        for k, v in other.collective_count.items():
            self.collective_count[k] = self.collective_count.get(k, 0) + v
        return self

    def scaled(self, factor: float) -> "Cost":
        return Cost(
            self.flops * factor,
            self.bytes * factor,
            self.collective_bytes * factor,
            {k: v * factor for k, v in self.per_collective.items()},
            {k: int(v * factor) for k, v in self.collective_count.items()},
        )


# ---------------------------------------------------------------------------
# Parsing
# ---------------------------------------------------------------------------

_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w.\-]+)")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    comment_re = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment_re.sub("", raw).rstrip()
        if cur is None:
            m = _COMP_HEADER_RE.match(line.strip())
            if m and "{" in line:
                cur = Computation(m.group(2), {}, [])
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.startswith("}") or line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, opcode, rest = m.groups()
        # operand section = up to matching paren at depth 0
        depth = 1
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str, attrs = rest[:end], rest[end + 1:]
        operands = _OPERAND_RE.findall(operand_str)
        if opcode == "parameter":
            # keep the parameter index recoverable (operand text is "N")
            attrs = f"param_index={operand_str.strip()} " + attrs
        inst = Instruction(name, shape, opcode, operands, attrs)
        cur.instructions[name] = inst
        cur.order.append(name)
    return comps, entry


# ---------------------------------------------------------------------------
# FLOP formulas
# ---------------------------------------------------------------------------


def _dot_flops(inst: Instruction, comp: Computation) -> float:
    out_elems = _shape_elems(inst.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.attrs)
    if not m or not inst.operands:
        return 2.0 * out_elems  # degenerate
    lhs = comp.instructions.get(inst.operands[0])
    if lhs is None:
        return 2.0 * out_elems
    lhs_dims = _first_shape_dims(lhs.shape)
    k = 1
    for d in m.group(1).split(","):
        if d != "" and int(d) < len(lhs_dims):
            k *= lhs_dims[int(d)]
    return 2.0 * out_elems * k


def _conv_flops(inst: Instruction, comp: Computation) -> float:
    out_elems = _shape_elems(inst.shape)
    if len(inst.operands) < 2:
        return 2.0 * out_elems
    rhs = comp.instructions.get(inst.operands[1])
    if rhs is None:
        return 2.0 * out_elems
    k_dims = _first_shape_dims(rhs.shape)
    # kernel = spatial... x in_ch x out_ch (whatever the layout: total / out_ch
    # upper-bounds the per-output work; use total elems / largest dim as proxy)
    k_elems = 1
    for d in k_dims:
        k_elems *= d
    out_ch = max(k_dims) if k_dims else 1
    return 2.0 * out_elems * max(k_elems // max(out_ch, 1), 1)


_MATERIALIZING = {
    "fusion", "dot", "convolution", "copy", "transpose", "reduce", "sort",
    "scatter", "gather", "dynamic-slice", "dynamic-update-slice", "select",
    "broadcast", "iota", "rng", "pad", "concatenate", "reverse", "slice",
    "convert", "add", "multiply", "subtract", "divide", "exponential",
    "tanh", "rsqrt", "maximum", "minimum", "compare",
} | set(_COLLECTIVES)

_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
               "after-all", "partition-id", "replica-id"}


# ---------------------------------------------------------------------------
# Evaluation with trip counts
# ---------------------------------------------------------------------------


# operands that are while-loop-invariant and at most this size are modeled
# as VMEM-resident across iterations (charged once, not per trip) — TPU v5e
# has 128 MB VMEM; 16 MB per pinned operand is conservative.
VMEM_RESIDENT_BYTES = 16 * 1024 * 1024


class ModuleCost:
    def __init__(self, text: str):
        self.text = text
        self.comps, self.entry_name = parse_module(text)
        self._const_vals = self._parse_constants(text)
        self._memo: Dict[Tuple[str, frozenset], Cost] = {}
        self.unknown_trip_loops = 0

    def _invariant_resident_gtes(self, body_name: str) -> frozenset:
        """GTE instructions in a while body that (a) pass through the loop
        unchanged (root tuple returns them as-is) and (b) are small enough
        to stay VMEM-resident."""
        body = self.comps.get(body_name)
        if body is None or not body.order:
            return frozenset()
        root = body.instructions[body.order[-1]]
        if root.opcode != "tuple":
            return frozenset()
        gte_index = {}
        for name in body.order:
            inst = body.instructions[name]
            if inst.opcode == "get-tuple-element":
                m = re.search(r"index=(\d+)", inst.attrs)
                if m:
                    gte_index[name] = int(m.group(1))
        resident = set()
        for pos, operand in enumerate(root.operands):
            if gte_index.get(operand) == pos:
                inst = body.instructions[operand]
                if _shape_bytes(inst.shape) <= VMEM_RESIDENT_BYTES:
                    resident.add(operand)
        return frozenset(resident)

    @staticmethod
    def _parse_constants(text: str) -> Dict[str, int]:
        """Map computation-qualified constant names -> integer values."""
        vals: Dict[str, int] = {}
        for m in re.finditer(r"%([\w.\-]+)\s*=\s*s32\[\]\s*constant\((-?\d+)\)", text):
            vals[m.group(1)] = int(m.group(2))
        return vals

    def _while_trip(self, cond_name: str) -> int:
        cond = self.comps.get(cond_name)
        if cond is None:
            return 1
        # scan pattern: ROOT compare(iter, K) (possibly via a wrapped fusion).
        # Prefer constants feeding the root; fall back to any s32 constant.
        root = cond.instructions.get(cond.order[-1]) if cond.order else None
        if root is not None:
            for o in root.operands:
                if o in self._const_vals and self._const_vals[o] > 0:
                    return self._const_vals[o]
        for name in cond.order:
            if name in self._const_vals and self._const_vals[name] > 0:
                return self._const_vals[name]
        self.unknown_trip_loops += 1
        return 1

    def comp_cost(self, comp_name: str, resident: frozenset = frozenset()) -> Cost:
        key = (comp_name, resident)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(comp_name)
        total = Cost()
        if comp is None:
            self._memo[key] = total
            return total
        self._memo[key] = total  # guard cycles
        for name in comp.order:
            inst = comp.instructions[name]
            op = inst.opcode
            if op == "while":
                m = re.search(r"condition=%?([\w.\-]+)", inst.attrs)
                b = re.search(r"body=%?([\w.\-]+)", inst.attrs)
                trips = self._while_trip(m.group(1)) if m else 1
                if b:
                    body_name = b.group(1)
                    res = self._invariant_resident_gtes(body_name)
                    body_cost = self.comp_cost(body_name, res)
                    total += body_cost.scaled(trips)
                    if res:
                        # charge the resident operands' HBM read once
                        body = self.comps[body_name]
                        once = sum(_shape_bytes(body.instructions[n].shape)
                                   for n in res)
                        total += Cost(bytes=once)
                continue
            if op == "conditional":
                branches = re.findall(r"%([\w.\-]+)", inst.attrs)
                best = Cost()
                for br in branches:
                    c = self.comp_cost(br)
                    if c.flops + c.bytes >= best.flops + best.bytes:
                        best = c
                total += best
                continue
            if op in ("call", "async-start", "async-done"):
                m = _CALLS_RE.search(inst.attrs)
                if m:
                    total += self.comp_cost(m.group(1))
                continue
            if op == "fusion":
                m = _CALLS_RE.search(inst.attrs)
                inner = self.comp_cost(m.group(1)) if m else Cost()
                c = Cost()
                c.flops = inner.flops if inner.flops > 0 else float(_shape_elems(inst.shape))
                c.bytes = self._io_bytes(inst, comp, resident)
                total += c
                continue
            if op == "dot":
                c = Cost(flops=_dot_flops(inst, comp), bytes=self._io_bytes(inst, comp, resident))
                total += c
                continue
            if op == "convolution":
                c = Cost(flops=_conv_flops(inst, comp), bytes=self._io_bytes(inst, comp, resident))
                total += c
                continue
            if op in _COLLECTIVES:
                opb = self._operand_bytes(inst, comp, resident)
                c = Cost(bytes=self._io_bytes(inst, comp, resident), collective_bytes=opb)
                c.per_collective[op] = opb
                c.collective_count[op] = 1
                total += c
                continue
            if op in _SKIP_BYTES:
                continue
            if op in _MATERIALIZING:
                total += Cost(
                    flops=float(_shape_elems(inst.shape)),
                    bytes=self._io_bytes(inst, comp, resident),
                )
        self._memo[key] = total
        return total

    def _operand_bytes(self, inst: Instruction, comp: Computation,
                       resident: frozenset = frozenset()) -> float:
        b = 0.0
        for o in inst.operands:
            if o in resident:
                continue
            src = comp.instructions.get(o)
            if src is not None:
                b += _shape_bytes(src.shape)
        return b

    def _io_bytes(self, inst: Instruction, comp: Computation,
                  resident: frozenset = frozenset()) -> float:
        """HBM traffic of one instruction.  Sliced accesses are charged at
        the slice size, not the full buffer — a scan body dynamic-slicing
        one layer out of [L, ...] stacked weights reads one layer, and a
        cache update writes one position (TPU aliases DUS in place)."""
        op = inst.opcode
        out_b = _shape_bytes(inst.shape)
        if op == "dynamic-slice" or op == "slice":
            return 2.0 * out_b  # read slice + write result
        if op == "dynamic-update-slice":
            upd = comp.instructions.get(inst.operands[1]) if len(inst.operands) > 1 else None
            ub = _shape_bytes(upd.shape) if upd is not None else out_b
            return 2.0 * ub  # read-modify-write of the updated region
        if op == "fusion":
            return self._fusion_io_bytes(inst, comp, resident)
        return out_b + self._operand_bytes(inst, comp, resident)

    def _fusion_io_bytes(self, inst: Instruction, comp: Computation,
                         resident: frozenset) -> float:
        """Fusion operands whose every use inside the fused computation is a
        (dynamic-)slice are charged at the slice size."""
        m = _CALLS_RE.search(inst.attrs)
        inner = self.comps.get(m.group(1)) if m else None
        out_b = _shape_bytes(inst.shape)
        if inner is None:
            return out_b + self._operand_bytes(inst, comp, resident)
        # fusion operand position i corresponds to inner parameter(i)
        by_index: Dict[int, str] = {}
        for n in inner.order:
            ii = inner.instructions[n]
            if ii.opcode == "parameter":
                mm = re.search(r"param_index=(\d+)", ii.attrs)
                if mm:
                    by_index[int(mm.group(1))] = n
        params_in_order = [by_index[i] for i in sorted(by_index)]
        total = out_b
        # in-place update pattern: the fusion contains a DUS on a buffer
        # parameter and returns the (possibly convert-wrapped) buffer — TPU
        # aliases it, so charge the updated region, not the whole stack
        dus_updates = 0.0
        has_buffer_dus = False
        for n in inner.order:
            ii = inner.instructions[n]
            if ii.opcode == "dynamic-update-slice" and len(ii.operands) > 1:
                upd = inner.instructions.get(ii.operands[1])
                if upd is not None and _shape_bytes(upd.shape) < out_b:
                    dus_updates += _shape_bytes(upd.shape)
                    has_buffer_dus = True
        if has_buffer_dus and dus_updates < out_b:
            total = 2.0 * dus_updates
        for pos, o in enumerate(inst.operands):
            if o in resident:
                continue
            src = comp.instructions.get(o)
            if src is None:
                continue
            full = _shape_bytes(src.shape)
            eff = full
            if pos < len(params_in_order):
                pname = params_in_order[pos]
                uses = [inner.instructions[n] for n in inner.order
                        if pname in inner.instructions[n].operands]
                if uses and all(u.opcode in ("dynamic-slice", "slice") or
                                (u.opcode == "dynamic-update-slice" and
                                 u.operands and u.operands[0] == pname)
                                for u in uses):
                    eff = 0.0
                    for u in uses:
                        if u.opcode in ("dynamic-slice", "slice"):
                            eff += _shape_bytes(u.shape)
                        else:
                            upd = inner.instructions.get(u.operands[1]) if len(u.operands) > 1 else None
                            eff += _shape_bytes(upd.shape) if upd is not None else 0.0
                    eff = min(eff, full)
            total += eff
        return total

    def entry_cost(self) -> Cost:
        if self.entry_name and self.entry_name in self.comps:
            return self.comp_cost(self.entry_name)
        # fallback: the computation not referenced by any other
        referenced: set = set()
        for comp in self.comps.values():
            for inst in comp.instructions.values():
                referenced.update(_CALLS_RE.findall(inst.attrs))
        best = Cost()
        for n in self.comps:
            if n in referenced or n.startswith(("fused", "wrapped", "region")):
                continue
            c = self.comp_cost(n)
            if c.flops + c.bytes > best.flops + best.bytes:
                best = c
        return best


def top_bytes_contributors(text: str, k: int = 25) -> List[str]:
    """The §Perf profiler: instructions ranked by trip-scaled HBM bytes.
    Walks the call graph accumulating a per-instruction multiplier."""
    mc = ModuleCost(text)
    rows: List[Tuple[float, str]] = []

    def walk(comp_name: str, mult: float, resident: frozenset):
        comp = mc.comps.get(comp_name)
        if comp is None or mult <= 0:
            return
        for name in comp.order:
            inst = comp.instructions[name]
            op = inst.opcode
            if op == "while":
                m = re.search(r"condition=%?([\w.\-]+)", inst.attrs)
                b = re.search(r"body=%?([\w.\-]+)", inst.attrs)
                trips = mc._while_trip(m.group(1)) if m else 1
                if b:
                    res = mc._invariant_resident_gtes(b.group(1))
                    walk(b.group(1), mult * trips, res)
                continue
            if op in ("call",):
                m = _CALLS_RE.search(inst.attrs)
                if m:
                    walk(m.group(1), mult, frozenset())
                continue
            if op in _SKIP_BYTES or op == "conditional":
                continue
            if op in _MATERIALIZING:
                by = mc._io_bytes(inst, comp, resident) * mult
                if by > 0:
                    opn = re.search(r'op_name="([^"]+)"', inst.attrs)
                    tag = opn.group(1)[-70:] if opn else name
                    rows.append((by, f"{op:22s} {inst.shape[:40]:40s} x{mult:<6.0f} {tag}"))

    entry = mc.entry_name or next(iter(mc.comps))
    walk(entry, 1.0, frozenset())
    rows.sort(reverse=True)
    return [f"{b/1e9:9.2f} GB  {s}" for b, s in rows[:k]]


def analyze(text: str) -> dict:
    mc = ModuleCost(text)
    c = mc.entry_cost()
    return {
        "flops_per_device": c.flops,
        "bytes_per_device": c.bytes,
        "collective_bytes_per_device": c.collective_bytes,
        "per_collective_bytes": dict(sorted(c.per_collective.items())),
        "collective_counts": dict(sorted(c.collective_count.items())),
        "unknown_trip_loops": mc.unknown_trip_loops,
    }


def cpu_f32_dup_bytes(text: str, min_bytes: float = 6.4e7) -> float:
    """XLA:CPU has no native bf16 dots; float-normalization inserts
    module-level f32 copies of large bf16 buffers (e.g. the whole stacked
    KV cache), which a TPU compile would not allocate.  Returns the bytes
    of distinct big f32 convert-outputs that shape-match an existing bf16
    buffer, so the dry-run can report a TPU-adjusted memory figure."""
    f32_converts = set()
    for m in re.finditer(r"=\s*f32\[([0-9,]+)\]\{[^}]*\}\s*convert\(", text):
        dims = m.group(1)
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 >= min_bytes:
            f32_converts.add(dims)
    total = 0.0
    for dims in f32_converts:
        if re.search(r"bf16\[" + re.escape(dims) + r"\]", text):
            n = 1
            for d in dims.split(","):
                n *= int(d)
            total += n * 4
    return total


def collective_schedule(text: str, limit: int = 40) -> List[str]:
    """Human-readable list of collectives (kind, shape, op_name source)."""
    out = []
    for line in text.splitlines():
        s = line.strip()
        if s.startswith("%") and any(f"= {k}" in s or f" {k}(" in s for k in _COLLECTIVES):
            m = re.match(r"%[\w.\-]+\s*=\s*(\S+)\s+([\w\-]+)\(", s)
            opn = re.search(r'op_name="([^"]+)"', s)
            if m:
                out.append(f"{m.group(2)} {m.group(1)}" + (f"  <- {opn.group(1)[:80]}" if opn else ""))
        if len(out) >= limit:
            out.append("... (truncated)")
            break
    return out
