"""Distributed-optimization tricks: quantized gradient all-reduce and
double-buffered collective helpers.

``int8_psum`` — block-wise int8-quantized gradient all-reduce (shard_map):
each rank quantizes its local gradient with a per-block scale, psums the
int8 payload (as int32 accumulators) and dequantizes.  4x less DP-sync
traffic than f32 / 2x less than bf16, with optional error feedback so the
quantization error is carried into the next step instead of lost
(1-bit-Adam-style residual compensation).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.common.compat import axis_size


def _quantize_int8(x: jnp.ndarray, block: int = 256):
    """x: [N] -> (q int8 [N], scales f32 [N/block])."""
    n = x.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad)) if pad else x
    xb = xp.reshape(-1, block)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0], pad


def _dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, pad: int, block: int = 256):
    xb = q.reshape(-1, block).astype(jnp.float32) * scales[:, None]
    x = xb.reshape(-1)
    return x[: x.shape[0] - pad] if pad else x


def int8_psum(x: jnp.ndarray, axis_name: str, *, block: int = 256) -> jnp.ndarray:
    """Quantized psum of a flat f32/bf16 vector inside shard_map/pmap.

    int8 payloads are summed in int32 (no overflow below ~2^23 ranks);
    per-block scales are max-combined so dequantization is conservative.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    q, scales, pad = _quantize_int8(flat, block)
    # scale harmonization: use the max scale across ranks per block so the
    # summed int8 payloads share a common quantization grid
    gmax = jax.lax.pmax(scales, axis_name)
    requant = jnp.clip(
        jnp.round(
            (q.reshape(-1, block).astype(jnp.float32) * scales[:, None]) / gmax[:, None]
        ), -127, 127,
    ).astype(jnp.int32)
    summed = jax.lax.psum(requant, axis_name)
    out = (summed.astype(jnp.float32) * gmax[:, None]).reshape(-1)
    out = out[: out.shape[0] - pad] if pad else out
    return out.reshape(x.shape).astype(x.dtype)


def compressed_grad_sync(
    grads, mesh, axis: str = "data", *, block: int = 256,
    error_feedback: Optional[dict] = None,
):
    """All-reduce a gradient pytree with int8 compression over ``axis``.

    Returns (synced_grads, new_error_feedback).  Call under `jax.jit` with
    grads sharded over ``axis``-replicated layout (DP gradients).
    """
    leaves, treedef = jax.tree.flatten(grads)
    ef_leaves = (jax.tree.leaves(error_feedback)
                 if error_feedback is not None else [None] * len(leaves))

    outs, new_ef = [], []
    for g, ef in zip(leaves, ef_leaves):
        carry_in = g if ef is None else g + ef.astype(g.dtype)

        def sync(v):
            return int8_psum(v, axis, block=block) / axis_size(axis)

        fn = shard_map(
            sync, mesh=mesh,
            in_specs=P(*([None] * g.ndim)),
            out_specs=P(*([None] * g.ndim)),
        )
        synced = fn(carry_in)
        outs.append(synced)
        new_ef.append((carry_in - synced).astype(jnp.float32))
    return (jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, new_ef))
