"""Logical-axis sharding rules -> PartitionSpecs.

Models annotate every parameter / activation dim with a *logical* axis name
("embed", "heads", "vocab", ...).  A rule table maps logical names to (tuples
of) physical mesh axes.  ``spec_for`` applies the table with two safeguards:

* divisibility — a mesh axis (product) that does not divide the dim size is
  dropped (longest usable prefix of the axis tuple wins, then ``None``);
* exclusivity — a mesh axis may appear at most once in a PartitionSpec; the
  first dim that claims it keeps it.

This is what lets one rule table serve 10 architectures whose head counts /
expert counts / batch sizes do not all divide the mesh (e.g. tinyllama's 4 KV
heads on a 16-way model axis fall back to replication automatically).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.params import Param, is_param

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Default rules for the production mesh (pod?, data, model).
# Weights are ZeRO-3/FSDP-sharded over ("pod","data") on their "embed"-like
# dim and tensor-parallel over "model" on their "heads"/"mlp"/"vocab" dim.
DEFAULT_RULES: dict[str, tuple] = {
    # -- weights --
    "embed": ("data",),          # FSDP shard dim (gathered per-layer in scan)
    "embed_pod": ("pod", "data"),  # alt: FSDP over pod too (set via override)
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "q_lora": ("model",),
    "kv_lora": (),               # latent rank: small, replicate
    "mlp": ("model",),
    "experts": ("model",),
    "expert_mlp": (),
    "rnn": ("model",),           # recurrent width
    "conv": (),
    "layers": (),                # scan dim: never sharded
    "stack": (),
    # -- activations --
    "act_batch": ("pod", "data"),
    "act_seq": (),
    "act_seq_sp": ("model",),  # Megatron sequence parallelism (residual stream)
    "act_embed": (),
    "act_heads": ("model",),
    "act_mlp": ("model",),
    "act_vocab": ("model",),
    "act_experts": ("model",),
    # -- kv cache (decode): sequence-split over model (flash-decoding style),
    #    because kv_heads (1..10) rarely divide a 16-way model axis.
    "cache_batch": ("pod", "data"),
    "cache_seq": ("model",),
    "cache_heads": (),
    # -- optimizer / scalar --
    "null": (),
}


def merge_rules(overrides: Optional[dict] = None) -> dict:
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    return rules


# ---------------------------------------------------------------------------
# Spec derivation
# ---------------------------------------------------------------------------


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def spec_for(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: dict,
) -> P:
    """Derive a PartitionSpec for one tensor."""
    used: set = set()
    entries = []
    for dim, logical in zip(shape, axes):
        if logical is None:
            entries.append(None)
            continue
        if logical not in rules:
            raise KeyError(f"no sharding rule for logical axis {logical!r}")
        candidate = tuple(a for a in rules[logical] if a in mesh.shape)
        # drop axes already used by earlier dims
        candidate = tuple(a for a in candidate if a not in used)
        # longest prefix whose size product divides the dim
        chosen: tuple = ()
        for k in range(len(candidate), 0, -1):
            prefix = candidate[:k]
            prod = 1
            for a in prefix:
                prod *= _axis_size(mesh, a)
            if prod > 1 and dim % prod == 0:
                chosen = prefix
                break
        if not chosen:
            entries.append(None)
        else:
            used.update(chosen)
            entries.append(chosen if len(chosen) > 1 else chosen[0])
    # strip trailing Nones for tidiness
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_specs_tree(specs: Any, mesh: Mesh, rules: Optional[dict] = None) -> Any:
    """Param tree -> PartitionSpec tree."""
    rules = merge_rules(rules)
    return jax.tree.map(
        lambda p: spec_for(p.axes, p.shape, mesh, rules), specs, is_leaf=is_param
    )


def param_shardings_tree(specs: Any, mesh: Mesh, rules: Optional[dict] = None) -> Any:
    rules = merge_rules(rules)
    return jax.tree.map(
        lambda p: NamedSharding(mesh, spec_for(p.axes, p.shape, mesh, rules)),
        specs,
        is_leaf=is_param,
    )


@dataclasses.dataclass(frozen=True)
class Axes:
    """Helper to annotate an activation with logical axes inside model code."""

    names: tuple

    def spec(self, shape, mesh, rules) -> P:
        return spec_for(self.names, shape, mesh, rules)


def constrain(x: jax.Array, axes: Sequence[Optional[str]], rules: Optional[dict] = None):
    """with_sharding_constraint via logical axes; no-op outside a mesh ctx."""
    from repro.common.compat import current_mesh

    mesh = current_mesh()
    if mesh is None or mesh.empty:  # pragma: no cover - outside jit/mesh
        return x
    r = merge_rules(rules)
    spec = spec_for(axes, x.shape, mesh, r)
    return jax.lax.with_sharding_constraint(x, spec)
