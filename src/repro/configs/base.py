"""Model / shape / run configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | ssm | hybrid | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0  # 0 -> d_model // num_heads

    # temporal mixer
    attention: str = "gqa"  # gqa | mla | local | rglru-hybrid | xlstm | encdec
    rope_theta: float = 10_000.0
    window: int = 0  # local attention window (0 = full)
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w)

    # MLA (minicpm3 / deepseek style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    dense_ff: int = 0                 # arctic dense-residual FFN width
    first_k_dense: int = 1            # leading dense layers in MoE stacks
    moe_impl: str = "einsum"          # einsum (GShard baseline) | gather (opt)

    # hybrid / recurrent
    rglru_pattern: int = 0   # griffin: every Nth layer is local-attn (1:N-1)
    rnn_width: int = 0       # rg-lru width (0 -> d_model)
    conv_width: int = 4
    slstm_every: int = 0     # xlstm: every Nth block is sLSTM

    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    num_decoder_layers: int = 0
    dec_len_ratio: int = 8  # dec_len = enc_len // ratio for train/prefill

    mlp_act: str = "swiglu"  # swiglu | geglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    input_kind: str = "tokens"  # tokens | embeds (vlm / audio frontends stubs)

    # TP head padding: pad num_heads up to a multiple (zero-init pad heads —
    # mathematically exact at inference; see DESIGN.md §5) so head count
    # divides the 16-way model axis.  0 = off.
    head_pad_multiple: int = 0
    # Megatron-style sequence parallelism: shard the residual stream's seq
    # dim over the model axis between blocks (activation-memory / collective
    # optimization used in §Perf).
    seq_parallel: bool = False
    # remat policy for the scanned unit: "full" recomputes everything;
    # "save_block_outputs" keeps each block's post-collective output so the
    # bwd-side recompute skips re-running its all-reduce (H12, §Perf)
    remat_policy: str = "full"
    # gradient accumulation dtype for microbatching (bf16 halves the
    # accumulator for very large models, e.g. arctic-480b)
    grad_accum_dtype: Any = jnp.float32
    # ZeRO-3 across pods too: shard weights/opt-states over ("pod","data")
    # instead of ("data",) — needed for arctic-480b's 480B params, costs an
    # extra cross-pod (DCN) all-gather per layer
    fsdp_over_pod: bool = False

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16

    # attention ref-path chunking (lowering-time block sizes)
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # decode-attention dispatch for the serving hot path (kernels/ops.py):
    # "auto" = Pallas kernel on TPU, jnp oracle elsewhere (XLA:CPU beats
    # emulated Pallas); "interpret" forces interpret-mode Pallas (kernel
    # debugging / CI parity); "ref" pins the oracle (dry-runs / GSPMD
    # sharding analyses)
    decode_impl: str = "auto"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)

    @property
    def qk_head_dim(self) -> int:
        if self.attention == "mla":
            return self.nope_head_dim + self.rope_head_dim
        return self.head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 16 so the logits dim shards over
        the model axis (Megatron-style vocab padding; pad rows are benign
        extra tokens, documented in DESIGN.md §5)."""
        return -(-self.vocab_size // 16) * 16

    def padded_gqa(self):
        """(H_pad, KV_pad) for TP head padding.

        Pads zero KV heads (whole zero q-groups) and/or zero q heads within
        groups so that H_pad = KV_pad * G_pad is a multiple of
        ``head_pad_multiple`` with uniform group size — zero-init pads make
        the padded network an exact representation of the original
        (DESIGN.md §5).  Minimizes the padded head count.
        """
        m = self.head_pad_multiple
        H, KV = self.num_heads, self.num_kv_heads
        if not m or H % m == 0:
            return H, KV
        G = H // KV
        best = None
        for kvp in range(KV, KV + m + 1):
            for gp in range(G, G + m + 1):
                hp = kvp * gp
                if hp % m == 0 and (best is None or hp < best[0]):
                    best = (hp, kvp)
        return best

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# smoke-scale variants of the same shape kinds (CPU-runnable)
SMOKE_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 64, 4, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 128, 2, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 128, 4, "decode"),
    "long_500k": ShapeConfig("long_500k", 256, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Per-(arch x shape) runtime knobs (microbatching, optimizer, remat)."""

    num_microbatches: int = 1
    optimizer: str = "adamw"       # adamw | adafactor
    opt_state_dtype: Any = jnp.float32
    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    remat: str = "layer"           # none | layer
    grad_compression: str = "none"  # none | int8


def block_pattern(cfg: ModelConfig):
    """(head, unit, repeats, tail): per-layer (temporal, channel) block kinds.

    ``head`` layers run first (unscanned), then ``unit`` is scanned
    ``repeats`` times, then ``tail`` layers run (unscanned).
    """
    L = cfg.num_layers
    if cfg.attention == "xlstm":
        k = cfg.slstm_every or 4
        unit = tuple(
            ("slstm", None) if (i % k == k - 1) else ("mlstm", None) for i in range(k)
        )
        reps, tail_n = divmod(L, k)
        return (), unit, reps, unit[:tail_n]
    if cfg.attention == "rglru-hybrid":
        k = cfg.rglru_pattern or 3  # griffin: (rglru, rglru, local-attn)
        unit = tuple(
            ("local", "mlp") if (i % k == k - 1) else ("rglru", "mlp")
            for i in range(k)
        )
        reps, tail_n = divmod(L, k)
        return (), unit, reps, unit[:tail_n]
    # transformer families
    temporal = "mla" if cfg.attention == "mla" else (
        "local" if cfg.attention == "local" else "attn")
    if cfg.num_experts > 0:
        fkd = cfg.first_k_dense
        head = tuple((temporal, "mlp") for _ in range(fkd))
        return head, ((temporal, "moe"),), L - fkd, ()
    return (), ((temporal, "mlp"),), L, ()
