"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base]."""
import jax.numpy as jnp
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe",
        num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=4864, vocab_size=32000, head_dim=128,
        attention="gqa", mlp_act="swiglu", rope_theta=10_000.0,
        num_experts=128, top_k=2, capacity_factor=1.25,
        moe_dense_residual=True, dense_ff=4864, first_k_dense=0,
        # gather dispatch (§Perf H14): -15% compute / -27% memory / -39%
        # collective vs GShard einsum AND brings train_4k under 16GB/chip.
        # (einsum stays the default family-wide: on moonshot-64e-top6 the
        # same change inflates collectives 4.3x.)
        moe_impl="gather",
        # fp32 AdamW for 480B does not fit 256 x 16GB; bf16 params +
        # Adafactor states (see RunConfig override in launch/dryrun.py).
        param_dtype=jnp.bfloat16,
        head_pad_multiple=16,
        grad_accum_dtype=jnp.bfloat16,

    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke", family="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=32,
        attention="gqa", mlp_act="swiglu",
        num_experts=8, top_k=2, capacity_factor=2.0,
        moe_dense_residual=True, dense_ff=128, first_k_dense=0,
    )
