"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

Attention-free: runs the long_500k shape (O(1) decode state)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        attention="xlstm", slstm_every=4, conv_width=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke", family="ssm",
        num_layers=4, d_model=64, num_heads=2, num_kv_heads=2,
        d_ff=0, vocab_size=256,
        attention="xlstm", slstm_every=4, conv_width=4,
    )
