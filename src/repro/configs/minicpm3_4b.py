"""minicpm3-4b [dense] — MLA (multi-head latent attention)
[hf:openbmb/MiniCPM3-4B]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="dense",
        num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
        d_ff=6400, vocab_size=73448,
        attention="mla", mlp_act="swiglu", rope_theta=10_000.0,
        q_lora_rank=768, kv_lora_rank=256,
        nope_head_dim=64, rope_head_dim=32, v_head_dim=64, head_dim=64,
        head_pad_multiple=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b-smoke", family="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=256,
        attention="mla", mlp_act="swiglu",
        q_lora_rank=48, kv_lora_rank=32,
        nope_head_dim=16, rope_head_dim=8, v_head_dim=16, head_dim=16,
    )
