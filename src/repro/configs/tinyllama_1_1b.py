"""tinyllama-1.1b [dense] — llama2-arch small [arXiv:2401.02385]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b", family="dense",
        num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4,
        d_ff=5632, vocab_size=32000, head_dim=64,
        attention="gqa", mlp_act="swiglu", rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b-smoke", family="dense",
        num_layers=2, d_model=128, num_heads=8, num_kv_heads=2,
        d_ff=256, vocab_size=256, head_dim=16,
        attention="gqa", mlp_act="swiglu",
    )
