"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

The vision frontend (ViT + merger) is a STUB: input_specs supplies
precomputed patch/token embeddings plus 3-D M-RoPE position ids."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=29568, vocab_size=152064, head_dim=128,
        attention="gqa", mlp_act="swiglu", rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24), input_kind="embeds",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b-smoke", family="vlm",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=256, head_dim=32,
        attention="gqa", mlp_act="swiglu",
        mrope_sections=(4, 6, 6), input_kind="embeds",
    )
