"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig,
    RunConfig,
    SHAPES,
    SMOKE_SHAPES,
    ShapeConfig,
    block_pattern,
)

ARCHS = {
    "phi3-medium-14b": "phi3_medium_14b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "minicpm3-4b": "minicpm3_4b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "arctic-480b": "arctic_480b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "xlstm-125m": "xlstm_125m",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-medium": "whisper_medium",
}

# archs whose attention is fully quadratic: long_500k is skipped per brief
FULL_ATTENTION_ARCHS = {
    "phi3-medium-14b", "tinyllama-1.1b", "minicpm3-4b", "phi3-mini-3.8b",
    "moonshot-v1-16b-a3b", "arctic-480b", "qwen2-vl-72b", "whisper-medium",
}


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k" and arch in FULL_ATTENTION_ARCHS:
        return False
    return True


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.smoke_config() if smoke else mod.config()


def default_run_config(arch: str, shape: str) -> RunConfig:
    """Per-cell runtime knobs sized so the dry-run fits 16 GB/chip HBM."""
    import jax.numpy as jnp

    micro = 1
    optimizer, opt_dtype = "adamw", jnp.float32
    if shape == "train_4k":
        # sized so the per-microbatch residual stack (L x tok/dev x d x 2B,
        # double-buffered) + params + opt states fits 16 GB/chip
        micro = {
            "qwen2-vl-72b": 8, "arctic-480b": 16, "phi3-medium-14b": 8,
            "recurrentgemma-9b": 8, "minicpm3-4b": 8, "phi3-mini-3.8b": 4,
            "moonshot-v1-16b-a3b": 8, "whisper-medium": 2,
            "tinyllama-1.1b": 2, "xlstm-125m": 4,
        }.get(arch, 1)
    grad_clip = 1.0
    if arch == "arctic-480b":
        # adafactor: factored states fit HBM; its internal RMS update
        # clipping replaces global-norm clip (whose f32 upcast of the
        # 480B grad tree would spike ~10 GB/device)
        optimizer = "adafactor"
        grad_clip = 0.0
    if arch == "qwen2-vl-72b":
        opt_dtype = jnp.bfloat16
    return RunConfig(num_microbatches=micro, optimizer=optimizer,
                     opt_state_dtype=opt_dtype, grad_clip=grad_clip)
