"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=163840, head_dim=128,
        attention="gqa", mlp_act="swiglu", rope_theta=50_000.0,
        num_experts=64, top_k=6, capacity_factor=1.25,
        first_k_dense=1, dense_ff=11264,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-smoke", family="moe",
        num_layers=3, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=32,
        attention="gqa", mlp_act="swiglu",
        num_experts=8, top_k=2, capacity_factor=2.0,
        first_k_dense=1, dense_ff=256,
    )
