"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 pattern
[arXiv:2402.19427].  Sub-quadratic: runs the long_500k shape."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        d_ff=12288, vocab_size=256000, head_dim=256,
        attention="rglru-hybrid", rglru_pattern=3, window=2048,
        rnn_width=4096, conv_width=4, mlp_act="geglu",
        remat_policy="save_block_outputs",  # §Perf H12: -7.4% collective
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke", family="hybrid",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=128, vocab_size=256, head_dim=16,
        attention="rglru-hybrid", rglru_pattern=3, window=16,
        rnn_width=64, conv_width=4, mlp_act="geglu",
    )
