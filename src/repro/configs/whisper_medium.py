"""whisper-medium [audio] — enc-dec; conv frontend is a STUB
(input_specs supplies frame embeddings) [arXiv:2212.04356].

dec_len = enc_len // dec_len_ratio for train/prefill shapes."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="audio",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=4096, vocab_size=51865, head_dim=64,
        attention="encdec", mlp_act="gelu", input_kind="embeds",
        is_encoder_decoder=True, num_decoder_layers=24, dec_len_ratio=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
        attention="encdec", mlp_act="gelu", input_kind="embeds",
        is_encoder_decoder=True, num_decoder_layers=2, dec_len_ratio=8,
    )
