"""phi3-medium-14b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", family="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
        d_ff=17920, vocab_size=100352, head_dim=128,
        attention="gqa", mlp_act="swiglu", rope_theta=10_000.0,
        head_pad_multiple=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b-smoke", family="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=256, head_dim=32,
        attention="gqa", mlp_act="swiglu",
    )
