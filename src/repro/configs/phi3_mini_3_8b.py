"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b", family="dense",
        num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32064, head_dim=96,
        attention="gqa", mlp_act="swiglu", rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b-smoke", family="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=256, head_dim=32,
        attention="gqa", mlp_act="swiglu",
    )
