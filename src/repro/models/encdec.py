"""Whisper-style encoder-decoder backbone.

The conv frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings [B, T_enc, d] (what the two conv layers would
produce).  Encoder = bidirectional attention stack with sinusoidal positions;
decoder = causal self-attention (+ cache) x cross-attention to the encoder
output x MLP.  Cross K/V are precomputed once per sequence and live in the
decode cache.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.params import Param, is_param
from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models.lm import _stack


def _sinusoid(S: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-dim * (jnp.log(10000.0) / max(d // 2 - 1, 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_specs(cfg: ModelConfig):
    return {"t": B.attn_specs(cfg), "c": B.mlp_specs(cfg)}


def _dec_layer_specs(cfg: ModelConfig):
    return {
        "self": B.attn_specs(cfg),
        "cross": B.attn_specs(cfg),
        "c": B.mlp_specs(cfg),
    }


def encdec_specs(cfg: ModelConfig) -> Dict[str, Any]:
    n_dec = cfg.num_decoder_layers or cfg.num_layers
    return {
        "embed": Param((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), init="embed"),
        "enc_unit": _stack({"b0": _enc_layer_specs(cfg)}, cfg.num_layers),
        "enc_norm": B.rmsnorm_specs(cfg.d_model),
        "dec_unit": _stack({"b0": _dec_layer_specs(cfg)}, n_dec),
        "final_norm": B.rmsnorm_specs(cfg.d_model),
        "lm_head": Param((cfg.d_model, cfg.padded_vocab), ("embed", "vocab")),
    }


def encdec_cache_specs(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    cdt = cfg.compute_dtype
    n_dec = cfg.num_decoder_layers or cfg.num_layers
    per_layer = {
        "k": Param((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                   ("cache_batch", "cache_seq", "cache_heads", None), dtype=cdt, init="zeros"),
        "v": Param((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                   ("cache_batch", "cache_seq", "cache_heads", None), dtype=cdt, init="zeros"),
        "xk": Param((batch, enc_len, cfg.num_kv_heads, cfg.head_dim),
                    ("cache_batch", "cache_seq", "cache_heads", None), dtype=cdt, init="zeros"),
        "xv": Param((batch, enc_len, cfg.num_kv_heads, cfg.head_dim),
                    ("cache_batch", "cache_seq", "cache_heads", None), dtype=cdt, init="zeros"),
    }
    return {"dec_unit": _stack({"b0": per_layer}, n_dec)}


def encode(cfg: ModelConfig, params, frames: jnp.ndarray, *, remat: bool = True):
    """frames: [B, T, d] stubbed conv-frontend output -> encoder states."""
    x = (frames + _sinusoid(frames.shape[1], cfg.d_model)[None]).astype(cfg.compute_dtype)
    Bsz, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (Bsz, S)).astype(jnp.int32)

    def body(x, p_i):
        p = p_i["b0"]
        x, _ = B.attn_apply(cfg, p["t"], x, positions, causal=False)
        x = B.mlp_apply(cfg, p["c"], x)
        return x, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["enc_unit"])
    return B.rmsnorm_apply(params["enc_norm"], x, cfg.norm_eps)


def decode_train(cfg: ModelConfig, params, enc_out, tokens, *, remat: bool = True, last_only: bool = False):
    """Teacher-forced decoder pass. tokens: [B, T_dec] -> logits."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    Bsz, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (Bsz, S)).astype(jnp.int32)

    def body(x, p_i):
        p = p_i["b0"]
        x, _ = B.attn_apply(cfg, p["self"], x, positions, causal=True)
        x, _ = B.attn_apply(cfg, p["cross"], x, positions, causal=False, kv_source=enc_out)
        x = B.mlp_apply(cfg, p["c"], x)
        return x, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["dec_unit"])
    if last_only:
        x = x[:, -1:]
    x = B.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return jnp.einsum(
        "bsd,dv->bsv", x.astype(cfg.compute_dtype), params["lm_head"].astype(cfg.compute_dtype)
    )


def precompute_cross_cache(cfg: ModelConfig, params, enc_out):
    """Per-layer cross K/V from encoder output (fills the decode cache)."""
    cdt = cfg.compute_dtype

    def body(_, p_i):
        p = p_i["b0"]["cross"]
        src = enc_out.astype(cdt)
        xk = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(cdt))
        xv = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(cdt))
        return None, {"b0": {"xk": xk, "xv": xv}}

    _, cross = jax.lax.scan(body, None, params["dec_unit"])
    return cross


def decode_step(cfg: ModelConfig, params, tokens, cache, cache_len):
    """Single-token decode. tokens: [B,1]; cache per layer: self k/v (+len)
    and precomputed cross xk/xv."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    Bsz = x.shape[0]
    positions = jnp.broadcast_to(cache_len[None, None], (Bsz, 1)).astype(jnp.int32)

    def body(x, xs):
        p_i, c_i = xs
        p, c = p_i["b0"], c_i["b0"]
        self_cache = {"k": c["k"], "v": c["v"], "len": cache_len}
        x, nc_self = B.attn_apply(cfg, p["self"], x, positions, self_cache, causal=True)
        cross_cache = {"xk": c["xk"], "xv": c["xv"], "xlen": c["xk"].shape[1]}
        x, _ = B.attn_apply(cfg, p["cross"], x, positions, cross_cache, causal=False,
                            kv_source=jnp.zeros((Bsz, 1, cfg.d_model), x.dtype))
        x = B.mlp_apply(cfg, p["c"], x)
        return x, {"b0": {"k": nc_self["k"], "v": nc_self["v"], "xk": c["xk"], "xv": c["xv"]}}

    x, new_cache = jax.lax.scan(body, x, (params["dec_unit"], cache["dec_unit"]))
    x = B.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", x.astype(cfg.compute_dtype), params["lm_head"].astype(cfg.compute_dtype)
    )
    return logits, {"dec_unit": new_cache}
