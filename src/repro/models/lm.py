"""Unified decoder-style LM covering dense / MoE / VLM / SSM / hybrid
families via ``configs.base.block_pattern``.

Layers are grouped into a repeating *unit* which is ``lax.scan``-ned over
(stacked parameters, stacked caches); head/tail layers run unscanned.  This
keeps compile time O(unit) instead of O(num_layers) — essential for the
512-device dry-runs — while the HLO cost analyzer multiplies while-bodies by
their trip count so roofline numbers stay honest.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.params import Param, is_param
from repro.configs.base import ModelConfig, block_pattern
from repro.models import blocks as B
from repro.models import recurrent as R

# ---------------------------------------------------------------------------
# block kind dispatch
# ---------------------------------------------------------------------------


def _temporal_specs(kind: str, cfg: ModelConfig):
    if kind in ("attn", "local"):
        return B.attn_specs(cfg)
    if kind == "mla":
        return B.mla_specs(cfg)
    if kind == "rglru":
        return R.rglru_specs(cfg)
    if kind == "mlstm":
        return R.mlstm_specs(cfg)
    if kind == "slstm":
        return R.slstm_specs(cfg)
    raise ValueError(kind)


def _temporal_apply(kind: str, cfg, params, x, positions, cache,
                    chunk_lens=None):
    if kind == "attn":
        return B.attn_apply(cfg, params, x, positions, cache, causal=True,
                            chunk_lens=chunk_lens)
    if kind == "local":
        if chunk_lens is not None:
            raise NotImplementedError(
                "chunked prefill does not support windowed (local) "
                "attention: ring cache writes need the full prompt")
        return B.attn_apply(cfg, params, x, positions, cache, causal=True, window=cfg.window)
    if kind == "mla":
        return B.mla_apply(cfg, params, x, positions, cache,
                           chunk_lens=chunk_lens)
    if chunk_lens is not None:
        raise NotImplementedError(
            f"chunked prefill supports attention-family blocks only, "
            f"got {kind!r}")
    if kind == "rglru":
        return R.rglru_block_apply(cfg, params, x, cache)
    if kind == "mlstm":
        return R.mlstm_block_apply(cfg, params, x, cache)
    if kind == "slstm":
        return R.slstm_block_apply(cfg, params, x, cache)
    raise ValueError(kind)


def _layer_specs(cfg: ModelConfig, tk: str, ck: Optional[str]):
    specs = {"t": _temporal_specs(tk, cfg)}
    if ck == "mlp":
        # in MoE stacks the dense head/tail layers use dense_ff if set
        ff = cfg.dense_ff if (cfg.num_experts > 0 and cfg.dense_ff) else None
        specs["c"] = B.mlp_specs(cfg, ff)
    elif ck == "moe":
        specs["c"] = B.moe_specs(cfg)
    return specs


def _layer_apply(cfg, tk, ck, params, x, positions, cache, chunk_lens=None):
    x, new_cache = _temporal_apply(tk, cfg, params["t"], x, positions, cache,
                                   chunk_lens)
    aux = jnp.zeros((), jnp.float32)
    if ck == "mlp":
        x = B.mlp_apply(cfg, params["c"], x)
    elif ck == "moe":
        x, aux = B.moe_apply(cfg, params["c"], x)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# cache declarations (as Param trees so the dry-run can make abstract caches)
# ---------------------------------------------------------------------------


def _temporal_cache_specs(kind: str, cfg: ModelConfig, batch: int, max_len: int):
    cdt = cfg.compute_dtype
    if kind in ("attn", "local"):
        _, KV = cfg.padded_gqa()
        slots = min(max_len, cfg.window) if (kind == "local" and cfg.window) else max_len
        return {
            "k": Param((batch, slots, KV, cfg.qk_head_dim),
                       ("cache_batch", "cache_seq", "cache_heads", None), dtype=cdt, init="zeros"),
            "v": Param((batch, slots, KV, cfg.head_dim if kind != "mla" else cfg.v_head_dim),
                       ("cache_batch", "cache_seq", "cache_heads", None), dtype=cdt, init="zeros"),
        }
    if kind == "mla":
        return {
            "c_kv": Param((batch, max_len, cfg.kv_lora_rank),
                          ("cache_batch", "cache_seq", None), dtype=cdt, init="zeros"),
            "k_pe": Param((batch, max_len, cfg.rope_head_dim),
                          ("cache_batch", "cache_seq", None), dtype=cdt, init="zeros"),
        }
    if kind == "rglru":
        r, w = cfg.rnn_width, cfg.conv_width
        return {
            "conv": Param((batch, w - 1, r), ("cache_batch", None, "rnn"), dtype=cdt, init="zeros"),
            "h": Param((batch, r), ("cache_batch", "rnn"), dtype=jnp.float32, init="zeros"),
        }
    if kind == "mlstm":
        m = 2 * cfg.d_model
        nh = cfg.num_heads
        dh = m // nh
        return {
            "conv": Param((batch, cfg.conv_width - 1, m), ("cache_batch", None, "rnn"), dtype=cdt, init="zeros"),
            "C": Param((batch, nh, dh, dh), ("cache_batch", None, None, None), dtype=jnp.float32, init="zeros"),
            "n": Param((batch, nh, dh), ("cache_batch", None, None), dtype=jnp.float32, init="zeros"),
            "m": Param((batch, nh), ("cache_batch", None), dtype=jnp.float32, init="zeros"),
        }
    if kind == "slstm":
        d = cfg.d_model
        return {
            "c": Param((batch, d), ("cache_batch", "rnn"), dtype=jnp.float32, init="zeros"),
            "n": Param((batch, d), ("cache_batch", "rnn"), dtype=jnp.float32, init="zeros"),
            "h": Param((batch, d), ("cache_batch", "rnn"), dtype=jnp.float32, init="zeros"),
            "m": Param((batch, d), ("cache_batch", "rnn"), dtype=jnp.float32, init="zeros"),
        }
    raise ValueError(kind)


def _temporal_paged_cache_specs(kind: str, cfg: ModelConfig,
                                num_pages: int, page_size: int):
    """Paged serving cache: one shared page pool per layer (``[num_pages,
    page_size, ...]``), addressed through a per-slot block table that
    lives OUTSIDE the cache tree (it is shared by every layer — all
    layers append at the same positions).  Attention-family kinds only:
    recurrent state caches have no sequence axis to page."""
    cdt = cfg.compute_dtype
    if kind == "attn":
        _, KV = cfg.padded_gqa()
        return {
            "k_pages": Param((num_pages, page_size, KV, cfg.qk_head_dim),
                             ("cache_seq", None, "cache_heads", None),
                             dtype=cdt, init="zeros"),
            "v_pages": Param((num_pages, page_size, KV, cfg.head_dim),
                             ("cache_seq", None, "cache_heads", None),
                             dtype=cdt, init="zeros"),
        }
    if kind == "mla":
        return {
            "ckv_pages": Param((num_pages, page_size, cfg.kv_lora_rank),
                               ("cache_seq", None, None), dtype=cdt,
                               init="zeros"),
            "kpe_pages": Param((num_pages, page_size, cfg.rope_head_dim),
                               ("cache_seq", None, None), dtype=cdt,
                               init="zeros"),
        }
    raise NotImplementedError(
        f"paged KV cache supports full-attention blocks only, got {kind!r}")


def lm_paged_cache_specs(cfg: ModelConfig, num_pages: int,
                         page_size: int) -> Dict[str, Any]:
    head, unit, reps, tail = block_pattern(cfg)
    return {
        "head_layers": {
            f"h{i}": _temporal_paged_cache_specs(tk, cfg, num_pages, page_size)
            for i, (tk, _) in enumerate(head)
        },
        "unit": _stack(
            {f"b{i}": _temporal_paged_cache_specs(tk, cfg, num_pages,
                                                  page_size)
             for i, (tk, _) in enumerate(unit)},
            reps,
        ),
        "tail_layers": {
            f"t{i}": _temporal_paged_cache_specs(tk, cfg, num_pages,
                                                 page_size)
            for i, (tk, _) in enumerate(tail)
        },
    }


def _pack_cache(kind: str, raw: Dict, length, block_table=None) -> Dict:
    """Join declared cache arrays with the runtime length scalar (and, for
    paged caches, the shared block table) into the structure the
    block-apply functions expect."""
    if kind in ("attn", "local"):
        if "k_pages" in raw:
            return {"k_pages": raw["k_pages"], "v_pages": raw["v_pages"],
                    "block_table": block_table, "len": length}
        return {"k": raw["k"], "v": raw["v"], "len": length}
    if kind == "mla":
        if "ckv_pages" in raw:
            return {"ckv_pages": raw["ckv_pages"],
                    "kpe_pages": raw["kpe_pages"],
                    "block_table": block_table, "len": length}
        return {"c_kv": raw["c_kv"], "k_pe": raw["k_pe"], "len": length}
    if kind == "rglru":
        return {"conv": raw["conv"], "h": raw["h"]}
    if kind == "mlstm":
        return {"conv": raw["conv"], "state": (raw["C"], raw["n"], raw["m"])}
    if kind == "slstm":
        return {"state": (raw["c"], raw["n"], raw["h"], raw["m"])}
    raise ValueError(kind)


def _unpack_cache(kind: str, cache: Dict) -> Dict:
    if kind in ("attn", "local"):
        if "k_pages" in cache:
            return {"k_pages": cache["k_pages"], "v_pages": cache["v_pages"]}
        return {"k": cache["k"], "v": cache["v"]}
    if kind == "mla":
        if "ckv_pages" in cache:
            return {"ckv_pages": cache["ckv_pages"],
                    "kpe_pages": cache["kpe_pages"]}
        return {"c_kv": cache["c_kv"], "k_pe": cache["k_pe"]}
    if kind == "rglru":
        return {"conv": cache["conv"], "h": cache["h"]}
    if kind == "mlstm":
        C, n, m = cache["state"]
        return {"conv": cache["conv"], "C": C, "n": n, "m": m}
    if kind == "slstm":
        c, n, h, m = cache["state"]
        return {"c": c, "n": n, "h": h, "m": m}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stacking helpers
# ---------------------------------------------------------------------------


def _stack(specs: Any, reps: int) -> Any:
    return jax.tree.map(
        lambda p: Param((reps,) + p.shape, ("layers",) + p.axes, p.dtype, p.init, p.scale),
        specs,
        is_leaf=is_param,
    )


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------


def lm_specs(cfg: ModelConfig) -> Dict[str, Any]:
    head, unit, reps, tail = block_pattern(cfg)
    specs: Dict[str, Any] = {
        "embed": Param((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), init="embed"),
        "final_norm": B.rmsnorm_specs(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = Param((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"))
    specs["head_layers"] = {
        f"h{i}": _layer_specs(cfg, tk, ck) for i, (tk, ck) in enumerate(head)
    }
    specs["unit"] = _stack(
        {f"b{i}": _layer_specs(cfg, tk, ck) for i, (tk, ck) in enumerate(unit)}, reps
    )
    specs["tail_layers"] = {
        f"t{i}": _layer_specs(cfg, tk, ck) for i, (tk, ck) in enumerate(tail)
    }
    return specs


def lm_cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    head, unit, reps, tail = block_pattern(cfg)
    return {
        "head_layers": {
            f"h{i}": _temporal_cache_specs(tk, cfg, batch, max_len)
            for i, (tk, _) in enumerate(head)
        },
        "unit": _stack(
            {f"b{i}": _temporal_cache_specs(tk, cfg, batch, max_len)
             for i, (tk, _) in enumerate(unit)},
            reps,
        ),
        "tail_layers": {
            f"t{i}": _temporal_cache_specs(tk, cfg, batch, max_len)
            for i, (tk, _) in enumerate(tail)
        },
    }


def _embed_tokens(cfg, params, tokens):
    emb = jnp.take(params["embed"], tokens, axis=0)
    return emb.astype(cfg.compute_dtype)


def lm_apply(
    cfg: ModelConfig,
    params: Dict[str, Any],
    inputs: jnp.ndarray,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[Dict] = None,
    cache_len=None,
    *,
    block_table=None,
    chunk_lens=None,
    remat: bool = True,
    last_only: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (logits, new_cache, aux_loss).

    inputs: int tokens [B,S] or embeds [B,S,d] (vlm/audio frontends).
    cache/cache_len: decode mode (S==1) or batched prefill (S>1 with a
    scalar cache_len — the full-sequence K/V is written into the cache in
    one forward).  A [B]-vector cache_len runs per-slot decode: every row
    appends and attends at its own length (continuous batching).
    ``block_table`` ([B, max_pages] int32) rides alongside a *paged* cache
    (``lm_paged_cache_specs``): it is shared by every layer, so it threads
    through here rather than living in the per-layer cache tree.
    ``chunk_lens`` ([B] int32, S>1 + cache only) switches prefill to the
    ragged cache-writing path: ``cache_len`` is then each row's *base*
    offset (cached-prefix length, scalar or [B]) and row ``b``'s first
    ``chunk_lens[b]`` tokens append at it — chunked prefill over a warm
    cache on either KV layout.  Positions default to ``base + arange(S)``
    per row.
    """
    head, unit, reps, tail = block_pattern(cfg)
    if inputs.ndim == 2:
        x = _embed_tokens(cfg, params, inputs)
    else:
        x = inputs.astype(cfg.compute_dtype)
    Bsz, S = x.shape[0], x.shape[1]
    if positions is None:
        if chunk_lens is not None:
            # ragged chunked prefill: row b's tokens sit at base + [0, S)
            base = jnp.broadcast_to(
                jnp.asarray(cache_len if cache_len is not None else 0,
                            jnp.int32).reshape(-1), (Bsz,))
            positions = base[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        elif cache_len is not None:
            cl = jnp.asarray(cache_len)
            if cl.ndim == 1:  # per-slot lengths: each row decodes at its own position
                positions = cl[:, None].astype(jnp.int32)
            else:
                positions = jnp.broadcast_to(cl[None, None], (Bsz, 1)).astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None, :], (Bsz, S)).astype(jnp.int32)

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {"head_layers": {}, "tail_layers": {}}

    def run_layer(tk, ck, p, x, c):
        cc = (_pack_cache(tk, c, cache_len, block_table)
              if c is not None else None)
        x, nc, aux = _layer_apply(cfg, tk, ck, p, x, positions, cc,
                                  chunk_lens)
        return x, (_unpack_cache(tk, nc) if nc is not None else None), aux

    # head
    for i, (tk, ck) in enumerate(head):
        c = cache["head_layers"][f"h{i}"] if cache is not None else None
        x, nc, aux = run_layer(tk, ck, params["head_layers"][f"h{i}"], x, c)
        aux_total += aux
        if nc is not None:
            new_cache["head_layers"][f"h{i}"] = nc

    # scanned unit
    if reps > 0:
        unit_params = params["unit"]
        unit_cache = cache["unit"] if cache is not None else None

        if unit_cache is None:

            def unit_body(carry, p_i):
                x, aux_acc = carry
                # barrier pins the saved-residual dtype: without it XLA:CPU
                # hoists the first-use f32 convert through the scan's
                # dynamic-update-slice and stacks the residuals twice
                # (bf16 + f32) — a 3x memory hit at 4k seq.
                from repro.common.compat import optimization_barrier
                x = optimization_barrier(x)
                if cfg.seq_parallel:
                    # Megatron SP: the saved residual is seq-sharded over
                    # the model axis (16x smaller stack); GSPMD inserts the
                    # gather at the first full-sequence consumer
                    from repro.distributed.sharding import constrain
                    x = constrain(x, ("act_batch", "act_seq_sp", None))
                aux_sum = jnp.zeros((), jnp.float32)
                for j, (tk, ck) in enumerate(unit):
                    x, _, aux = run_layer(tk, ck, p_i[f"b{j}"], x, None)
                    aux_sum += aux
                return (x, aux_acc + aux_sum), None

            if remat and cfg.remat_policy == "save_block_outputs":
                body = jax.checkpoint(
                    unit_body,
                    policy=jax.checkpoint_policies.save_only_these_names("block_out"),
                )
            elif remat:
                body = jax.checkpoint(unit_body)
            else:
                body = unit_body
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), unit_params)
        else:

            def unit_body_c(carry, xs):
                x, aux_acc = carry
                p_i, c_i = xs
                nc_i = {}
                aux_sum = jnp.zeros((), jnp.float32)
                for j, (tk, ck) in enumerate(unit):
                    x, nc, aux = run_layer(tk, ck, p_i[f"b{j}"], x, c_i[f"b{j}"])
                    aux_sum += aux
                    nc_i[f"b{j}"] = nc
                return (x, aux_acc + aux_sum), nc_i

            (x, aux_total), scanned_cache = jax.lax.scan(
                unit_body_c, (x, aux_total), (unit_params, unit_cache)
            )
            new_cache["unit"] = scanned_cache

    # tail
    for i, (tk, ck) in enumerate(tail):
        c = cache["tail_layers"][f"t{i}"] if cache is not None else None
        x, nc, aux = run_layer(tk, ck, params["tail_layers"][f"t{i}"], x, c)
        aux_total += aux
        if nc is not None:
            new_cache["tail_layers"][f"t{i}"] = nc

    if last_only:
        x = x[:, -1:]
    x = B.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    head_w = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(cfg.compute_dtype)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(cfg.compute_dtype), head_w)
    return logits, (new_cache if cache is not None else None), aux_total
