"""Recurrent temporal mixers: RG-LRU (RecurrentGemma/Griffin), mLSTM and
sLSTM (xLSTM).

Sequence forms:
* RG-LRU — elementwise linear recurrence -> ``jax.lax.associative_scan``
  (log-depth, sub-quadratic; this is why recurrentgemma runs long_500k).
* mLSTM — chunked parallel form (matrix memory carried across chunks via
  ``lax.scan``; quadratic only within a chunk).
* sLSTM — strictly sequential (recurrent weights) -> ``lax.scan`` over time.

Each block also has a single-step decode path operating on a small state.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

from repro.common.params import Param
from repro.configs.base import ModelConfig
from repro.models.blocks import rmsnorm_apply, rmsnorm_specs

# ---------------------------------------------------------------------------
# causal depthwise conv1d (width w) used by griffin + mlstm
# ---------------------------------------------------------------------------


def conv1d_specs(width: int, channels: int) -> Dict[str, Param]:
    return {"w": Param((width, channels), (None, "rnn"), init="normal", scale=0.1)}


def conv1d_apply(params, x: jnp.ndarray, state: Optional[jnp.ndarray] = None):
    """x: [B,S,C]. state (decode): [B,w-1,C] previous inputs. Returns
    (y, new_state)."""
    w = params["w"].shape[0]
    if state is None:
        pad = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
        new_state = pad[:, -(w - 1):, :] if w > 1 else None
    else:
        pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = pad[:, -(w - 1):, :] if w > 1 else None
    y = sum(
        pad[:, i : pad.shape[1] - (w - 1 - i), :] * params["w"][i].astype(x.dtype)
        for i in range(w)
    )
    return y, new_state


# ---------------------------------------------------------------------------
# RG-LRU (Griffin eq. 3-4): per-channel gated linear recurrence
# ---------------------------------------------------------------------------

_C_RGLRU = 8.0


def rglru_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, r = cfg.d_model, cfg.rnn_width
    return {
        "norm": rmsnorm_specs(d),
        "wx": Param((d, r), ("embed", "rnn")),
        "wgate": Param((d, r), ("embed", "rnn")),
        "conv": conv1d_specs(cfg.conv_width, r),
        "lam": Param((r,), ("rnn",), init="normal", scale=1.0),  # Λ
        "wa": Param((r,), ("rnn",), init="normal", scale=0.1),   # recurrence gate
        "ba": Param((r,), ("rnn",), init="zeros"),
        "wi": Param((r,), ("rnn",), init="normal", scale=0.1),   # input gate
        "bi": Param((r,), ("rnn",), init="zeros"),
        "wo": Param((r, d), ("rnn", "embed")),
    }


def _rglru_coeffs(params, u, dtype):
    """u: [...,r] branch input -> (log_a, gated_in) fp32."""
    uf = u.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(uf * params["wa"].astype(jnp.float32) + params["ba"].astype(jnp.float32))
    i_gate = jax.nn.sigmoid(uf * params["wi"].astype(jnp.float32) + params["bi"].astype(jnp.float32))
    log_a = -_C_RGLRU * r_gate * jax.nn.softplus(params["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    scaled_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (i_gate * uf)
    return a, scaled_in


def rglru_scan(params, u: jnp.ndarray, chunk: int = 2048) -> jnp.ndarray:
    """u: [B,S,r] -> h: [B,S,r].

    Chunked: ``lax.scan`` over S/chunk blocks carrying the boundary state,
    ``associative_scan`` (log-depth) within each block.  Bounds the
    log-depth scan's materialized intermediates to O(chunk) instead of O(S)
    — the un-chunked version costs ~log2(S) full-sequence f32 copies, which
    at 32k x 4096 width was 168 GB/device."""
    a, b = _rglru_coeffs(params, u, u.dtype)
    B, S, r = a.shape
    chunk = min(chunk, S)
    nc = S // chunk

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    if nc <= 1:
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        return h.astype(u.dtype)

    ac = jnp.moveaxis(a.reshape(B, nc, chunk, r), 1, 0)
    bc = jnp.moveaxis(b.reshape(B, nc, chunk, r), 1, 0)

    def body(h0, xs):
        ai, bi = xs
        a_cum, b_cum = jax.lax.associative_scan(combine, (ai, bi), axis=1)
        h = a_cum * h0[:, None, :] + b_cum
        return h[:, -1, :], h

    h_last0 = jnp.zeros((B, r), jnp.float32)
    _, hs = jax.lax.scan(body, h_last0, (ac, bc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, r)
    return h.astype(u.dtype)


def rglru_step(params, u: jnp.ndarray, h_prev: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """u: [B,1,r]; h_prev: [B,r]."""
    a, b = _rglru_coeffs(params, u[:, 0], u.dtype)
    h = a * h_prev.astype(jnp.float32) + b
    return h.astype(u.dtype)[:, None], h


def rglru_block_apply(
    cfg: ModelConfig, params, x: jnp.ndarray, cache: Optional[Dict] = None
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Griffin recurrent block: gate branch x (conv -> RG-LRU) branch."""
    cdt = cfg.compute_dtype
    h = rmsnorm_apply(params["norm"], x, cfg.norm_eps).astype(cdt)
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", h, params["wgate"].astype(cdt)))
    u = jnp.einsum("bsd,dr->bsr", h, params["wx"].astype(cdt))
    new_cache = None
    if cache is None:
        u, _ = conv1d_apply(params["conv"], u)
        r = rglru_scan(params, u)
    else:
        u, conv_state = conv1d_apply(params["conv"], u, cache["conv"])
        r, h_state = rglru_step(params, u, cache["h"])
        new_cache = {"conv": conv_state, "h": h_state}
    y = jnp.einsum("bsr,rd->bsd", r * gate, params["wo"].astype(cdt))
    y = _checkpoint_name(y, "block_out")
    return x + y.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# mLSTM (xLSTM): matrix memory, chunked parallel over sequence
# ---------------------------------------------------------------------------


def mlstm_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    m = 2 * d  # official projection factor 2
    nh = cfg.num_heads
    return {
        "norm": rmsnorm_specs(d),
        "wup": Param((d, 2 * m), ("embed", "rnn")),
        "conv": conv1d_specs(cfg.conv_width, m),
        "wq": Param((m, m), ("rnn", None)),
        "wk": Param((m, m), ("rnn", None)),
        "wv": Param((m, m), ("rnn", None)),
        "wi": Param((m, nh), ("rnn", None), init="normal", scale=0.02),
        "bi": Param((nh,), (None,), init="zeros"),
        "wf": Param((m, nh), ("rnn", None), init="normal", scale=0.02),
        "bf": Param((nh,), (None,), init="ones"),
        "gnorm": rmsnorm_specs(m // nh),
        "wdown": Param((m, d), ("rnn", "embed")),
    }


def _mlstm_chunk_scan(q, k, v, log_i, log_f, chunk: int):
    """Chunked-parallel mLSTM. q,k,v: [B,S,nh,dh]; log_i/log_f: [B,S,nh]
    (fp32).  Returns h: [B,S,nh,dh]."""
    B, S, nh, dh = q.shape
    nc = S // chunk
    qc = q.reshape(B, nc, chunk, nh, dh)
    kc = k.reshape(B, nc, chunk, nh, dh)
    vc = v.reshape(B, nc, chunk, nh, dh)
    li = log_i.reshape(B, nc, chunk, nh)
    lf = log_f.reshape(B, nc, chunk, nh)
    # move chunk axis first for scan
    qc, kc, vc = (jnp.moveaxis(t, 1, 0) for t in (qc, kc, vc))
    li, lf = (jnp.moveaxis(t, 1, 0) for t in (li, lf))
    scale = 1.0 / math.sqrt(dh)

    def body(carry, xs):
        C_prev, n_prev, m_prev = carry  # [B,nh,dh,dh], [B,nh,dh], [B,nh]
        qb, kb, vb, lib, lfb = xs  # [B,c,nh,dh] / [B,c,nh]
        fcum = jnp.cumsum(lfb, axis=1)  # [B,c,nh]
        ftot = fcum[:, -1]
        # intra-chunk decay matrix: D[t,s] = exp(fcum_t - fcum_s + i_s), s<=t
        lD = fcum[:, :, None, :] - fcum[:, None, :, :] + lib[:, None, :, :]
        tri = jnp.tril(jnp.ones((qb.shape[1], qb.shape[1]), bool))
        lD = jnp.where(tri[None, :, :, None], lD, -jnp.inf)
        # inter-chunk coefficient per target t: exp(fcum_t)
        l_inter = fcum  # [B,c,nh]
        m_intra = jnp.max(lD, axis=2)  # [B,c,nh]
        m_new = jnp.maximum(m_intra, l_inter + m_prev[:, None, :])
        m_new = jnp.maximum(m_new, -1e30)
        D = jnp.exp(lD - m_new[:, :, None, :])  # [B,c,c,nh]
        inter_w = jnp.exp(l_inter + m_prev[:, None, :] - m_new)  # [B,c,nh]

        s_qk = jnp.einsum("bthd,bshd->btsh", qb.astype(jnp.float32), kb.astype(jnp.float32)) * scale
        intra = jnp.einsum("btsh,bshd->bthd", s_qk * D, vb.astype(jnp.float32))
        inter = jnp.einsum("bthd,bhde->bthe", qb.astype(jnp.float32) * scale, C_prev) * inter_w[..., None]
        num = intra + inter
        # normalizer
        qn = jnp.einsum("bthd,bhd->bth", qb.astype(jnp.float32) * scale, n_prev) * inter_w
        denom = jnp.abs(jnp.einsum("btsh->bth", s_qk * D) + qn)
        h = num / jnp.maximum(denom, jnp.exp(-m_new))[..., None]

        # carry updates (decayed to end of chunk)
        m_next = jnp.maximum(ftot + m_prev, jnp.max(lib + (ftot[:, None] - fcum), axis=1))
        w_old = jnp.exp(ftot + m_prev - m_next)  # [B,nh]
        w_k = jnp.exp(lib + (ftot[:, None] - fcum) - m_next[:, None])  # [B,c,nh]
        C_new = C_prev * w_old[..., None, None] + jnp.einsum(
            "bsh,bshd,bshe->bhde", w_k, kb.astype(jnp.float32), vb.astype(jnp.float32)
        )
        n_new = n_prev * w_old[..., None] + jnp.einsum("bsh,bshd->bhd", w_k, kb.astype(jnp.float32))
        return (C_new, n_new, m_next), h

    C0 = jnp.zeros((B, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, nh, dh), jnp.float32)
    m0 = jnp.full((B, nh), -1e30, jnp.float32)
    _, hs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, li, lf))
    return jnp.moveaxis(hs, 0, 1).reshape(B, S, nh, dh)


def mlstm_step(q, k, v, log_i, log_f, state):
    """Single decode step. q,k,v: [B,1,nh,dh]; log_i/f: [B,1,nh];
    state = (C [B,nh,dh,dh], n [B,nh,dh], m [B,nh])."""
    C_prev, n_prev, m_prev = state
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)
    li, lf = log_i[:, 0], log_f[:, 0]
    m_new = jnp.maximum(lf + m_prev, li)
    f_w = jnp.exp(lf + m_prev - m_new)
    i_w = jnp.exp(li - m_new)
    kb = k[:, 0].astype(jnp.float32)
    vb = v[:, 0].astype(jnp.float32)
    C = C_prev * f_w[..., None, None] + i_w[..., None, None] * jnp.einsum("bhd,bhe->bhde", kb, vb)
    n = n_prev * f_w[..., None] + i_w[..., None] * kb
    qb = q[:, 0].astype(jnp.float32) * scale
    num = jnp.einsum("bhd,bhde->bhe", qb, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qb, n)), jnp.exp(-m_new))
    h = (num / denom[..., None])[:, None]
    return h, (C, n, m_new)


def mlstm_block_apply(
    cfg: ModelConfig, params, x: jnp.ndarray, cache: Optional[Dict] = None,
    chunk: int = 256,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    cdt = cfg.compute_dtype
    B, S, d = x.shape
    nh = cfg.num_heads
    m = params["wq"].shape[0]
    dh = m // nh
    h = rmsnorm_apply(params["norm"], x, cfg.norm_eps).astype(cdt)
    up = jnp.einsum("bsd,dm->bsm", h, params["wup"].astype(cdt))
    u, z = up[..., :m], up[..., m:]
    new_cache: Optional[Dict] = None
    if cache is None:
        uc, _ = conv1d_apply(params["conv"], u)
    else:
        uc, conv_state = conv1d_apply(params["conv"], u, cache["conv"])
    uact = jax.nn.silu(uc)
    q = jnp.einsum("bsm,mn->bsn", uact, params["wq"].astype(cdt)).reshape(B, S, nh, dh)
    k = jnp.einsum("bsm,mn->bsn", uact, params["wk"].astype(cdt)).reshape(B, S, nh, dh)
    v = jnp.einsum("bsm,mn->bsn", u, params["wv"].astype(cdt)).reshape(B, S, nh, dh)
    log_i = (jnp.einsum("bsm,mh->bsh", uact, params["wi"].astype(cdt)) + params["bi"].astype(cdt)).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        (jnp.einsum("bsm,mh->bsh", uact, params["wf"].astype(cdt)) + params["bf"].astype(cdt)).astype(jnp.float32)
    )
    if cache is None:
        hseq = _mlstm_chunk_scan(q, k, v, log_i, log_f, min(chunk, S))
    else:
        hseq, state = mlstm_step(q, k, v, log_i, log_f, cache["state"])
        new_cache = {"conv": conv_state, "state": state}
    hseq = rmsnorm_apply(params["gnorm"], hseq.astype(cdt), cfg.norm_eps)
    out = hseq.reshape(B, S, m) * jax.nn.silu(z)
    y = jnp.einsum("bsm,md->bsd", out, params["wdown"].astype(cdt))
    return x + y.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# sLSTM (xLSTM): scalar memory with recurrent block-diagonal weights
# ---------------------------------------------------------------------------


def slstm_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    nh = cfg.num_heads
    dh = d // nh
    ff = max(int(d * 4 / 3) // 64 * 64, 64)
    return {
        "norm": rmsnorm_specs(d),
        "wz": Param((d, d), ("embed", "rnn")),
        "wi": Param((d, d), ("embed", "rnn")),
        "wf": Param((d, d), ("embed", "rnn")),
        "wo": Param((d, d), ("embed", "rnn")),
        "rz": Param((nh, dh, dh), (None, None, None), init="normal", scale=0.05),
        "ri": Param((nh, dh, dh), (None, None, None), init="normal", scale=0.05),
        "rf": Param((nh, dh, dh), (None, None, None), init="normal", scale=0.05),
        "ro": Param((nh, dh, dh), (None, None, None), init="normal", scale=0.05),
        "gnorm": rmsnorm_specs(d),
        # gated FFN (factor 4/3) — part of the sLSTM block in xLSTM
        "ff_w1": Param((d, ff), ("embed", "mlp")),
        "ff_w3": Param((d, ff), ("embed", "mlp")),
        "ff_w2": Param((ff, d), ("mlp", "embed")),
    }


def _slstm_cell(params, xz, xi, xf, xo, state, nh, dh):
    """One timestep. x*: [B,d] pre-activations from input; state=(c,n,h,m)."""
    c, n, h, m = state
    B = xz.shape[0]
    hh = h.reshape(B, nh, dh)

    def rec(w):
        return jnp.einsum("bhd,hde->bhe", hh, w.astype(jnp.float32)).reshape(B, -1)

    z = jnp.tanh(xz + rec(params["rz"]))
    i_t = xi + rec(params["ri"])
    f_t = xf + rec(params["rf"])
    o = jax.nn.sigmoid(xo + rec(params["ro"]))
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(logf + m - m_new)
    c_new = f_p * c + i_p * z
    n_new = jnp.maximum(f_p * n + i_p, 1e-6)
    h_new = o * c_new / n_new
    return (c_new, n_new, h_new, m_new)


def slstm_block_apply(
    cfg: ModelConfig, params, x: jnp.ndarray, cache: Optional[Dict] = None
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    cdt = cfg.compute_dtype
    B, S, d = x.shape
    nh = cfg.num_heads
    dh = d // nh
    hin = rmsnorm_apply(params["norm"], x, cfg.norm_eps).astype(cdt)
    xz = jnp.einsum("bsd,de->bse", hin, params["wz"].astype(cdt)).astype(jnp.float32)
    xi = jnp.einsum("bsd,de->bse", hin, params["wi"].astype(cdt)).astype(jnp.float32)
    xf = jnp.einsum("bsd,de->bse", hin, params["wf"].astype(cdt)).astype(jnp.float32)
    xo = jnp.einsum("bsd,de->bse", hin, params["wo"].astype(cdt)).astype(jnp.float32)

    if cache is None:
        state0 = tuple(
            jnp.zeros((B, d), jnp.float32) if i != 3 else jnp.full((B, d), -1e30, jnp.float32)
            for i in range(4)
        )

        def body(state, xs):
            s = _slstm_cell(params, *xs, state, nh, dh)
            return s, s[2]

        _, hs = jax.lax.scan(
            body, state0, tuple(jnp.moveaxis(t, 1, 0) for t in (xz, xi, xf, xo))
        )
        hseq = jnp.moveaxis(hs, 0, 1)  # [B,S,d]
        new_cache = None
    else:
        state = cache["state"]
        state = _slstm_cell(params, xz[:, 0], xi[:, 0], xf[:, 0], xo[:, 0], state, nh, dh)
        hseq = state[2][:, None]
        new_cache = {"state": state}
    hseq = rmsnorm_apply(params["gnorm"], hseq.astype(cdt), cfg.norm_eps)
    # gated FFN
    u = jnp.einsum("bsd,df->bsf", hseq, params["ff_w1"].astype(cdt))
    g = jnp.einsum("bsd,df->bsf", hseq, params["ff_w3"].astype(cdt))
    y = jnp.einsum("bsf,fd->bsd", jax.nn.silu(u) * g, params["ff_w2"].astype(cdt))
    return x + y.astype(x.dtype), new_cache
