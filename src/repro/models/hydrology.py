"""LSTM hydrology model (paper §3.3, Tables 1-2; He et al. arXiv:2410.15218).

Multivariate daily forcings -> LSTM -> per-target head, predicting
precipitation / mean temperature / streamflow (QObs), with NNSE reporting
as in Table 1.  ``make_camels_like`` generates a CAMELS-US-shaped synthetic
basin (seasonal forcings, snow-melt-ish lag, baseflow recession) so the
pipeline is runnable offline.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

N_FEATURES = 5  # prcp, srad, tmax, tmin, vp  (CAMELS forcing set)
TARGETS = ("precipitation", "mean_temperature", "streamflow")


def lstm_init(key, n_in: int = N_FEATURES, nh: int = 64,
              n_out: int = len(TARGETS)) -> Dict:
    k = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(n_in + nh)
    return {
        "wx": jax.random.normal(k[0], (n_in, 4 * nh)) * s,
        "wh": jax.random.normal(k[1], (nh, 4 * nh)) * s,
        "b": jnp.zeros((4 * nh,)).at[nh:2 * nh].set(1.0),  # forget bias 1
        "head_w": jax.random.normal(k[2], (nh, n_out)) * (1.0 / math.sqrt(nh)),
        "head_b": jnp.zeros((n_out,)),
    }


def lstm_apply(params, x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, T, F] -> predictions [B, n_out] (last-step readout)."""
    B = x.shape[0]
    nh = params["wh"].shape[0]

    def cell(carry, xt):
        h, c = carry
        z = xt @ params["wx"] + h @ params["wh"] + params["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    (h, _), _ = jax.lax.scan(
        cell, (jnp.zeros((B, nh)), jnp.zeros((B, nh))), jnp.swapaxes(x, 0, 1)
    )
    return h @ params["head_w"] + params["head_b"]


def nse(pred: jnp.ndarray, obs: jnp.ndarray) -> jnp.ndarray:
    """Nash-Sutcliffe efficiency; NNSE = 1 / (2 - NSE)."""
    num = jnp.sum((pred - obs) ** 2)
    den = jnp.maximum(jnp.sum((obs - obs.mean()) ** 2), 1e-9)
    return 1.0 - num / den


def nnse(pred, obs):
    return 1.0 / (2.0 - nse(pred, obs))


def make_camels_like(n_days: int = 5000, seed: int = 0):
    """Synthetic CAMELS-US-like basin: returns (forcings [T,F],
    targets {name: [T]}), standardized."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    t = jnp.arange(n_days, dtype=jnp.float32)
    season = jnp.sin(2 * jnp.pi * t / 365.25)
    # forcings
    prcp = jax.nn.relu(
        0.6 * season + 0.8 * jax.random.normal(ks[0], (n_days,)) + 0.3
    )
    tmax = 15 + 12 * season + 2 * jax.random.normal(ks[1], (n_days,))
    tmin = tmax - 8 - jnp.abs(jax.random.normal(ks[2], (n_days,)))
    srad = 200 + 150 * season + 20 * jax.random.normal(ks[3], (n_days,))
    vp = 8 + 5 * season + jax.random.normal(ks[4], (n_days,))
    # streamflow: routed precipitation with recession (simple bucket model)
    def bucket(storage, p_m):
        p, melt = p_m
        storage = storage + p + melt
        q = 0.06 * storage
        return storage - q, q
    melt = jax.nn.relu(tmin / 20.0) * 0.2
    _, q = jax.lax.scan(bucket, jnp.asarray(5.0), (prcp, melt))
    q = q + 0.05 * jax.random.normal(ks[5], (n_days,))

    feats = jnp.stack([prcp, srad, tmax, tmin, vp], axis=-1)
    feats = (feats - feats.mean(0)) / (feats.std(0) + 1e-6)
    mean_temp = (tmax + tmin) / 2
    targets = {}
    for name, y in [("precipitation", prcp), ("mean_temperature", mean_temp),
                    ("streamflow", q)]:
        targets[name] = (y - y.mean()) / (y.std() + 1e-6)
    return feats, targets


def window_dataset(feats, targets, window: int = 64):
    """Sliding windows: x [N, window, F]; y [N, n_targets] (next-day)."""
    T = feats.shape[0]
    n = T - window - 1
    idx = jnp.arange(n)[:, None] + jnp.arange(window)[None, :]
    x = feats[idx]
    y = jnp.stack([targets[k][jnp.arange(n) + window] for k in TARGETS], -1)
    return x, y
