"""Transformer building blocks: norms, RoPE/M-RoPE, GQA/MLA attention
(chunked flash-style reference path), SwiGLU/GeGLU/GELU MLPs, GShard-style
MoE (einsum dispatch baseline + gather-dispatch optimized variant).

Every block is a pair of functions:

* ``<kind>_specs(cfg) -> PyTree[Param]`` — parameter declaration with
  logical sharding axes;
* ``<kind>_apply(cfg, params, x, ...) -> y`` — pure forward.

Attention convention: activations are [batch, seq, ...]; caches are dicts.
Compute runs in ``cfg.compute_dtype`` (bf16); norms/softmax accumulate fp32.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

from repro.common.params import Param
from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_specs(d: int) -> Dict[str, Param]:
    return {"scale": Param((d,), (None,), init="ones")}


def rmsnorm_apply(params, x, eps: float = 1e-5):
    """f32 variance reduction, input-dtype scaling multiply (H5 in
    EXPERIMENTS §Perf: upcasting the whole tensor doubled fwd+bwd HBM
    traffic; the reduction accumulates f32 inside the fused reduce)."""
    var = jnp.mean(
        jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True
    )
    scale = (jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return x * scale * params["scale"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def _rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, D]; positions: [B, S] int32."""
    d = x.shape[-1]
    freqs = _rope_freqs(d, theta)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float, sections: Tuple[int, ...]
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE. positions: [B, S, 3] (t, h, w); ``sections``
    splits the D/2 rotary frequencies into (t, h, w) groups."""
    d = x.shape[-1]
    freqs = _rope_freqs(d, theta)  # [D/2]
    # angles per modality then stitched along the frequency dim
    ang = positions[..., None, :].astype(jnp.float32)  # [B,S,1,3]
    ang = ang * freqs[None, None, :, None]  # [B,S,D/2,3]
    sec_idx = []
    for i, s in enumerate(sections):
        sec_idx += [i] * s
    sec_idx = jnp.asarray(sec_idx[: d // 2], dtype=jnp.int32)
    angles = jnp.take_along_axis(
        ang, sec_idx[None, None, :, None].astype(jnp.int32), axis=-1
    )[..., 0]  # [B,S,D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash-style chunked attention (jnp reference path — differentiable, O(chunk)
# memory; the Pallas kernel in repro.kernels is the TPU fast path).
# ---------------------------------------------------------------------------

_NEG_INF = -1e30


def _check_prefill_base(raw_len) -> None:
    """S>1 prefill attends over the fresh K/V only, which is exact iff the
    cache is empty — a nonzero base would silently drop the cached prefix
    from attention.  The base must therefore be *statically* zero: pass a
    plain Python ``0`` (a traced/data-dependent length cannot be validated
    at trace time and is rejected)."""
    if getattr(raw_len, "ndim", 0) != 0:
        raise ValueError(
            "prefill (S>1) requires a scalar cache length; per-slot "
            "lengths only apply to single-token decode")
    try:
        concrete = int(raw_len)  # jit-ok: deliberate trace-time probe
    except (TypeError, jax.errors.ConcretizationTypeError) as e:
        # traced / data-dependent value: int() on a tracer raises
        # ConcretizationTypeError (a TypeError subclass)
        raise NotImplementedError(
            "prefill (S>1) needs a statically-zero cache length (pass a "
            "plain int 0): attention runs over the fresh K/V only, so "
            "appending at a data-dependent offset would silently ignore "
            "the cached prefix") from e
    if concrete != 0:
        raise NotImplementedError(
            f"prefill (S>1) writes into an EMPTY cache (got base length "
            f"{concrete}); chunked/multi-turn prefill over a warm cache is "
            f"not implemented")


def _attn_chunk(q, k, v, qpos, kpos, causal, window, scale):
    """One (q-chunk x kv-chunk) tile. q:[B,qc,H,D] k,v:[B,kc,H,D]."""
    s = jnp.einsum(
        "bqhd,bchd->bhqc", q, k, preferred_element_type=jnp.float32
    ) * scale  # [B,H,qc,kc]
    mask = jnp.ones((q.shape[1], k.shape[1]), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, None], s, _NEG_INF)
    m = jnp.max(s, axis=-1)  # [B,H,qc]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhqc,bchd->bqhd", p.astype(v.dtype), v)
    return m, l, o.astype(jnp.float32)


def _repeat_kv(k: jnp.ndarray, H: int, seq_axes=("act_batch", None, "act_heads", None)):
    """[B,S,KV,D] -> [B,S,H,D] (GQA repeat), sharding-constrained so the
    repeated heads land on the model axis instead of being replicated."""
    from repro.distributed.sharding import constrain

    KV = k.shape[2]
    if KV == H:
        return k
    k = jnp.repeat(k, H // KV, axis=2)
    return constrain(k, seq_axes)


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    q_offset: int = 0,
) -> jnp.ndarray:
    """q: [B,Sq,H,D]; k, v: [B,Skv,KV,D] -> [B,Sq,H,D].

    Outer loop over q chunks is a *python* loop (static), so causal chunks
    only visit the KV prefix they can see — the compiled FLOPs follow the
    causal triangle instead of the full rectangle.  Inner loop is a
    ``lax.scan`` over kv chunks with running-softmax accumulators.
    """
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    # GQA repeat happens per KV tile inside the scan (H4 in EXPERIMENTS
    # §Perf): repeating the full sequence up-front writes + reads G x the
    # whole K/V — per-tile repeat touches only the live block.
    per_tile_repeat = KV != H
    scale = 1.0 / math.sqrt(D)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, k.shape[1])
    nq = (Sq + q_chunk - 1) // q_chunk
    qg = q

    outs = []
    for qi in range(nq):
        q_lo = qi * q_chunk
        qc = min(q_chunk, Sq - q_lo)
        qblk = jax.lax.slice_in_dim(qg, q_lo, q_lo + qc, axis=1)
        qpos = q_offset + q_lo + jnp.arange(qc)
        # visible kv range for this q chunk (static)
        hi = k.shape[1] if not causal else q_offset + q_lo + qc
        hi = min(hi, k.shape[1])
        lo = 0
        if window:
            lo = max(0, q_offset + q_lo - window + 1)
            lo = (lo // kv_chunk) * kv_chunk  # align
        hi_pad = ((hi - lo + kv_chunk - 1) // kv_chunk) * kv_chunk + lo
        hi_pad = min(hi_pad, k.shape[1])
        nkv = max((hi_pad - lo + kv_chunk - 1) // kv_chunk, 1)

        def kv_body(carry, j):
            m_prev, l_prev, o_prev = carry
            k_lo = lo + j * kv_chunk
            kblk = jax.lax.dynamic_slice_in_dim(k, k_lo, kv_chunk, axis=1)
            vblk = jax.lax.dynamic_slice_in_dim(v, k_lo, kv_chunk, axis=1)
            if per_tile_repeat:
                kblk = _repeat_kv(kblk, H)
                vblk = _repeat_kv(vblk, H)
            kpos = k_lo + jnp.arange(kv_chunk)
            m_new, l_new, o_new = _attn_chunk(
                qblk, kblk, vblk, qpos, kpos, causal, window, scale
            )
            m_run = jnp.maximum(m_prev, m_new)
            a = jnp.exp(m_prev - m_run)  # [B,H,qc]
            b = jnp.exp(m_new - m_run)
            l_run = l_prev * a + l_new * b
            o_run = o_prev * a.transpose(0, 2, 1)[..., None] + (
                o_new * b.transpose(0, 2, 1)[..., None]
            )
            return (m_run, l_run, o_run), None

        m0 = jnp.full((B, H, qc), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        o0 = jnp.zeros((B, qc, H, D), jnp.float32)
        # flash-style bwd: recompute score tiles instead of stacking them as
        # scan residuals (H6 in EXPERIMENTS §Perf — trades ~25% extra attn
        # FLOPs in bwd for O(S^2/chunk) saved HBM)
        (mF, lF, oF), _ = jax.lax.scan(
            jax.checkpoint(kv_body), (m0, l0, o0), jnp.arange(nkv), length=nkv
        )
        lF = jnp.maximum(lF, 1e-30)
        out = oF / lF.transpose(0, 2, 1)[..., None]
        outs.append(out)
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out.astype(q.dtype)


def _decode_attn(cfg, q, k_cache, v_cache, cache_len, *, window: int = 0):
    """Single-step decode through the kernel dispatch (kernels/ops.py):
    Pallas flash-decode on TPU, interpret-mode Pallas elsewhere, the
    GSPMD-sharded jnp oracle under ``cfg.decode_impl="ref"``.

    q: [B,1,H,D]; caches: [B,Smax,KV,D]; cache_len: [] or [B] int32 —
    number of valid positions (including current).  A [B] vector gives
    each batch row its own valid prefix — the continuous-batching slot
    cache, where every slot is at a different point in its sequence."""
    from repro.kernels import ops

    o = ops.decode_attention(q[:, 0], k_cache, v_cache, cache_len,
                             window=window, impl=cfg.decode_impl)
    return o[:, None].astype(q.dtype)


def _paged_decode_attn(cfg, q, k_pages, v_pages, block_table, cache_len):
    """Paged decode: K/V gathered from a shared page pool through the
    per-row block table (see kernels/decode_attention.py).  q: [B,1,H,D];
    pools: [num_pages, page_size, KV, D]; block_table: [B, max_pages]."""
    from repro.kernels import ops

    o = ops.decode_attention_paged(q[:, 0], k_pages, v_pages, block_table,
                                   cache_len, impl=cfg.decode_impl)
    return o[:, None].astype(q.dtype)


def _paged_append(pages, block_table, idx, row_vals):
    """Scatter one new position per row into the shared page pool.
    ``idx`` [B] is each row's append position; unallocated / out-of-range
    logical pages hit the sentinel (>= num_pages) and the write drops."""
    num_pages, page_size = pages.shape[0], pages.shape[1]
    max_pages = block_table.shape[1]
    rows = jnp.arange(block_table.shape[0])
    lp = idx // page_size
    off = idx % page_size
    phys = jnp.where(
        lp < max_pages,
        block_table[rows, jnp.minimum(lp, max_pages - 1)],
        num_pages,
    )
    return pages.at[phys, off].set(row_vals.astype(pages.dtype), mode="drop")


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, Dh = cfg.d_model, cfg.head_dim
    H, KV = cfg.padded_gqa()
    return {
        "norm": rmsnorm_specs(d),
        "wq": Param((d, H, Dh), ("embed", "heads", None)),
        "wk": Param((d, KV, Dh), ("embed", "kv_heads", None)),
        "wv": Param((d, KV, Dh), ("embed", "kv_heads", None)),
        "wo": Param((H, Dh, d), ("heads", None, "embed")),
    }


def _rope_or_mrope(cfg, x, positions):
    if cfg.mrope_sections:
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    if positions.ndim == 3:  # mrope positions given but plain rope cfg
        positions = positions[..., 0]
    return apply_rope(x, positions, cfg.rope_theta)


def attn_apply(
    cfg: ModelConfig,
    params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cache: Optional[Dict] = None,
    *,
    causal: bool = True,
    window: int = 0,
    kv_source: Optional[jnp.ndarray] = None,
    chunk_lens: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """GQA attention. ``cache`` (decode): {"k","v","len"}. ``kv_source``
    (cross-attention): encoder states.

    ``chunk_lens`` ([B] int32, S>1 + cache only) selects the ragged
    cache-writing prefill: row ``b``'s first ``chunk_lens[b]`` tokens of
    the [B, S] slab append into its cache at offset ``cache["len"][b]``
    and attend the full cached prefix — chunked / multi-turn prefill over
    a warm cache, on both KV layouts.  Without it, S>1 prefill keeps the
    legacy empty-cache fast path."""
    from repro.distributed.sharding import constrain

    cdt = cfg.compute_dtype
    h = rmsnorm_apply(params["norm"], x, cfg.norm_eps).astype(cdt)
    src = h if kv_source is None else kv_source.astype(cdt)
    act_axes = ("act_batch", None, "act_heads", None)
    q = constrain(jnp.einsum("bsd,dhk->bshk", h, params["wq"].astype(cdt)), act_axes)
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"].astype(cdt))
    is_self = kv_source is None
    if is_self and causal:
        q = _rope_or_mrope(cfg, q, positions)
        if cache is None:
            k = _rope_or_mrope(cfg, k, positions)
        else:
            k = _rope_or_mrope(cfg, k, positions)
    new_cache = None
    if cache is not None and is_self and "k_pages" in cache:
        # paged decode (continuous batching): each row appends into its
        # block-table page at its own length, attention gathers K/V
        # through the table — no contiguous per-slot rows exist
        if window:
            raise NotImplementedError(
                "windowed attention over a paged cache needs ring-aware "
                "page recycling; the engine restricts paged serving to "
                "full-attention blocks")
        if k.shape[1] > 1:
            if chunk_lens is None:
                raise NotImplementedError(
                    "paged prefill without chunk_lens is not supported: "
                    "pass per-row chunk_lens to run the ragged "
                    "cache-writing prefill through the block tables")
            from repro.kernels import ops

            base = jnp.broadcast_to(
                jnp.asarray(cache["len"], jnp.int32).reshape(-1),
                (k.shape[0],))
            bt = cache["block_table"]
            o, k_pages, v_pages = ops.prefill_attention_paged(
                q, k, v, cache["k_pages"], cache["v_pages"], bt, base,
                chunk_lens, impl=cfg.decode_impl)
            new_cache = {"k_pages": k_pages, "v_pages": v_pages,
                         "block_table": bt, "len": base + chunk_lens}
        else:
            idx = jnp.asarray(cache["len"])
            bt = cache["block_table"]
            k_pages = _paged_append(cache["k_pages"], bt, idx, k[:, 0])
            v_pages = _paged_append(cache["v_pages"], bt, idx, v[:, 0])
            new_cache = {"k_pages": k_pages, "v_pages": v_pages,
                         "block_table": bt, "len": idx + 1}
            o = _paged_decode_attn(cfg, q, k_pages, v_pages, bt, idx + 1)
    elif cache is not None and is_self:
        S = k.shape[1]
        slots_n = cache["k"].shape[1]
        if S > 1 and chunk_lens is not None:
            # ragged cache-writing prefill: append the chunk at each
            # row's own base offset and attend the full cached prefix
            # (kernels/prefill_attention.py via the ops dispatch)
            if window:
                raise NotImplementedError(
                    "windowed attention does not support chunked prefill "
                    "over a warm cache (ring writes need the full prompt)")
            from repro.kernels import ops

            base = jnp.broadcast_to(
                jnp.asarray(cache["len"], jnp.int32).reshape(-1),
                (k.shape[0],))
            o, k_cache, v_cache = ops.prefill_attention(
                q, k, v, cache["k"], cache["v"], base, chunk_lens,
                impl=cfg.decode_impl)
            new_cache = {"k": k_cache, "v": v_cache,
                         "len": base + chunk_lens}
        elif S > 1:
            # batched prefill: write the whole prompt's K/V into the cache
            # in one shot and run the causal flash pass over the fresh
            # K/V (exact because the cache is statically empty — enforced
            # BEFORE any array conversion, on the raw python length)
            _check_prefill_base(cache["len"])
            if window and S >= slots_n:
                # ring cache: only the last `slots_n` positions survive,
                # each at its position-mod-size slot
                keep_k = k[:, S - slots_n:]
                keep_v = v[:, S - slots_n:]
                ring = (S - slots_n + jnp.arange(slots_n)) % slots_n
                k_cache = cache["k"].at[:, ring].set(keep_k.astype(cache["k"].dtype))
                v_cache = cache["v"].at[:, ring].set(keep_v.astype(cache["v"].dtype))
            else:
                k_cache = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
                v_cache = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
            new_cache = {"k": k_cache, "v": v_cache, "len": S}
            o = chunked_attention(
                q, k, v, causal=True, window=window,
                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            )
        elif jnp.asarray(cache["len"]).ndim == 1:
            # per-slot decode (continuous batching): each row appends at
            # its own length; rows past capacity are dropped, not wrapped
            idx = jnp.asarray(cache["len"])
            rows = jnp.arange(k.shape[0])
            slot = idx % slots_n if window else idx
            k_cache = cache["k"].at[rows, slot].set(
                k[:, 0].astype(cache["k"].dtype), mode="drop")
            v_cache = cache["v"].at[rows, slot].set(
                v[:, 0].astype(cache["v"].dtype), mode="drop")
            new_cache = {"k": k_cache, "v": v_cache, "len": idx + 1}
            lens = jnp.minimum(idx + 1, slots_n) if window else idx + 1
            o = _decode_attn(cfg, q, k_cache, v_cache, lens)
        else:
            # decode: append to cache (ring-buffer for windowed attention)
            idx = cache["len"]
            slot = idx % slots_n if window else idx
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
            new_cache = {"k": k_cache, "v": v_cache, "len": idx + 1}
            if window:
                # ring buffer of exactly `window` slots: all valid once warm
                o = _decode_attn(cfg, q, k_cache, v_cache,
                                 jnp.minimum(idx + 1, k_cache.shape[1]))
            else:
                o = _decode_attn(cfg, q, k_cache, v_cache, idx + 1)
    elif cache is not None and not is_self:
        o = _decode_attn(cfg, q, cache["xk"], cache["xv"],
                         jnp.asarray(cache["xlen"], jnp.int32))
        new_cache = cache
    else:
        o = chunked_attention(
            q, k, v, causal=causal, window=window,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        )
    y = jnp.einsum("bshk,hkd->bsd", o.astype(cdt), params["wo"].astype(cdt))
    y = _checkpoint_name(y, "block_out")
    return x + y.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------


def mla_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, H = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    H, _ = cfg.padded_gqa()
    specs: Dict[str, Any] = {
        "norm": rmsnorm_specs(d),
        "wkv_a": Param((d, kvr + rd), ("embed", None)),
        "kv_norm": rmsnorm_specs(kvr),
        "wk_b": Param((kvr, H, nd), ("kv_lora", "heads", None)),
        "wv_b": Param((kvr, H, vd), ("kv_lora", "heads", None)),
        "wo": Param((H, vd, d), ("heads", None, "embed")),
    }
    if qr > 0:
        specs["wq_a"] = Param((d, qr), ("embed", "q_lora"))
        specs["q_norm"] = rmsnorm_specs(qr)
        specs["wq_b"] = Param((qr, H, nd + rd), ("q_lora", "heads", None))
    else:
        specs["wq"] = Param((d, H, nd + rd), ("embed", "heads", None))
    return specs


def mla_apply(
    cfg: ModelConfig,
    params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cache: Optional[Dict] = None,
    *,
    chunk_lens: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    cdt = cfg.compute_dtype
    B, S, _ = x.shape
    H, _kv = cfg.padded_gqa()
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    h = rmsnorm_apply(params["norm"], x, cfg.norm_eps).astype(cdt)

    if cfg.q_lora_rank > 0:
        ql = jnp.einsum("bsd,dr->bsr", h, params["wq_a"].astype(cdt))
        ql = rmsnorm_apply(params["q_norm"], ql, cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", ql, params["wq_b"].astype(cdt))
    else:
        q = jnp.einsum("bsd,dhk->bshk", h, params["wq"].astype(cdt))
    q_nope, q_pe = q[..., :nd], q[..., nd:]
    q_pe = apply_rope(q_pe, positions if positions.ndim == 2 else positions[..., 0], cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", h, params["wkv_a"].astype(cdt))
    c_kv, k_pe = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    c_kv = rmsnorm_apply(params["kv_norm"], c_kv, cfg.norm_eps)
    k_pe = apply_rope(k_pe[:, :, None, :], positions if positions.ndim == 2 else positions[..., 0], cfg.rope_theta)  # [B,S,1,rd]

    new_cache = None
    if cache is not None and S > 1 and chunk_lens is not None:
        # ragged chunked prefill: append the latent chunk at each row's
        # own base offset (both layouts), then attend the full cached
        # latents with per-row causal masking — the latent-cache
        # counterpart of the GQA prefill kernel (latents are rank-sized,
        # so the masked dense expansion stays cheap)
        from repro.kernels.prefill_attention import (write_chunk,
                                                     write_chunk_paged)

        base = jnp.broadcast_to(
            jnp.asarray(cache["len"], jnp.int32).reshape(-1), (B,))
        if "ckv_pages" in cache:
            bt = cache["block_table"]
            ckv_pages = write_chunk_paged(
                cache["ckv_pages"], bt, c_kv, base, chunk_lens)
            kpe_pages = write_chunk_paged(
                cache["kpe_pages"], bt, k_pe[:, :, 0, :], base, chunk_lens)
            new_cache = {"ckv_pages": ckv_pages, "kpe_pages": kpe_pages,
                         "block_table": bt, "len": base + chunk_lens}
            num_pages, page = ckv_pages.shape[0], ckv_pages.shape[1]
            btc = jnp.clip(bt, 0, num_pages - 1)
            mp = bt.shape[1]
            ckv_c = ckv_pages[btc].reshape(B, mp * page,
                                           ckv_pages.shape[-1])
            kpe_c = kpe_pages[btc].reshape(B, mp * page,
                                           kpe_pages.shape[-1])
        else:
            ckv_c = write_chunk(cache["c_kv"], c_kv, base, chunk_lens)
            kpe_c = write_chunk(cache["k_pe"], k_pe[:, :, 0, :], base,
                                chunk_lens)
            new_cache = {"c_kv": ckv_c, "k_pe": kpe_c,
                         "len": base + chunk_lens}
        o = _mla_ragged_prefill_attn(cfg, params, q_nope, q_pe, ckv_c,
                                     kpe_c, base, chunk_lens, cdt)
    elif cache is not None and S > 1:
        # batched prefill: write the latent K/V for the whole prompt, then
        # run the full-attention pass over the fresh latents (exact
        # because the cache is statically empty — enforced BEFORE any
        # array conversion, on the raw python length)
        _check_prefill_base(cache["len"])
        ckv_c = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), 0, axis=1)
        kpe_c = jax.lax.dynamic_update_slice_in_dim(cache["k_pe"], k_pe[:, :, 0, :].astype(cache["k_pe"].dtype), 0, axis=1)
        new_cache = {"c_kv": ckv_c, "k_pe": kpe_c, "len": S}
        o = _mla_full_attention(cfg, params, q_nope, q_pe, c_kv, k_pe, cdt)
    elif cache is not None and "ckv_pages" in cache:
        # paged decode: latents append into the shared page pool through
        # the block table, then gather (tiny: rank + rope dims only),
        # expand per head, and attend via the vector-length kernel
        if S > 1:
            raise NotImplementedError(
                "paged prefill is not supported: prefill writes a "
                "contiguous scratch cache which the engine packs into "
                "pages (page-aligned chunks)")
        idx = jnp.asarray(cache["len"])
        bt = cache["block_table"]
        ckv_pages = _paged_append(cache["ckv_pages"], bt, idx, c_kv[:, 0])
        kpe_pages = _paged_append(cache["kpe_pages"], bt, idx,
                                  k_pe[:, 0, 0, :])
        new_cache = {"ckv_pages": ckv_pages, "kpe_pages": kpe_pages,
                     "block_table": bt, "len": idx + 1}
        num_pages, page = ckv_pages.shape[0], ckv_pages.shape[1]
        btc = jnp.clip(bt, 0, num_pages - 1)
        mp = bt.shape[1]
        ckv_c = ckv_pages[btc].reshape(B, mp * page, ckv_pages.shape[-1])
        kpe_c = kpe_pages[btc].reshape(B, mp * page, kpe_pages.shape[-1])
        o = _mla_expanded_decode(cfg, params, q_nope, q_pe, ckv_c, kpe_c,
                                 idx + 1, cdt)
    elif cache is not None:
        idx = jnp.asarray(cache["len"])
        if idx.ndim == 1:
            # per-slot decode (continuous batching): row-wise append
            rows = jnp.arange(B)
            ckv_c = cache["c_kv"].at[rows, idx].set(
                c_kv[:, 0].astype(cache["c_kv"].dtype), mode="drop")
            kpe_c = cache["k_pe"].at[rows, idx].set(
                k_pe[:, 0, 0, :].astype(cache["k_pe"].dtype), mode="drop")
        else:
            ckv_c = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), idx, axis=1)
            kpe_c = jax.lax.dynamic_update_slice_in_dim(cache["k_pe"], k_pe[:, :, 0, :].astype(cache["k_pe"].dtype), idx, axis=1)
        new_cache = {"c_kv": ckv_c, "k_pe": kpe_c, "len": idx + 1}
        o = _mla_expanded_decode(cfg, params, q_nope, q_pe, ckv_c, kpe_c,
                                 idx + 1, cdt)
    else:
        o = _mla_full_attention(cfg, params, q_nope, q_pe, c_kv, k_pe, cdt)
    y = jnp.einsum("bshk,hkd->bsd", o.astype(cdt), params["wo"].astype(cdt))
    y = _checkpoint_name(y, "block_out")
    return x + y.astype(x.dtype), new_cache


def _mla_ragged_prefill_attn(cfg, params, q_nope, q_pe, ckv_c, kpe_c,
                             base, clens, cdt):
    """Ragged MLA prefill attention: expand the full cached latents to
    per-head K/V and attend the [B,T] query chunk with per-row offsets
    (padding query rows exact zero) — the masked oracle shared with the
    GQA prefill kernels."""
    from repro.kernels.ref import prefill_attend_ref

    B, Sc = ckv_c.shape[0], ckv_c.shape[1]
    H = q_nope.shape[2]
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv_c.astype(cdt),
                        params["wk_b"].astype(cdt))
    v_full = jnp.einsum("bsr,rhk->bshk", ckv_c.astype(cdt),
                        params["wv_b"].astype(cdt))
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kpe_c[:, :, None, :].astype(k_nope.dtype),
                                  (B, Sc, H, rd))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)  # [B,T,H,nd+rd]
    if vd < nd + rd:
        v_pad = jnp.pad(v_full, ((0, 0), (0, 0), (0, 0), (0, nd + rd - vd)))
    else:
        v_pad = v_full
    return prefill_attend_ref(q_full, k_full, v_pad, base, clens)[..., :vd]


def _mla_expanded_decode(cfg, params, q_nope, q_pe, ckv_c, kpe_c, lens, cdt):
    """MLA single-step decode: expand cached latents to full K/V per head
    and run the shared decode kernel (KV == H after expansion, so the GQA
    group is 1).  V is zero-padded to the qk head dim for the kernel, then
    trimmed — padded columns contribute exact zeros."""
    B, Sc = ckv_c.shape[0], ckv_c.shape[1]
    H = q_nope.shape[2]
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv_c.astype(cdt),
                        params["wk_b"].astype(cdt))
    v_full = jnp.einsum("bsr,rhk->bshk", ckv_c.astype(cdt),
                        params["wv_b"].astype(cdt))
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kpe_c[:, :, None, :].astype(k_nope.dtype),
                                  (B, Sc, H, rd))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)  # [B,1,H,nd+rd]
    if vd < nd + rd:
        v_pad = jnp.pad(v_full, ((0, 0), (0, 0), (0, 0), (0, nd + rd - vd)))
    else:
        v_pad = v_full
    return _decode_attn(cfg, q_full, k_full, v_pad, lens)[..., :vd]


def _mla_full_attention(cfg, params, q_nope, q_pe, c_kv, k_pe, cdt):
    """Full causal MLA pass over in-flight latents (training forward and
    the batched-prefill cache write share this)."""
    B, S, H, _ = q_nope.shape
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"].astype(cdt))
    v_full = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"].astype(cdt))
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe, (B, S, H, rd)).astype(k_nope.dtype)], axis=-1
    )
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    # pad v to qk head dim for the shared chunked kernel, then trim
    if vd < nd + rd:
        v_pad = jnp.pad(v_full, ((0, 0), (0, 0), (0, 0), (0, nd + rd - vd)))
    else:
        v_pad = v_full
    return chunked_attention(
        q_full, k_full, v_pad, causal=True,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
    )[..., :vd]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    specs = {
        "norm": rmsnorm_specs(d),
        "w1": Param((d, ff), ("embed", "mlp")),
        "w2": Param((ff, d), ("mlp", "embed")),
    }
    if cfg.mlp_act in ("swiglu", "geglu"):
        specs["w3"] = Param((d, ff), ("embed", "mlp"))
    return specs


def _act(name: str, x):
    if name == "swiglu":
        return jax.nn.silu(x)
    if name == "geglu":
        return jax.nn.gelu(x)
    return jax.nn.gelu(x)


def mlp_apply(cfg: ModelConfig, params, x: jnp.ndarray) -> jnp.ndarray:
    cdt = cfg.compute_dtype
    h = rmsnorm_apply(params["norm"], x, cfg.norm_eps).astype(cdt)
    u = jnp.einsum("bsd,df->bsf", h, params["w1"].astype(cdt))
    if "w3" in params:
        g = jnp.einsum("bsd,df->bsf", h, params["w3"].astype(cdt))
        u = _act(cfg.mlp_act, u) * g
    else:
        u = _act(cfg.mlp_act, u)
    y = jnp.einsum("bsf,fd->bsd", u, params["w2"].astype(cdt))
    y = _checkpoint_name(y, "block_out")
    return x + y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE (GShard capacity-based top-k)
# ---------------------------------------------------------------------------


def moe_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    specs: Dict[str, Any] = {
        "norm": rmsnorm_specs(d),
        "router": Param((d, E), ("embed", "experts"), init="normal", scale=0.02),
        "w1": Param((E, d, ff), ("experts", "embed", "expert_mlp")),
        "w2": Param((E, ff, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.mlp_act in ("swiglu", "geglu"):
        specs["w3"] = Param((E, d, ff), ("experts", "embed", "expert_mlp"))
    if cfg.moe_dense_residual:
        dd = cfg.dense_ff or cfg.d_ff
        specs["dense"] = mlp_specs(cfg, dd)
    return specs


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.num_experts))
    return max(c, 4)


def _route(cfg, params, h):
    """h: [G,S,d] -> gates [G,S,k], idx [G,S,k], aux_loss."""
    logits = jnp.einsum("gsd,de->gse", h, params["router"].astype(h.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx[..., 0], cfg.num_experts, dtype=jnp.float32), axis=-2),
        axis=0,
    ) / probs.shape[1]
    aux = jnp.sum(me * ce) * cfg.num_experts
    return gates.astype(h.dtype), idx, aux


def _positions_in_expert(idx, E, S):
    """idx: [G,S,k] -> pos [G,S,k] slot positions per expert (priority by k
    then token order), plus expert one-hots [G,S,k,E]."""
    G, _, K = idx.shape
    onehots = jax.nn.one_hot(idx, E, dtype=jnp.int32)  # [G,S,k,E]
    # flatten (k major per token? GShard: priority k=0 first across all tokens)
    flat = jnp.transpose(onehots, (0, 2, 1, 3)).reshape(G, K * S, E)
    pos_flat = jnp.cumsum(flat, axis=1) - 1  # [G,k*S,E]
    pos_flat = jnp.sum(pos_flat * flat, axis=-1)  # [G,k*S]
    pos = jnp.transpose(pos_flat.reshape(G, K, S), (0, 2, 1))  # [G,S,k]
    return pos, onehots


def moe_apply(cfg: ModelConfig, params, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss)."""
    cdt = cfg.compute_dtype
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    h = rmsnorm_apply(params["norm"], x, cfg.norm_eps).astype(cdt)
    G = B  # one routing group per batch row (keeps groups data-sharded)
    hg = h.reshape(G, S, d)
    C = _capacity(S, cfg)
    gates, idx, aux = _route(cfg, params, hg)
    pos, onehots = _positions_in_expert(idx, E, S)
    keep = ((pos < C) & (gates > 0)).astype(cdt)

    if cfg.moe_impl == "einsum":
        # GShard-classic: dense one-hot dispatch/combine einsums.
        pos_oh = jax.nn.one_hot(pos, C, dtype=cdt)  # [G,S,k,C]
        disp = jnp.einsum(
            "gske,gskc->gsec", onehots.astype(cdt) * keep[..., None], pos_oh
        )  # [G,S,E,C]
        expert_in = jnp.einsum("gsec,gsd->gecd", disp, hg)
        expert_out = _expert_ffn(cfg, params, expert_in)
        # combine tensor is gate-weighted PER k-choice (outer-producting the
        # summed dispatch with gates would weight each chosen expert by
        # sum(gates)=1 instead of its own gate)
        comb = jnp.einsum(
            "gske,gskc,gsk->gsec",
            onehots.astype(cdt) * keep[..., None], pos_oh, gates * keep,
        )
        y = jnp.einsum("gsec,gecd->gsd", comb, expert_out)
        y = y.reshape(B, S, d)
    else:
        # gather dispatch: no O(S*E*C) dense einsums.
        gidx = jnp.arange(G)[:, None, None]
        slot_token = jnp.full((G, E, C), S, jnp.int32)  # sentinel = S
        tok = jnp.broadcast_to(jnp.arange(S)[None, :, None], idx.shape)
        # out-of-capacity (pos >= C) indices fall outside the slot dim and
        # are dropped by the scatter — they must NOT clobber slot C-1
        slot_token = slot_token.at[gidx, idx, pos].set(tok, mode="drop")
        h_pad = jnp.concatenate([hg, jnp.zeros((G, 1, d), hg.dtype)], axis=1)
        expert_in = jnp.take_along_axis(
            h_pad[:, :, None, :], slot_token.reshape(G, E * C, 1, 1).clip(0, S), axis=1
        ).reshape(G, E, C, d)
        expert_out = _expert_ffn(cfg, params, expert_in)
        eo_flat = expert_out.reshape(G, E * C, d)
        slot_of_tok = jnp.clip(idx * C + jnp.clip(pos, 0, C - 1), 0, E * C - 1)  # [G,S,k]
        picked = jnp.take_along_axis(
            eo_flat[:, :, None, :], slot_of_tok.reshape(G, S * K, 1, 1), axis=1
        ).reshape(G, S, K, d)
        y = jnp.sum(picked * (gates * keep)[..., None], axis=2).reshape(B, S, d)

    if cfg.moe_dense_residual:
        y = y + (mlp_apply(cfg, params["dense"], x) - x)
    return x + y.astype(x.dtype), aux


def _expert_ffn(cfg, params, expert_in):
    """expert_in: [G,E,C,d] -> [G,E,C,d]."""
    cdt = cfg.compute_dtype
    u = jnp.einsum("gecd,edf->gecf", expert_in, params["w1"].astype(cdt))
    if "w3" in params:
        g = jnp.einsum("gecd,edf->gecf", expert_in, params["w3"].astype(cdt))
        u = _act(cfg.mlp_act, u) * g
    else:
        u = _act(cfg.mlp_act, u)
    return jnp.einsum("gecf,efd->gecd", u, params["w2"].astype(cdt))
