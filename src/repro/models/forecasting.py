"""The 11 NeuralForecast models of paper Table 3, as compact JAX
implementations (faithful to each model's core mechanism at benchmark
scale: window W -> horizon Hz univariate point forecasting).

Autoformer / DeepAR / NLinear / GRU / NBEATS / AutoNHITS / PatchTST / TFT /
TimesNet / VanillaTransformer / TiDE.

Each model is (init(key, W, Hz) -> params, apply(params, x[B,W]) -> y[B,Hz]).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Model = Tuple[Callable, Callable]

_D = 64  # shared hidden width at benchmark scale


# -- helpers -----------------------------------------------------------------


def _dense(key, nin, nout, scale=None):
    s = scale or 1.0 / math.sqrt(nin)
    return {
        "w": jax.random.normal(key, (nin, nout)) * s,
        "b": jnp.zeros((nout,)),
    }


def _apply_dense(p, x):
    return x @ p["w"] + p["b"]


def _mlp_init(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return [_dense(k, dims[i], dims[i + 1]) for i, k in enumerate(ks)]


def _mlp_apply(ps, x, act=jax.nn.relu):
    for i, p in enumerate(ps):
        x = _apply_dense(p, x)
        if i < len(ps) - 1:
            x = act(x)
    return x


def _gru_init(key, nin, nh):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wz": _dense(k1, nin + nh, nh), "wr": _dense(k2, nin + nh, nh),
        "wh": _dense(k3, nin + nh, nh),
    }


def _gru_scan(p, xs, h0):
    def cell(h, x):
        xh = jnp.concatenate([x, h], axis=-1)
        z = jax.nn.sigmoid(_apply_dense(p["wz"], xh))
        r = jax.nn.sigmoid(_apply_dense(p["wr"], xh))
        hh = jnp.tanh(_apply_dense(p["wh"], jnp.concatenate([x, r * h], -1)))
        h = (1 - z) * h + z * hh
        return h, h

    h, ys = jax.lax.scan(cell, h0, jnp.swapaxes(xs, 0, 1))
    return h, jnp.swapaxes(ys, 0, 1)


def _attn(q, k, v):
    s = q @ jnp.swapaxes(k, -1, -2) / math.sqrt(q.shape[-1])
    return jax.nn.softmax(s, axis=-1) @ v


def _moving_avg(x, w=13):
    pad = jnp.pad(x, ((0, 0), (w // 2, w - 1 - w // 2)), mode="edge")
    kernel = jnp.ones((w,)) / w
    return jax.vmap(lambda r: jnp.convolve(r, kernel, mode="valid"))(pad)


# -- models ------------------------------------------------------------------


def nlinear(W, Hz) -> Model:
    def init(key):
        return {"head": _dense(key, W, Hz)}

    def apply(p, x):
        last = x[:, -1:]
        return _apply_dense(p["head"], x - last) + last

    return init, apply


def gru(W, Hz) -> Model:
    def init(key):
        k1, k2 = jax.random.split(key)
        return {"gru": _gru_init(k1, 1, _D), "head": _dense(k2, _D, Hz)}

    def apply(p, x):
        h, _ = _gru_scan(p["gru"], x[..., None], jnp.zeros((x.shape[0], _D)))
        return _apply_dense(p["head"], h)

    return init, apply


def deepar(W, Hz) -> Model:
    """GRU backbone emitting (mu, sigma); point forecast = mu (NLL trained
    models reported by their mean in Table 3's point metrics)."""

    def init(key):
        k1, k2 = jax.random.split(key)
        return {"gru": _gru_init(k1, 1, _D), "head": _dense(k2, _D, 2 * Hz)}

    def apply(p, x):
        h, _ = _gru_scan(p["gru"], x[..., None], jnp.zeros((x.shape[0], _D)))
        out = _apply_dense(p["head"], h)
        return out[:, :Hz]  # mu

    return init, apply


def nbeats(W, Hz, blocks=3) -> Model:
    def init(key):
        ks = jax.random.split(key, blocks)
        return [
            {"mlp": _mlp_init(k, [W, _D, _D]),
             "back": _dense(jax.random.fold_in(k, 1), _D, W),
             "fore": _dense(jax.random.fold_in(k, 2), _D, Hz)}
            for k in ks
        ]

    def apply(ps, x):
        residual = x
        forecast = jnp.zeros((x.shape[0], Hz))
        for p in ps:
            h = _mlp_apply(p["mlp"], residual)
            residual = residual - _apply_dense(p["back"], h)
            forecast = forecast + _apply_dense(p["fore"], h)
        return forecast

    return init, apply


def autonhits(W, Hz, pools=(8, 4, 1)) -> Model:
    """NHITS: multi-rate pooling + hierarchical interpolation.  Pool sizes
    are static closure values (NOT params leaves — a traced int inside the
    pytree breaks both grad and reshape under jit)."""

    def init(key):
        ks = jax.random.split(key, len(pools))
        out = []
        for k, pl in zip(ks, pools):
            win = W // pl
            out.append({
                "mlp": _mlp_init(k, [win, _D, _D]),
                "back": _dense(jax.random.fold_in(k, 1), _D, W),
                "fore": _dense(jax.random.fold_in(k, 2), _D, max(Hz // pl, 1)),
            })
        return out

    def apply(ps, x):
        residual = x
        forecast = jnp.zeros((x.shape[0], Hz))
        for p, pl in zip(ps, pools):
            pooled = residual.reshape(x.shape[0], -1, pl).mean(-1)
            h = _mlp_apply(p["mlp"], pooled)
            residual = residual - _apply_dense(p["back"], h)
            f = _apply_dense(p["fore"], h)
            f = jax.image.resize(f, (x.shape[0], Hz), "linear")
            forecast = forecast + f
        return forecast

    return init, apply


def patchtst(W, Hz, patch=8) -> Model:
    def init(key):
        np_ = W // patch
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "embed": _dense(k1, patch, _D),
            "q": _dense(k2, _D, _D), "k": _dense(jax.random.fold_in(k2, 1), _D, _D),
            "v": _dense(jax.random.fold_in(k2, 2), _D, _D),
            "ff": _mlp_init(k3, [_D, 2 * _D, _D]),
            "head": _dense(k4, np_ * _D, Hz),
        }

    def apply(p, x):
        B = x.shape[0]
        patches = x.reshape(B, -1, patch)
        h = _apply_dense(p["embed"], patches)
        a = _attn(_apply_dense(p["q"], h), _apply_dense(p["k"], h),
                  _apply_dense(p["v"], h))
        h = h + a
        h = h + _mlp_apply(p["ff"], h)
        return _apply_dense(p["head"], h.reshape(B, -1))

    return init, apply


def vanilla_transformer(W, Hz) -> Model:
    def init(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "embed": _dense(k1, 1, _D),
            "q": _dense(k2, _D, _D), "k": _dense(jax.random.fold_in(k2, 1), _D, _D),
            "v": _dense(jax.random.fold_in(k2, 2), _D, _D),
            "ff": _mlp_init(k3, [_D, 2 * _D, _D]),
            "head": _dense(k4, _D, Hz),
        }

    def apply(p, x):
        pos = jnp.linspace(-1, 1, x.shape[1])[None, :, None]
        h = _apply_dense(p["embed"], x[..., None]) + pos
        h = h + _attn(_apply_dense(p["q"], h), _apply_dense(p["k"], h),
                      _apply_dense(p["v"], h))
        h = h + _mlp_apply(p["ff"], h)
        return _apply_dense(p["head"], h.mean(axis=1))

    return init, apply


def autoformer(W, Hz) -> Model:
    """Series decomposition + attention on the seasonal part + linear trend."""

    def init(key):
        k1, k2 = jax.random.split(key)
        base = vanilla_transformer(W, Hz)[0](k1)
        base["trend"] = _dense(k2, W, Hz)
        return base

    def apply(p, x):
        trend = _moving_avg(x)
        seasonal = x - trend
        pos = jnp.linspace(-1, 1, x.shape[1])[None, :, None]
        h = _apply_dense(p["embed"], seasonal[..., None]) + pos
        h = h + _attn(_apply_dense(p["q"], h), _apply_dense(p["k"], h),
                      _apply_dense(p["v"], h))
        h = h + _mlp_apply(p["ff"], h)
        return _apply_dense(p["head"], h.mean(axis=1)) + _apply_dense(p["trend"], trend)

    return init, apply


def tft(W, Hz) -> Model:
    """Temporal fusion transformer, reduced: GRN gate + LSTM(GRU) + attn."""

    def init(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "grn": _mlp_init(k1, [1, _D, _D]),
            "gate": _dense(jax.random.fold_in(k1, 1), _D, _D),
            "gru": _gru_init(k2, _D, _D),
            "q": _dense(k3, _D, _D), "k": _dense(jax.random.fold_in(k3, 1), _D, _D),
            "v": _dense(jax.random.fold_in(k3, 2), _D, _D),
            "head": _dense(k4, _D, Hz),
        }

    def apply(p, x):
        h = _mlp_apply(p["grn"], x[..., None], act=jax.nn.elu)
        h = h * jax.nn.sigmoid(_apply_dense(p["gate"], h))
        _, hs = _gru_scan(p["gru"], h, jnp.zeros((x.shape[0], _D)))
        a = _attn(_apply_dense(p["q"], hs[:, -1:]), _apply_dense(p["k"], hs),
                  _apply_dense(p["v"], hs))
        return _apply_dense(p["head"], a[:, 0])

    return init, apply


def timesnet(W, Hz, k_periods=2) -> Model:
    """Top-k FFT periods -> fold to 2D -> conv (depthwise via dense on
    period dim) -> unfold; reduced TimesBlock."""

    def init(key):
        k1, k2 = jax.random.split(key)
        return {"mix": _mlp_init(k1, [W, _D, W]), "head": _dense(k2, W, Hz)}

    def apply(p, x):
        spec = jnp.abs(jnp.fft.rfft(x, axis=-1))
        # dominant-period energy re-weighting (differentiable stand-in for
        # discrete period folding, keeps the frequency-domain selection)
        weights = jax.nn.softmax(spec, axis=-1)
        energy = jnp.fft.irfft(jnp.fft.rfft(x, axis=-1) * weights, n=W, axis=-1)
        h = x + _mlp_apply(p["mix"], energy)
        return _apply_dense(p["head"], h)

    return init, apply


def tide(W, Hz) -> Model:
    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "enc": _mlp_init(k1, [W, _D, _D]),
            "dec": _mlp_init(k2, [_D, _D, Hz]),
            "skip": _dense(k3, W, Hz),
        }

    def apply(p, x):
        h = _mlp_apply(p["enc"], x)
        return _mlp_apply(p["dec"], h) + _apply_dense(p["skip"], x)

    return init, apply


MODELS: Dict[str, Callable[[int, int], Model]] = {
    "Autoformer": autoformer,
    "DeepAR": deepar,
    "NLinear": nlinear,
    "GRU": gru,
    "NBEATS": nbeats,
    "AutoNHITS": autonhits,
    "PatchTST": patchtst,
    "TFT": tft,
    "TimesNet": timesnet,
    "VanillaTransformer": vanilla_transformer,
    "TiDE": tide,
}


def make_ett_series(n: int = 4096, seed: int = 0) -> jnp.ndarray:
    """ETT-like synthetic series (oil-temperature style: daily + weekly
    seasonality + slow trend + noise), standardized."""
    rng = jax.random.PRNGKey(seed)
    t = jnp.arange(n, dtype=jnp.float32)
    series = (
        jnp.sin(2 * jnp.pi * t / 24.0)
        + 0.5 * jnp.sin(2 * jnp.pi * t / (24.0 * 7))
        + 0.3 * jnp.sin(2 * jnp.pi * t / 96.0 + 1.0)
        + 0.0005 * t
        + 0.2 * jax.random.normal(rng, (n,))
    )
    return (series - series.mean()) / series.std()
