"""RemoteAgent: master–worker task executor (paper Fig. 3).

The master holds the queue; workers execute tasks on carved communicators.
Implements the runnability features the brief requires at scale:

* **fault isolation + retry** — a task exception (including simulated
  ``DeviceFailure``) is contained in its Task; failed devices are removed
  from the pilot pool and the task retries on a re-carved (possibly
  smaller) mesh — elastic degradation;
* **straggler mitigation** — speculative duplicate execution when a task
  runs past ``straggler_factor x`` the median duration of its tag class;
  first completion wins;
* **overhead accounting** — per-task communicator-build / queue / execute
  timings (reproduces the paper's Table 2 overhead decomposition).
"""
from __future__ import annotations

import itertools
import queue
import statistics
import threading
import time
import traceback
from concurrent.futures import ThreadPoolExecutor, Future
from typing import Dict, List, Optional

from repro.core.pilot import Pilot
from repro.core.task import DeviceFailure, Task, TaskDescription, TaskState


class RemoteAgent:
    _uid = itertools.count()

    def __init__(self, pilot: Pilot, *, max_workers: int = 4,
                 straggler_factor: float = 3.0, straggler_min_s: float = 1.0):
        self.pilot = pilot
        self.max_workers = max_workers
        self.straggler_factor = straggler_factor
        self.straggler_min_s = straggler_min_s
        self._durations: Dict[str, List[float]] = {}
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="rc-worker")
        self._lock = threading.Lock()

    # -- public --------------------------------------------------------------

    def execute(self, tasks: List[Task]) -> List[Task]:
        """Run a batch of tasks to completion (respecting device capacity,
        priority order)."""
        pending = sorted(tasks, key=lambda t: -t.description.priority)
        futures: Dict[str, Future] = {}
        speculative: Dict[str, Future] = {}
        while pending or futures:
            # launch whatever fits the free pool
            still = []
            launched = False
            for t in pending:
                if self._try_launch(t, futures):
                    launched = True
                    continue
                still.append(t)
            pending = still
            if pending and not futures and not launched:
                # nothing runnable and nothing running: pool is dead
                for t in pending:
                    t.state = TaskState.FAILED
                    t.error = "pilot has no alive devices"
                break
            done_uids = []
            for uid, fut in list(futures.items()):
                t = next(x for x in tasks if x.uid == uid)
                try:
                    fut.result(timeout=0.05)
                    done_uids.append(uid)
                except TimeoutError:
                    self._maybe_speculate(t, futures, speculative)
                except Exception:  # pragma: no cover - result recorded in task
                    done_uids.append(uid)
            for uid in done_uids:
                futures.pop(uid, None)
                spec = speculative.pop(uid, None)
                if spec is not None:
                    spec.cancel()
            # retries
            for t in tasks:
                if (
                    t.state == TaskState.FAILED
                    and t.attempts <= t.description.max_retries
                    and t.uid not in futures
                ):
                    t.state = TaskState.PENDING
                    pending.append(t)
        return tasks

    def submit(self, descriptions: List[TaskDescription]) -> List[Task]:
        tasks = [Task(uid=f"task.{next(self._uid):06d}", description=d)
                 for d in descriptions]
        return self.execute(tasks)

    # -- internals -------------------------------------------------------------

    def _try_launch(self, task: Task, futures: Dict[str, Future]) -> bool:
        d = task.description
        n = min(d.num_devices, max(len(self.pilot.alive_devices()), 1))
        devices = self.pilot.lease(n, task.uid)
        if devices is None:
            return False
        task.state = TaskState.RUNNING
        futures[task.uid] = self._pool.submit(self._run_one, task, devices)
        return True

    def _run_one(self, task: Task, devices) -> None:
        d = task.description
        task.attempts += 1
        task.overhead_s["queue"] = time.time() - task.submitted_at
        try:
            t0 = time.time()
            mesh_shape = d.mesh_shape if d.mesh_shape and len(devices) == _prod(d.mesh_shape) else (len(devices),)
            mesh_axes = d.mesh_axes if len(mesh_shape) == len(d.mesh_axes) else ("data",)
            comm = self.pilot.carve(devices, mesh_shape, mesh_axes)
            task.overhead_s["communicator"] = time.time() - t0
            task.started_at = time.time()
            result = d.fn(comm, *d.args)
            task.finished_at = time.time()
            with self._lock:
                if task.state == TaskState.DONE:
                    return  # a speculative twin won
                task.result = result
                task.state = TaskState.DONE
                self._durations.setdefault(d.kind, []).append(task.duration_s)
        except DeviceFailure as e:
            task.finished_at = time.time()
            self.pilot.mark_failed(e.device_ids)
            task.error = f"DeviceFailure{e.device_ids}"
            task.state = TaskState.FAILED
        except Exception as e:  # noqa: BLE001 — isolation boundary
            task.finished_at = time.time()
            task.error = f"{type(e).__name__}: {e}\n{traceback.format_exc()[-1500:]}"
            task.state = TaskState.FAILED
        finally:
            self.pilot.release(task.uid)

    def _maybe_speculate(self, task: Task, futures, speculative) -> None:
        d = task.description
        if not d.speculative or task.uid in speculative:
            return
        hist = self._durations.get(d.kind, [])
        if len(hist) < 3 or task.started_at is None:
            return
        median = statistics.median(hist)
        runtime = time.time() - task.started_at
        if runtime > max(self.straggler_factor * median, self.straggler_min_s):
            devices = self.pilot.lease(min(d.num_devices, 1), task.uid + ".spec")
            if devices is None:
                return
            speculative[task.uid] = self._pool.submit(self._run_one, task, devices)


def _prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out
