"""RemoteAgent: master–worker task executor (paper Fig. 3).

The master holds the queue; workers execute tasks on carved communicators.
Execution is **event-driven**: ``submit_async`` enqueues tasks and returns
immediately, and a background dispatcher thread launches tasks as devices
free up.  The dispatcher sleeps on a condition variable and is woken by
submissions, task completions, and pilot capacity changes — there is no
polling spin; a bounded wait is used only while straggler speculation is
actually possible.

Execution is layered ``PilotManager -> Pilot -> Transport``: the agent
owns *when* an attempt runs (this dispatcher), and a pluggable
:class:`repro.core.transport.Transport` owns *where* it runs — the
default ``InProcessTransport`` is a thread pool in this process, and the
interface admits a subprocess / jax-distributed transport later without
touching the scheduling logic here.

Runnability features the brief requires at scale:

* **fault isolation + retry** — a task exception (including simulated
  ``DeviceFailure``) is contained in its Task; failed devices are removed
  from the pilot pool and the task retries on a re-carved (possibly
  smaller) mesh — elastic degradation.  With a
  :class:`repro.core.resilience.FailurePolicy` on the description the
  retry loop gains exponential backoff with deterministic jitter
  (retries park on ``Task.not_before``), a per-attempt timeout enforced
  by remote transports, and an end-to-end deadline across all attempts
  — a task that runs out of deadline fails *cleanly*: devices released,
  quotas balanced, callbacks fired;
* **straggler mitigation** — speculative duplicate execution when a task
  runs past ``straggler_factor x`` the median duration of its tag class;
  first completion wins, and the speculative lease is released under its
  own uid so the pool always recovers;
* **overhead accounting** — per-task communicator-build / queue / execute
  timings (reproduces the paper's Table 2 overhead decomposition);
* **per-group device quotas** — tasks carrying a ``group`` (their
  pipeline's name) never hold more devices concurrently than the group's
  quota (``set_quota``); over-quota tasks wait in the queue while other
  groups' tasks launch past them, so one wide pipeline cannot starve its
  siblings (Table-4 fairness).  Every grouped lease/release is recorded
  in ``lease_trace`` and ``group_peaks()`` so fairness is auditable;
* **checkpoint-aware retry** — a retried task whose description names a
  ``checkpoint_dir`` is re-submitted with ``resume_step`` set to the last
  completed step found there, instead of the task fn rediscovering it;
* **service tasks + priority preemption** — a ``service=True`` task is a
  long-running stage (e.g. a continuous-batching inference engine) that
  holds its lease and is driven through its ``ServiceControl``.  When
  higher-priority work is starved of devices or worker slots, the
  dispatcher requests preemption; the service checkpoints its state and
  raises ``ServicePreempted``, the lease is released, and the task is
  re-queued (no retry budget consumed) to resume with
  ``resume_state=<checkpoint>`` once capacity frees up.  Service tasks
  are never speculated and never pollute the straggler duration medians.

Historical bug notes (regression-tested in tests/test_scheduler.py):
``Future.result(timeout=...)`` raises ``concurrent.futures.TimeoutError``,
which on Python 3.10 is NOT a subclass of builtin ``TimeoutError`` — the
old polling loop caught the builtin, so still-running tasks fell into the
generic handler and were popped as done.  The dispatcher design removes
result-polling entirely; the one remaining timed future wait (``close``)
catches ``concurrent.futures.TimeoutError`` explicitly.
"""
from __future__ import annotations

import collections
import concurrent.futures
import itertools
import statistics
import threading
import time
import traceback
from concurrent.futures import Future
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.checkpoint import store as ckpt_store
from repro.core.exec.pickling import ensure_picklable
from repro.core.exec.remote import run_task_body
from repro.core.pilot import Pilot
from repro.core.task import (
    DeviceFailure, ServicePreempted, Task, TaskDescription, TaskState,
)
from repro.core.transport import InProcessTransport, Transport

# Python 3.10: concurrent.futures.TimeoutError is distinct from the builtin;
# 3.11+ aliases them.  Catch both wherever a timed future wait happens.
_FUTURE_TIMEOUT_ERRORS = (TimeoutError, concurrent.futures.TimeoutError)


class RemoteAgent:
    _uid = itertools.count()

    def __init__(self, pilot: Pilot, *, max_workers: int = 4,
                 transport: Optional[Transport] = None,
                 straggler_factor: float = 3.0, straggler_min_s: float = 1.0,
                 straggler_check_s: float = 0.1,
                 lease_trace_limit: int = 10_000):
        self.pilot = pilot
        # an injected transport belongs to the caller (it may be shared
        # across agents); only a transport we created here is shut down
        # in close()
        self._own_transport = transport is None
        self._transport = transport if transport is not None else \
            InProcessTransport(max_workers)
        # the transport bounds in-flight attempts; an explicit transport's
        # capacity wins over the max_workers default
        self.max_workers = (self._transport.capacity
                            if self._transport.capacity is not None
                            else max_workers)
        # a remote transport executes in worker *processes*: the agent
        # ships the picklable module-level task body instead of its bound
        # _run_one, and applies result/preemption transitions in
        # _on_remote_exit when the transport's Future resolves
        self._remote = bool(getattr(self._transport, "remote", False))
        self.straggler_factor = straggler_factor
        self.straggler_min_s = straggler_min_s
        self.straggler_check_s = straggler_check_s
        # _result_lock guards task result/state transitions (primary vs
        # speculative twin); _cond guards the scheduling state below.
        self._result_lock = threading.Lock()
        self._cond = threading.Condition()
        # straggler duration history lives with the scheduling state: its
        # readers (_wait_timeout_locked / _check_stragglers_locked) run
        # under _cond, so the writer must too (_on_worker_exit)
        self._durations: Dict[str, List[float]] = {}  # guarded-by: _cond
        self._pending: List[Task] = []  # guarded-by: _cond  (priority queue)
        self._running: Dict[str, Task] = {}  # guarded-by: _cond  (uid -> task)
        # uid -> (lease uid, fut)
        self._spec: Dict[str, Tuple[str, Future]] = {}  # guarded-by: _cond
        self._seq = itertools.count()             # FIFO tiebreak within priority
        self._order: Dict[str, int] = {}  # guarded-by: _cond
        # per-group quota state: quota caps, devices currently held per
        # group (speculative twins included), observed peaks, and an
        # auditable (time, group, delta, held-after) trace of every
        # grouped lease event
        self._quotas: Dict[str, int] = {}  # guarded-by: _cond
        self._group_held: Dict[str, int] = {}  # guarded-by: _cond
        self._group_peak: Dict[str, int] = {}  # guarded-by: _cond
        self._lease_sizes: Dict[str, Tuple[Optional[str], int]] = {}  # guarded-by: _cond
        self.lease_trace: Deque[Tuple[float, str, int, int]] = \
            collections.deque(maxlen=lease_trace_limit)  # guarded-by: _cond
        #: total preemption requests issued to service tasks (auditable)
        self.preemption_requests = 0  # guarded-by: _cond
        self._closed = False  # guarded-by: _cond
        pilot.add_capacity_listener(self._wake)
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="rc-dispatcher", daemon=True)
        self._dispatcher.start()

    # -- public --------------------------------------------------------------

    def submit_async(self, descriptions: List[TaskDescription],
                     on_complete: Optional[Callable[[Task], None]] = None,
                     ) -> List[Task]:
        """Enqueue tasks and return immediately (non-blocking).

        ``on_complete(task)`` fires once per task when it reaches a terminal
        state — after all retries, never while another attempt is possible.
        Callbacks run on worker threads; they may call ``submit_async``.
        """
        tasks = [Task(uid=f"task.{next(self._uid):06d}", description=d)
                 for d in descriptions]
        if on_complete is not None:
            for t in tasks:
                t.add_done_callback(on_complete)
        self._enqueue(tasks)
        return tasks

    def submit(self, descriptions: List[TaskDescription]) -> List[Task]:
        """Blocking submit: enqueue and wait for every task to finish."""
        tasks = self.submit_async(descriptions)
        self.wait(tasks)
        return tasks

    def execute(self, tasks: List[Task]) -> List[Task]:
        """Run pre-built Task objects to completion (respecting device
        capacity, priority order)."""
        self._enqueue([t for t in tasks if not t.finalized])
        self.wait(tasks)
        return tasks

    def wait(self, tasks: List[Task], timeout: Optional[float] = None) -> bool:
        """Block until all tasks are terminal; False on timeout."""
        deadline = None if timeout is None else time.time() + timeout
        for t in tasks:
            remaining = None if deadline is None else max(0.0, deadline - time.time())
            if not t.wait(remaining):
                return False
        return True

    # -- quotas ----------------------------------------------------------------

    def set_quota(self, group: str, max_devices: Optional[int]) -> None:
        """Cap the devices tasks of ``group`` may hold concurrently (None
        removes the cap).  Raising a quota wakes the dispatcher so newly
        admissible tasks launch immediately."""
        with self._cond:
            if max_devices is None:
                self._quotas.pop(group, None)
            else:
                if max_devices < 1:
                    raise ValueError(f"quota for {group!r} must be >= 1")
                self._quotas[group] = max_devices
            self._cond.notify_all()

    def quota(self, group: str) -> Optional[int]:
        with self._cond:
            return self._quotas.get(group)

    def group_peaks(self) -> Dict[str, int]:
        """Max devices each group was observed holding at once."""
        with self._cond:
            return dict(self._group_peak)

    def quota_violations(self) -> Dict[str, int]:
        """Groups whose observed peak exceeded their quota (empty = the
        enforcement invariant held for the recorded trace)."""
        with self._cond:
            return {g: peak for g, peak in self._group_peak.items()
                    if g in self._quotas and peak > self._quotas[g]}

    def close(self, timeout: Optional[float] = None) -> None:
        """Stop the dispatcher and drain workers (idempotent).  Queued
        tasks that never launched are CANCELED and finalized so waiters
        and completion callbacks are released, not left hanging."""
        self.pilot.remove_capacity_listener(self._wake)
        with self._cond:
            self._closed = True
            abandoned, self._pending = self._pending, []
            for t in abandoned:
                t.state = TaskState.CANCELED
                t.error = "agent closed before task launched"
                t.finalized = True
            specs = list(self._spec.values())  # snapshot under the cond:
            # workers pop from _spec concurrently
            service_controls = [
                t.description.control for t in self._running.values()
                if t.description.service and t.description.control is not None]
            self._cond.notify_all()
        # a service task never returns on its own — without a stop signal
        # the transport drain below would hang forever
        for c in service_controls:
            c.stop()
        for t in abandoned:
            self._finalize(t)
        for _, fut in specs:
            fut.cancel()
            try:
                fut.result(timeout=timeout if timeout is not None else 0)
            except _FUTURE_TIMEOUT_ERRORS:
                pass  # still running: the pool shutdown below will not wait
            except Exception:  # noqa: BLE001 — result already in the task
                pass
        if self._own_transport:
            self._transport.shutdown(wait=timeout is None or timeout > 0)

    def __enter__(self) -> "RemoteAgent":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- scheduling core -------------------------------------------------------

    def _enqueue(self, tasks: List[Task]) -> None:
        if self._remote:
            # fail a contract violation HERE, in the submitter's stack,
            # with the offending closure/capture named — not later as a
            # worker-side pickle traceback
            for t in tasks:
                ensure_picklable(t.description.fn, t.description.args,
                                 transport=self._transport.name)
        with self._cond:
            if self._closed:
                raise RuntimeError("RemoteAgent is closed")
            for t in tasks:
                self._order.setdefault(t.uid, next(self._seq))
                pol = t.description.policy
                if pol is not None and t.deadline is None:
                    # end-to-end deadline: one clock across all attempts,
                    # anchored at submission
                    t.deadline = pol.deadline_at(t.submitted_at)
            self._pending.extend(tasks)
            self._pending.sort(
                key=lambda t: (-t.description.priority, self._order[t.uid]))
            self._cond.notify_all()

    def _wake(self) -> None:
        with self._cond:
            self._cond.notify_all()

    def _dispatch_loop(self) -> None:
        while True:
            with self._cond:
                self._launch_ready_locked()
                self._fail_if_pool_dead_locked()
                if self._closed and not self._running and not self._spec:
                    return
                # Sleep until woken by submit/complete/release.  A bounded
                # wait is used only while speculation could trigger.
                self._cond.wait(self._wait_timeout_locked())

    def _wait_timeout_locked(self) -> Optional[float]:
        timeout: Optional[float] = None
        for task in self._running.values():
            d = task.description
            if (d.speculative and task.uid not in self._spec
                    and len(self._durations.get(d.kind, [])) >= 3):
                timeout = self.straggler_check_s
                break
        # a parked retry (backoff) or a pending deadline needs a timed
        # wake: nothing else is guaranteed to notify the condition then
        now = time.time()
        for t in self._pending:
            for at in (t.not_before, t.deadline):
                if at is not None and at > now:
                    w = (at - now) + 0.005
                    timeout = w if timeout is None else min(timeout, w)
        return timeout

    def _quota_headroom_locked(self, group: Optional[str]) -> Optional[int]:
        """Devices the group may still take (None = unconstrained)."""
        if group is None or group not in self._quotas:
            return None
        return self._quotas[group] - self._group_held.get(group, 0)

    def _record_lease_locked(self, group: Optional[str], delta: int) -> None:
        if group is None:
            return
        held = self._group_held.get(group, 0) + delta
        self._group_held[group] = held
        if delta > 0:
            self._group_peak[group] = max(self._group_peak.get(group, 0), held)
        self.lease_trace.append((time.time(), group, delta, held))

    def _submit_attempt_locked(self, task: Task, devices, lease_uid: str,
                               group) -> bool:
        """Hand one attempt to the transport; on submit failure (e.g. a
        shared transport was shut down) undo the lease/quota bookkeeping
        instead of letting the exception kill the dispatcher thread."""
        try:
            self._submit_to_transport(task, devices, lease_uid)
            return True
        except Exception as e:  # noqa: BLE001 — isolation boundary
            self._lease_sizes.pop(lease_uid, None)
            self._record_lease_locked(group, -len(devices))
            self.pilot.release(lease_uid)
            task.finished_at = time.time()
            task.error = f"transport rejected attempt: {type(e).__name__}: {e}"
            task.state = TaskState.FAILED
            task.finalized = True
            threading.Thread(target=self._finalize, args=(task,),
                             daemon=True).start()
            return False

    def _launch_ready_locked(self) -> None:
        if self._closed:
            return
        still: List[Task] = []
        starved: List[Task] = []  # blocked on capacity (not quota) — these
        # can justify preempting a lower-priority service task
        expired: List[Task] = []  # end-to-end deadline hit before launch
        now = time.time()
        for t in self._pending:
            d = t.description
            if t.deadline is not None and now >= t.deadline:
                # clean failure, not a crash: the task never launched,
                # so no lease/quota state exists to unwind
                t.finished_at = now
                t.error = ((t.error + "; ") if t.error else "") + (
                    f"end-to-end deadline exceeded after {t.attempts} "
                    f"attempt(s) (FailurePolicy.deadline_s="
                    f"{d.policy.deadline_s if d.policy else None})")
                t.state = TaskState.FAILED
                t.finalized = True
                expired.append(t)
                continue
            if t.not_before > now:
                still.append(t)  # parked by retry backoff
                continue
            if d.service and any(
                    s.description.priority > d.priority for s in starved):
                # a (possibly just-preempted) service must not re-grab
                # devices while strictly-higher-priority work is still
                # starved — otherwise preempt/relaunch thrashes, copying
                # the engine checkpoint in a tight loop
                still.append(t)
                continue
            if len(self._running) + len(self._spec) >= self.max_workers:
                still.append(t)
                starved.append(t)
                continue
            n = min(d.num_devices, max(len(self.pilot.alive_devices()), 1))
            headroom = self._quota_headroom_locked(d.group)
            if headroom is not None:
                if headroom < 1:
                    # over quota: this task waits, later (other-group)
                    # tasks still get considered — backpressure without
                    # head-of-line blocking (a preemption would not help:
                    # the group's own quota is the limit)
                    still.append(t)
                    continue
                # a wide task shrinks to its group's remaining share, the
                # same elastic-degradation contract as device failures
                n = min(n, headroom)
            devices = self.pilot.lease(n, t.uid)
            if devices is None:
                still.append(t)
                starved.append(t)
                continue
            t.state = TaskState.RUNNING
            self._running[t.uid] = t
            self._lease_sizes[t.uid] = (d.group, len(devices))
            self._record_lease_locked(d.group, len(devices))
            if not self._submit_attempt_locked(t, devices, t.uid, d.group):
                self._running.pop(t.uid, None)
        self._pending = still
        if expired:
            # callbacks fire outside the condition, like _fail_if_pool_dead
            threading.Thread(
                target=lambda: [self._finalize(t) for t in expired],
                daemon=True).start()
        self._maybe_preempt_locked(starved)
        self._check_stragglers_locked()

    def _maybe_preempt_locked(self, starved: List[Task]) -> None:
        """Ask ONE running service task to yield when strictly-higher-
        priority work is starved of devices or worker slots — the
        lowest-priority service first; if the starved work still cannot
        launch after that yield, the next dispatch pass escalates to the
        next service.  Cooperative: the service notices between work
        units, checkpoints, and raises ``ServicePreempted``; its lease is
        released on the way out.  One-at-a-time matters: every preemption
        costs a full engine checkpoint/restore cycle, so yielding every
        service at once for a one-device deficit doubles serving
        disruption for nothing."""
        if not starved:
            return
        top = max(t.description.priority for t in starved)
        victims = [
            t for t in self._running.values()
            if (t.description.service and t.description.control is not None
                and t.description.priority < top
                and t.state == TaskState.RUNNING)]
        if any(t.description.control.preempt_requested() for t in victims):
            return  # a yield is already in flight; let it land first
        if victims:
            victim = min(victims, key=lambda t: t.description.priority)
            victim.description.control.request_preempt()
            self.preemption_requests += 1

    def _fail_if_pool_dead_locked(self) -> None:
        if (self._pending and not self._running and not self._spec
                and not self.pilot.alive_devices()):
            dead, self._pending = self._pending, []
            for t in dead:
                t.state = TaskState.FAILED
                t.error = "pilot has no alive devices"
                t.finalized = True
            # fire callbacks outside the condition
            threading.Thread(target=lambda: [self._finalize(t) for t in dead],
                             daemon=True).start()

    def _check_stragglers_locked(self) -> None:
        now = time.time()
        for uid, task in list(self._running.items()):
            d = task.description
            # the lease release wakes the dispatcher before _on_worker_exit
            # removes the uid from _running — skip tasks already terminal
            if task.state != TaskState.RUNNING:
                continue
            if not d.speculative or uid in self._spec:
                continue
            hist = self._durations.get(d.kind, [])
            if len(hist) < 3 or task.started_at is None:
                continue
            if now - task.started_at <= max(
                    self.straggler_factor * statistics.median(hist),
                    self.straggler_min_s):
                continue
            if len(self._running) + len(self._spec) >= self.max_workers:
                continue
            headroom = self._quota_headroom_locked(d.group)
            if headroom is not None and headroom < 1:
                continue  # a speculative twin must not bust the quota
            lease_uid = f"{uid}.spec{task.attempts}"
            devices = self.pilot.lease(min(d.num_devices, 1), lease_uid)
            if devices is None:
                continue
            self._lease_sizes[lease_uid] = (d.group, len(devices))
            self._record_lease_locked(d.group, len(devices))
            try:
                fut = self._submit_to_transport(task, devices, lease_uid)
            except Exception:  # noqa: BLE001 — a dead transport must not
                # kill the dispatcher; the primary attempt is still running
                self._lease_sizes.pop(lease_uid, None)
                self._record_lease_locked(d.group, -len(devices))
                self.pilot.release(lease_uid)
                continue
            self._spec[uid] = (lease_uid, fut)

    # -- worker side -----------------------------------------------------------

    def _submit_to_transport(self, task: Task, devices, lease_uid: str):
        """Hand one attempt to the transport.  In-process: the bound
        ``_run_one`` worker.  Remote: the picklable module-level
        ``run_task_body`` — scheduling state stays here (single master),
        only the execution crosses the process boundary."""
        if not self._remote:
            return self._transport.submit(self._run_one, task, devices,
                                          lease_uid)
        d = task.description
        if lease_uid == task.uid:
            # primary bookkeeping happens at dispatch (the worker process
            # cannot touch Task objects); twins leave it alone, as in-process
            task.attempts += 1
            task.overhead_s["queue"] = time.time() - task.submitted_at
            task.started_at = time.time()
        kwargs = {}
        if d.checkpoint_dir is not None:
            kwargs["resume_step"] = d.resume_step
        if d.service:
            kwargs["resume_state"] = d.resume_state
        # a service attempt runs until told to stop — per-attempt
        # deadlines only apply to bounded task bodies
        attempt_timeout = None if d.service else (
            d.policy.attempt_timeout_s if d.policy is not None
            else d.timeout_s)
        return self._transport.submit(
            run_task_body, d.fn, tuple(d.args), kwargs,
            len(devices), d.mesh_shape, d.mesh_axes,
            service_control=d.control if d.service else None,
            on_done=lambda fut, t=task, lu=lease_uid:
                self._on_remote_exit(t, lu, fut),
            label=f"{task.uid} ({d.name})",
            attempt_timeout_s=attempt_timeout)

    def _on_remote_exit(self, task: Task, lease_uid: str, fut) -> None:
        """Remote mirror of ``_run_one``'s state transitions, fired on a
        transport thread when the worker's Future resolves.  A worker
        crash (``WorkerCrashed``) and a remote task exception
        (``RemoteTaskError``) both land in the generic failure path, so
        the checkpoint-aware retry machinery takes over unchanged.  A
        remote ``DeviceFailure`` is a plain failure too: worker-local
        device ids don't map onto this pilot's inventory — for remote
        execution the fault-detection unit is the worker process."""
        d = task.description
        try:
            out = fut.result()  # noqa: TMO001 — done-callback: result is ready
            result = out["result"] if isinstance(out, dict) else out
            overhead = out.get("overhead", {}) if isinstance(out, dict) else {}
            finished = time.time()
            with self._result_lock:
                if task.state == TaskState.DONE:
                    return  # a speculative twin won
                task.finished_at = finished
                if lease_uid == task.uid:
                    task.overhead_s.update(overhead)
                task.result = result
                task.error = None
                task.state = TaskState.DONE
        except ServicePreempted as e:
            with self._result_lock:
                if task.state == TaskState.DONE:
                    return
                task.finished_at = time.time()
                d.resume_state = e.state
                task.preemptions += 1
                task.attempts -= 1  # preemption is a yield, not a failure
                task.state = TaskState.PREEMPTED
        except Exception as e:  # noqa: BLE001 — isolation boundary
            with self._result_lock:
                if task.state == TaskState.DONE:
                    return
                task.finished_at = time.time()
                task.error = f"{type(e).__name__}: {e}"
                task.state = TaskState.FAILED
        finally:
            if task.state == TaskState.FAILED and d.checkpoint_dir is not None:
                # same off-lock resume-point resolution as _run_one
                d.resume_step = ckpt_store.latest_step(d.checkpoint_dir)
            self.pilot.release(lease_uid)
            self._on_worker_exit(task, lease_uid)

    def _run_one(self, task: Task, devices, lease_uid: str) -> None:
        d = task.description
        is_primary = lease_uid == task.uid
        if is_primary:
            # a speculative twin must not consume retry budget nor clobber
            # the primary's timing fields (a shrunken duration would drag
            # the straggler median down and cascade spurious speculation)
            task.attempts += 1
            task.overhead_s["queue"] = time.time() - task.submitted_at
        try:
            t0 = time.time()
            mesh_shape = (d.mesh_shape
                          if d.mesh_shape and len(devices) == _prod(d.mesh_shape)
                          else (len(devices),))
            mesh_axes = (d.mesh_axes if len(mesh_shape) == len(d.mesh_axes)
                         else ("data",))
            comm = self.pilot.carve(devices, mesh_shape, mesh_axes)
            if is_primary:
                task.overhead_s["communicator"] = time.time() - t0
                task.started_at = time.time()
            kwargs = {}
            if d.checkpoint_dir is not None:
                # checkpoint-aware contract: fn accepts resume_step=None on
                # the first attempt; retries get the last completed step
                kwargs["resume_step"] = d.resume_step
            if d.service:
                # service contract: fn accepts the control handle and (on
                # resume after preemption) its own checkpointed state
                kwargs["control"] = d.control
                kwargs["resume_state"] = d.resume_state
            result = d.fn(comm, *d.args, **kwargs)
            finished = time.time()
            with self._result_lock:
                if task.state == TaskState.DONE:
                    return  # a speculative twin won
                task.finished_at = finished
                task.result = result
                task.error = None  # a retry succeeded: stale error must not
                # make error-checking callers reject a DONE task
                task.state = TaskState.DONE
                # NB: the straggler duration history is _cond state — it is
                # recorded in _on_worker_exit when this completion is
                # finalized, not here under _result_lock
        except ServicePreempted as e:
            with self._result_lock:
                if task.state == TaskState.DONE:
                    return
                task.finished_at = time.time()
                d.resume_state = e.state
                task.preemptions += 1
                task.attempts -= 1  # preemption is a yield, not a failure
                task.state = TaskState.PREEMPTED
        except DeviceFailure as e:
            self.pilot.mark_failed(e.device_ids)
            with self._result_lock:
                if task.state == TaskState.DONE:
                    return
                task.finished_at = time.time()
                task.error = f"DeviceFailure{e.device_ids}"
                task.state = TaskState.FAILED
        except Exception as e:  # noqa: BLE001 — isolation boundary
            with self._result_lock:
                if task.state == TaskState.DONE:
                    return
                task.finished_at = time.time()
                task.error = f"{type(e).__name__}: {e}\n{traceback.format_exc()[-1500:]}"
                task.state = TaskState.FAILED
        finally:
            if task.state == TaskState.FAILED and d.checkpoint_dir is not None:
                # resolve the resume point HERE, on the worker thread —
                # a directory scan on slow storage must never run under
                # the scheduling condition in _on_worker_exit
                d.resume_step = ckpt_store.latest_step(d.checkpoint_dir)
            self.pilot.release(lease_uid)  # NB: the lease uid, not task.uid —
            # a speculative twin's lease differs and must be returned too
            self._on_worker_exit(task, lease_uid)

    def _on_worker_exit(self, task: Task, lease_uid: str) -> None:
        """One attempt (primary or speculative) finished running.  Decide —
        under the scheduling condition — whether the task is terminal,
        should retry, or must wait for an in-flight twin."""
        to_finalize = False
        with self._cond:
            group, leased_n = self._lease_sizes.pop(lease_uid, (None, 0))
            self._record_lease_locked(group, -leased_n)
            if lease_uid == task.uid:
                self._running.pop(task.uid, None)
            else:
                spec = self._spec.get(task.uid)
                if spec is not None and spec[0] == lease_uid:
                    self._spec.pop(task.uid, None)
            in_flight = task.uid in self._running or task.uid in self._spec
            if not task.finalized:
                if task.state == TaskState.DONE:
                    # first completion wins, even with a twin still running
                    task.finalized = True
                    to_finalize = True
                    if not task.description.service:
                        # a service run's duration is its lifetime, not a
                        # unit of work — it must not drag straggler medians
                        self._durations.setdefault(
                            task.description.kind, []).append(task.duration_s)
                elif task.state == TaskState.FAILED and not in_flight:
                    pol = task.description.policy
                    now = time.time()
                    budget_ok = (pol.allow_retry(task.attempts)
                                 if pol is not None
                                 else task.attempts
                                 <= task.description.max_retries)
                    deadline_ok = task.deadline is None or now < task.deadline
                    if (not self._closed and budget_ok and deadline_ok
                            and self.pilot.alive_devices()):
                        # checkpoint-aware retry: description.resume_step
                        # was already refreshed off-lock in _run_one.
                        # Under a FailurePolicy the retry is parked until
                        # its backoff elapses (deterministic jitter).
                        if pol is not None:
                            delay = pol.backoff_s(task.attempts,
                                                  key=task.uid)
                            if delay > 0:
                                task.not_before = now + delay
                                task.overhead_s["backoff"] = \
                                    task.overhead_s.get("backoff", 0.0) \
                                    + delay
                        task.state = TaskState.PENDING
                        self._pending.append(task)
                        self._pending.sort(key=lambda t: (
                            -t.description.priority, self._order[t.uid]))
                    else:
                        if not deadline_ok:
                            task.error = ((task.error + "; ")
                                          if task.error else "") + \
                                "end-to-end deadline exceeded (FailurePolicy)"
                        task.finalized = True
                        to_finalize = True
                elif task.state == TaskState.PREEMPTED and not in_flight:
                    if not self._closed and self.pilot.alive_devices():
                        # re-queue at the task's own priority: the work
                        # that preempted it sorts first, and the service
                        # resumes (resume_state already stashed) once
                        # devices free up again
                        if task.description.control is not None:
                            task.description.control._clear_preempt()
                        task.state = TaskState.PENDING
                        self._pending.append(task)
                        self._pending.sort(key=lambda t: (
                            -t.description.priority, self._order[t.uid]))
                    else:
                        task.state = TaskState.CANCELED
                        task.error = "agent closed while service was preempted"
                        task.finalized = True
                        to_finalize = True
            self._cond.notify_all()
        if to_finalize:
            self._finalize(task)

    def _finalize(self, task: Task) -> None:
        """Fire completion callbacks and release waiters (outside the
        scheduling condition)."""
        for cb in task._drain_callbacks():
            try:
                cb(task)
            except Exception:  # noqa: BLE001 — callbacks must not kill workers
                traceback.print_exc()


def _prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out
