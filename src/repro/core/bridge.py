"""The Deep RC Bridge (paper Fig. 2): Data Bridge + System Bridge.

* ``data_bridge`` — Cylon GT -> zero-copy loader for the DL framework
  (repro.bridge.loader).  The GT's device buffers ARE the training input.
* ``system_bridge`` — wraps a dataframe operation as a pilot task whose
  output feeds downstream train/infer tasks (resource flow Cylon -> RP).

``cylon_stage`` / ``dl_stage`` build raw :class:`Stage` objects for the
positional ``fn(comm, upstream, *args)`` contract; new code should
prefer the ``@stage`` decorator DSL in :mod:`repro.core.session`, whose
kinds (``data_engineering`` / ``train`` / ``inference``) drive the
Session's per-stage pod placement the same way.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

from repro.bridge.loader import ZeroCopyLoader
from repro.core.pipeline import Stage
from repro.dataframe.table import Table


def data_bridge(
    table: Table,
    feature_cols: Sequence[str],
    label_col: str,
    global_batch: int,
    **kw,
) -> ZeroCopyLoader:
    return ZeroCopyLoader(table, feature_cols, label_col, global_batch, **kw)


def cylon_stage(
    name: str,
    df_fn: Callable,  # df_fn(comm, upstream) -> Table
    *,
    num_devices: int = 1,
    deps: Sequence[str] = (),
) -> Stage:
    """System Bridge: a data-engineering stage running on CPUs (a 1-D
    worker mesh), producing a GT consumed by DL stages."""
    return Stage(name=name, fn=df_fn, kind="data_engineering",
                 num_devices=num_devices, mesh_axes=("data",), deps=deps)


def dl_stage(
    name: str,
    train_fn: Callable,  # train_fn(comm, upstream[, resume_step=...]) -> result
    *,
    num_devices: int = 1,
    mesh_shape: Optional[tuple] = None,
    mesh_axes: tuple = ("data",),
    deps: Sequence[str] = (),
    kind: str = "train",
    checkpoint_dir: Optional[str] = None,
) -> Stage:
    """``checkpoint_dir`` opts the stage into checkpoint-aware retry: the
    agent passes ``resume_step`` (last completed step under that dir) to
    ``train_fn`` on every retried attempt — see RemoteAgent docs."""
    return Stage(name=name, fn=train_fn, kind=kind, num_devices=num_devices,
                 mesh_axes=mesh_axes, mesh_shape=mesh_shape, deps=deps,
                 checkpoint_dir=checkpoint_dir)
