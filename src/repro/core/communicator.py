"""Per-task communicator construction.

In Deep RC (paper), the RemoteAgent builds an MPI/GLOO/NCCL communicator
with N ranks for each task *at runtime, in constant time* — the measured
3–8 s overhead of Table 2.  The TPU-native analogue: carve a
``jax.sharding.Mesh`` over a slice of the pilot's devices.  Mesh
construction is pure host-side metadata (O(1) in chips), which is how the
design *preserves* the constant-overhead property; ``benchmarks/
overheads.py`` measures it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass
class Communicator:
    """What a task receives: its mesh plus metadata (cf. an MPI comm)."""

    mesh: Mesh
    backend: str  # "ici" on TPU; "host" on CPU placeholders
    build_time_s: float
    devices: Tuple
    # which pilot's pool this mesh was carved from (None for meshes built
    # outside the pilot runtime).  Task fns and the migration tests use it
    # to observe *where* an attempt actually ran.
    pilot_uid: Optional[str] = None

    @property
    def size(self) -> int:
        return len(self.devices)

    def axis_size(self, name: str) -> int:
        return self.mesh.shape[name]

    def describe(self) -> dict:
        return {"pilot": self.pilot_uid, "backend": self.backend,
                "size": self.size, "device_ids": [d.id for d in self.devices]}


def build_communicator(
    devices: Sequence,
    mesh_shape: Optional[Tuple[int, ...]] = None,
    mesh_axes: Tuple[str, ...] = ("data",),
    pilot_uid: Optional[str] = None,
) -> Communicator:
    t0 = time.time()
    n = len(devices)
    if mesh_shape is None:
        mesh_shape = (n,)
    want = 1
    for s in mesh_shape:
        want *= s
    if want != n:
        raise ValueError(f"mesh shape {mesh_shape} needs {want} devices, got {n}")
    arr = np.asarray(devices).reshape(mesh_shape)
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5 explicit-axes API
        mesh = Mesh(arr, mesh_axes,
                    axis_types=(jax.sharding.AxisType.Auto,) * len(mesh_axes))
    else:
        mesh = Mesh(arr, mesh_axes)
    backend = "ici" if devices and devices[0].platform == "tpu" else "host"
    return Communicator(mesh, backend, time.time() - t0, tuple(devices),
                        pilot_uid=pilot_uid)
