"""SubprocessTransport: the worker-pool side of cross-process execution.

Layering contract (unchanged from ``repro.core.transport``): the
RemoteAgent dispatcher is the single master — it decides *when* an
attempt runs; this transport only executes.  Each worker is a long-lived
``repro.core.exec.worker`` daemon process with its own isolated JAX
runtime, connected back over a localhost socket speaking the
length-prefixed pickle protocol.  One task runs per worker at a time, so
``capacity == max_workers`` and the agent's in-flight window maps 1:1
onto processes.

Fault model — a Future returned by ``submit`` always resolves:

- worker returns → result / reconstructed ``RemoteTaskError`` /
  ``ServicePreempted`` (typed result frames; exception *objects* never
  cross the wire);
- worker process exits (crash, SIGKILL, OOM) → the monitor's
  ``proc.poll`` notices within one poll interval and fails the Future
  with ``WorkerCrashed`` — no heartbeat-timeout wait on the fast path;
- worker hangs without dying → missed heartbeats trip the
  ``heartbeat_timeout_s`` backstop, same ``WorkerCrashed``.

Crashed workers are respawned so the agent's checkpoint-aware retry
finds a live pool — under the transport's :class:`FailurePolicy`:
consecutive crashes of the same worker slot back off exponentially
(deterministic jitter), so a crash-looping worker no longer burns the
lifetime ``max_respawns`` cap in seconds, and every respawn (attempt,
streak, delay) is visible in ``stats()``.  The policy's
``attempt_timeout_s`` (or a per-submit override) is enforced by the
monitor: a busy worker whose attempt outlives its deadline is treated
as hung — which is also what rescues a dropped RPC reply.  ``shutdown``
reaps every worker process either way: ``wait=True`` drains in-flight
work first; ``wait=False`` terminates immediately and fails
outstanding Futures.

Chaos hooks: when a :mod:`repro.core.resilience.faults` injector is
armed, the dispatch path consults the ``transport.dispatch`` site after
handing a worker its task (actions ``crash_worker`` / ``stall_heartbeat``
become ``die`` / ``stall`` frames the worker honours), and each worker
channel consults ``protocol.recv`` per inbound frame (``drop`` /
``delay`` of result replies) — every fault mode above is reproducible
from a seed, with detection and recovery exercising the real paths.

Service tasks: ``submit(..., service_control=ctrl)`` bridges the
caller-held :class:`~repro.core.task.ServiceControl` to a replica in the
worker — queued requests and stop/drain/preempt flags flow down; token
streams and terminal request states flow back and are applied to the
client-held Request objects, so streaming semantics match the
in-process transport.
"""
from __future__ import annotations

import collections
import itertools
import os
import subprocess
import sys
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Deque, Dict, List, Optional

from repro.core.exec import pickling, protocol
from repro.core.resilience import faults as rfaults
from repro.core.resilience.policy import FailurePolicy
from repro.core.task import ServicePreempted
from repro.core.transport import Transport


class WorkerCrashed(RuntimeError):
    """The worker process executing a task died (or stopped heartbeating)
    before returning a result."""

    def __init__(self, worker_id: int, pid: Optional[int], label: str,
                 reason: str):
        self.worker_id = worker_id
        self.pid = pid
        super().__init__(
            f"worker {worker_id} (pid {pid}) died while running "
            f"{label or 'a task'}: {reason}")


class RemoteTaskError(RuntimeError):
    """A task fn raised inside a worker.  Carries the remote exception's
    type name and traceback text (the object itself never crosses the
    wire — custom exception signatures don't survive pickling)."""

    def __init__(self, etype: str, message: str, traceback_text: str = ""):
        self.remote_type = etype
        self.remote_traceback = traceback_text
        detail = f"\n--- remote traceback ---\n{traceback_text}" \
            if traceback_text else ""
        super().__init__(f"{etype}: {message}{detail}")


class _Job:
    __slots__ = ("jid", "payload", "future", "label", "service_control",
                 "on_done", "worker_id", "attempt_timeout_s", "deadline")

    def __init__(self, jid: int, payload: bytes, label: str,
                 service_control, on_done,
                 attempt_timeout_s: Optional[float] = None):
        self.jid = jid
        self.payload = payload
        self.label = label
        self.service_control = service_control
        self.on_done = on_done
        self.future: Future = Future()
        self.worker_id: Optional[int] = None
        self.attempt_timeout_s = attempt_timeout_s
        self.deadline: Optional[float] = None  # set at dispatch


class _WorkerHandle:
    __slots__ = ("wid", "proc", "chan", "state", "last_seen", "job",
                 "spawned_at", "devices")

    def __init__(self, wid: int, proc: subprocess.Popen):
        self.wid = wid
        self.proc = proc
        self.chan: Optional[protocol.Channel] = None
        self.state = "starting"  # starting | idle | busy | dead
        self.last_seen = time.time()
        self.job: Optional[_Job] = None
        self.spawned_at = time.time()
        self.devices: Optional[int] = None


class SubprocessTransport(Transport):
    """Pool of worker daemon processes executing pickled task calls."""

    name = "subprocess"
    #: marks transports whose submit crosses a process boundary — the
    #: agent switches to the picklable remote-dispatch path on this flag
    remote = True

    _pool_seq = itertools.count()

    def __init__(self, max_workers: int = 2, *,
                 worker_devices: int = 2,
                 heartbeat_s: float = 0.2,
                 heartbeat_timeout_s: float = 3.0,
                 poll_s: float = 0.05,
                 start_timeout_s: float = 120.0,
                 drain_timeout_s: float = 120.0,
                 max_respawns: int = 16,
                 policy: Optional[FailurePolicy] = None,
                 env: Optional[Dict[str, str]] = None):
        import socket as _socket
        self.capacity = max_workers
        self._worker_devices = worker_devices
        self._heartbeat_s = heartbeat_s
        self._heartbeat_timeout_s = max(heartbeat_timeout_s, 3 * heartbeat_s)
        self._poll_s = poll_s
        self._start_timeout_s = start_timeout_s
        self._drain_timeout_s = drain_timeout_s
        self._env = env
        # respawn backoff + attempt deadlines; the default keeps the first
        # respawn near-immediate but makes a crash-looping slot back off
        # exponentially instead of burning the lifetime cap in seconds
        self._policy = policy if policy is not None else FailurePolicy(
            backoff_base_s=0.1, backoff_factor=2.0, backoff_max_s=5.0,
            jitter=0.1)
        # multi-host hook (set by JaxDistributedTransport)
        self._jax_coordinator: Optional[str] = None
        self._jax_num_processes: Optional[int] = None
        self._jax_process_id: Optional[int] = None

        self._cond = threading.Condition()
        self._workers: Dict[int, _WorkerHandle] = {}  # guarded-by: _cond
        self._queue: Deque[_Job] = collections.deque()  # guarded-by: _cond
        self._inflight: Dict[int, _Job] = {}  # guarded-by: _cond (jid -> job)
        self._closed = False  # guarded-by: _cond
        self._respawns = 0  # guarded-by: _cond
        self._crash_streak: Dict[int, int] = {}  # guarded-by: _cond
        self._respawn_due: Dict[int, float] = {}  # guarded-by: _cond
        self._respawn_log: List[Dict[str, Any]] = []  # guarded-by: _cond
        self._jid = itertools.count()

        self._stream_lock = threading.Lock()
        #: rid -> client-held Request the worker streams into
        self._streams: Dict[str, Any] = {}  # guarded-by: _stream_lock

        self._listener = _socket.socket(_socket.AF_INET, _socket.SOCK_STREAM)
        self._listener.setsockopt(_socket.SOL_SOCKET, _socket.SO_REUSEADDR, 1)
        self._listener.bind(("127.0.0.1", 0))
        self._listener.listen(max_workers + 4)
        self._listener.settimeout(0.2)
        self._port = self._listener.getsockname()[1]

        pool_id = next(self._pool_seq)
        with self._cond:
            for wid in range(max_workers):
                self._workers[wid] = self._spawn_locked(wid)
        self._threads = [
            threading.Thread(target=self._accept_loop,
                             name=f"rc-exec-accept-{pool_id}", daemon=True),
            threading.Thread(target=self._dispatch_loop,
                             name=f"rc-exec-dispatch-{pool_id}", daemon=True),
            threading.Thread(target=self._monitor_loop,
                             name=f"rc-exec-monitor-{pool_id}", daemon=True),
        ]
        for t in self._threads:
            t.start()

    # -- public --------------------------------------------------------------

    def submit(self, fn: Callable, *args,
               service_control=None,
               on_done: Optional[Callable[[Future], None]] = None,
               label: Optional[str] = None,
               attempt_timeout_s: Optional[float] = None,
               **kwargs) -> Future:
        """Ship ``fn(*args, **kwargs)`` to an idle worker.

        Raises ``TypeError`` (naming the offending closure/capture)
        synchronously if the call is unpicklable, and ``RuntimeError`` if
        the transport is shut down.  Execution errors travel through the
        returned Future.  ``on_done`` fires exactly once on a transport
        thread after the Future resolves — never on the submitter's
        thread, so callers may hold scheduling locks while submitting.
        ``attempt_timeout_s`` (default: the transport policy's) bounds
        how long this attempt may run once dispatched before the monitor
        declares the worker hung and fails the Future.
        """
        pickling.ensure_picklable(fn, args, kwargs, transport=self.name)
        payload = pickling.format_payload(
            fn, args, kwargs, service=service_control is not None)
        if attempt_timeout_s is None:
            attempt_timeout_s = self._policy.attempt_timeout_s
        job = _Job(next(self._jid), payload,
                   label or getattr(fn, "__qualname__", repr(fn)),
                   service_control, on_done,
                   attempt_timeout_s=attempt_timeout_s)
        with self._cond:
            if self._closed:
                raise RuntimeError("SubprocessTransport is shut down")
            self._queue.append(job)
            self._cond.notify_all()
        return job.future

    def shutdown(self, wait: bool = True) -> None:
        """Stop the pool.  ``wait=True`` drains in-flight attempts (up to
        ``drain_timeout_s``) then asks workers to exit; ``wait=False``
        terminates worker processes immediately and fails their Futures.
        Either way every worker process is reaped — no orphans."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            queued = list(self._queue)
            self._queue.clear()
            inflight = list(self._inflight.values())
            workers = list(self._workers.values())
            self._cond.notify_all()
        for job in queued:
            self._resolve(job, exc=RuntimeError(
                "transport shut down before dispatch"))
        if wait:
            deadline = time.time() + self._drain_timeout_s
            for job in inflight:
                try:
                    job.future.result(timeout=max(0.0,
                                                  deadline - time.time()))
                except Exception:  # noqa: BLE001 — outcome lives in the Future
                    pass
            for w in workers:
                if w.chan is not None and w.state != "dead":
                    try:
                        w.chan.send({"type": "shutdown"})
                    except (protocol.ConnectionClosed, OSError):
                        pass
        self._reap_all(workers, grace_s=2.0 if wait else 0.2)
        # any Future still unresolved (wait=False, or a drain that timed
        # out on a hung worker) must resolve now — never a hang
        for job in inflight:
            if not job.future.done():
                self._resolve(job, exc=WorkerCrashed(
                    job.worker_id if job.worker_id is not None else -1,
                    None, job.label,
                    "transport shutdown" + ("" if wait else "(wait=False)")))
        try:
            self._listener.close()
        except OSError:
            pass
        for t in self._threads:
            t.join(timeout=1.0)

    def worker_pids(self) -> List[int]:
        """PIDs of live worker processes (test/diagnostic surface)."""
        with self._cond:
            return [w.proc.pid for w in self._workers.values()
                    if w.state != "dead" and w.proc.poll() is None]

    def stats(self) -> Dict[str, Any]:
        """One-lock snapshot of pool health and the respawn history."""
        with self._cond:
            states = collections.Counter(
                w.state for w in self._workers.values())
            now = time.time()
            return {
                "respawns": self._respawns,
                "respawn_log": [dict(r) for r in self._respawn_log],
                "respawn_pending": {
                    wid: max(0.0, due - now)
                    for wid, due in self._respawn_due.items()},
                "crash_streaks": {w: s for w, s in
                                  self._crash_streak.items() if s},
                "queued": len(self._queue),
                "inflight": len(self._inflight),
                "workers": dict(states),
            }

    # -- spawning / reaping ----------------------------------------------------

    def _spawn_locked(self, wid: int) -> _WorkerHandle:
        env = dict(os.environ if self._env is None else self._env)
        env["PYTHONPATH"] = os.pathsep.join(p for p in sys.path if p)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={self._worker_devices}")
        env.setdefault("JAX_PLATFORMS", "cpu")
        cmd = [sys.executable, "-m", "repro.core.exec.worker",
               "--host", "127.0.0.1", "--port", str(self._port),
               "--worker-id", str(wid),
               "--heartbeat-s", str(self._heartbeat_s)]
        if self._jax_coordinator is not None:
            cmd += ["--jax-coordinator", self._jax_coordinator,
                    "--jax-num-processes", str(self._jax_num_processes),
                    "--jax-process-id", str(self._jax_process_id)]
        proc = subprocess.Popen(cmd, env=env)
        return _WorkerHandle(wid, proc)

    def _reap_all(self, workers: List[_WorkerHandle], grace_s: float) -> None:
        for w in workers:
            if w.proc.poll() is None:
                w.proc.terminate()
        deadline = time.time() + grace_s
        for w in workers:
            try:
                w.proc.wait(timeout=max(0.0, deadline - time.time()))
            except subprocess.TimeoutExpired:
                w.proc.kill()
                w.proc.wait()  # noqa: TMO001 — SIGKILL'd: reap cannot hang
            if w.chan is not None:
                w.chan.close()

    # -- accept / receive ------------------------------------------------------

    def _accept_loop(self) -> None:
        import socket as _socket
        while True:
            with self._cond:
                if self._closed:
                    return
            try:
                sock, _ = self._listener.accept()
            except _socket.timeout:
                continue
            except OSError:
                return  # listener closed under us: shutting down
            chan = protocol.Channel(sock)
            try:
                hello = chan.recv(timeout=10.0)
            except (protocol.ConnectionClosed, _socket.timeout):
                chan.close()
                continue
            wid = hello.get("worker_id")
            with self._cond:
                w = self._workers.get(wid)
                if (w is None or w.state == "dead"
                        or w.proc.pid != hello.get("pid")):
                    stale = True  # a replaced worker's late connection
                else:
                    stale = False
                    chan.fault_filter = self._fault_filter_for(wid)
                    w.chan = chan
                    w.state = "idle"
                    w.last_seen = time.time()
                    self._cond.notify_all()
            if stale:
                chan.close()
            else:
                threading.Thread(target=self._recv_loop, args=(w, chan),
                                 name=f"rc-exec-recv-{wid}",
                                 daemon=True).start()

    @staticmethod
    def _fault_filter_for(wid: int):
        """Per-frame chaos filter for a worker channel: consults the
        armed injector's ``protocol.recv`` site so a planned fault can
        drop or delay an RPC reply (recovery then rides the attempt
        deadline, like a real lost result would)."""
        def _filter(msg: Dict[str, Any]):
            inj = rfaults.active()
            if inj is None:
                return None
            return inj.fire("protocol.recv", worker=wid,
                            mtype=msg.get("type"), task=msg.get("task_id"))
        return _filter

    def _recv_loop(self, w: _WorkerHandle, chan: protocol.Channel) -> None:
        while True:
            try:
                msg = chan.recv()  # noqa: TMO001 — heartbeat monitor backstops a dead peer
            except protocol.ConnectionClosed:
                self._worker_lost(w, "channel closed")
                return
            mtype = msg.get("type")
            if mtype in ("heartbeat", "ready"):
                with self._cond:
                    w.last_seen = time.time()
                    if mtype == "ready":
                        w.devices = msg.get("devices")
            elif mtype == "result":
                self._on_result(w, msg)
            elif mtype == "stream":
                self._apply_stream(msg)
            elif mtype == "finish":
                self._apply_finish(msg)

    # -- dispatch --------------------------------------------------------------

    def _dispatch_loop(self) -> None:
        while True:
            to_send: Optional[tuple] = None
            with self._cond:
                while to_send is None:
                    if self._closed:
                        return
                    job, w = self._pick_locked()
                    if job is None:
                        self._cond.wait(0.5)
                        if self._closed:
                            return
                        continue
                    if not job.future.set_running_or_notify_cancel():
                        continue  # cancelled while queued (agent close)
                    w.state = "busy"
                    w.job = job
                    job.worker_id = w.wid
                    if job.attempt_timeout_s is not None:
                        job.deadline = time.time() + job.attempt_timeout_s
                    self._inflight[job.jid] = job
                    to_send = (job, w)
            job, w = to_send
            try:
                # chaos site first: an injected crash/stall frame lands
                # before the task, so the fault deterministically hits
                # the attempt being dispatched (no result/death race)
                self._maybe_inject_dispatch_fault(job, w)
                w.chan.send({"type": "task", "task_id": job.jid,
                             "payload": job.payload})
            except (protocol.ConnectionClosed, OSError):
                self._worker_lost(w, "send failed")
                continue
            if job.service_control is not None:
                threading.Thread(
                    target=self._bridge_loop, args=(job, w),
                    name=f"rc-exec-bridge-{job.jid}", daemon=True).start()

    def _pick_locked(self):
        if not self._queue:
            return None, None
        for w in self._workers.values():
            if w.state == "idle" and w.chan is not None:
                return self._queue.popleft(), w
        return None, None

    def _maybe_inject_dispatch_fault(self, job: _Job,
                                     w: _WorkerHandle) -> None:
        """``transport.dispatch`` chaos site: a planned fault frame is
        sent just ahead of the task frame, so the crash (worker exits
        with the attempt assigned but unfinished) or stall (worker goes
        heartbeat-silent while the attempt runs) hits exactly the
        dispatch the plan named."""
        inj = rfaults.active()
        if inj is None:
            return
        act = inj.fire("transport.dispatch", worker=w.wid, task=job.jid,
                       label=job.label)
        if act is None:
            return
        if act["action"] == "crash_worker":
            w.chan.send({"type": "die"})
        elif act["action"] == "stall_heartbeat":
            w.chan.send({"type": "stall",
                         "for_s": float(act.get("for_s", 1.0))})

    # -- results / faults ------------------------------------------------------

    def _on_result(self, w: _WorkerHandle, msg: Dict[str, Any]) -> None:
        with self._cond:
            w.last_seen = time.time()
            self._crash_streak[w.wid] = 0  # a result proves the slot healthy
            job = self._inflight.pop(msg["task_id"], None)
            if w.job is job:
                w.job = None
            if w.state == "busy":
                w.state = "idle"
            self._cond.notify_all()
        if job is None:
            return  # already failed by the monitor (late result)
        status = msg.get("status")
        if status == "ok":
            self._resolve(job, value=msg.get("value"))
        elif status == "preempted":
            self._resolve(job, exc=ServicePreempted(msg.get("state")))
        else:
            err = msg.get("error") or {}
            self._resolve(job, exc=RemoteTaskError(
                err.get("etype", "Exception"), err.get("message", ""),
                err.get("traceback", "")))

    def _worker_lost(self, w: _WorkerHandle, reason: str) -> None:
        with self._cond:
            if w.state == "dead":
                return
            if self._closed:
                w.state = "dead"
                return  # shutdown() owns reaping and future resolution
            w.state = "dead"
            job, w.job = w.job, None
            if job is not None:
                self._inflight.pop(job.jid, None)
            pid = w.proc.pid
            chan = w.chan
            if self._respawns < self._max_respawns():
                self._respawns += 1
                streak = self._crash_streak.get(w.wid, 0) + 1
                self._crash_streak[w.wid] = streak
                delay = self._policy.backoff_s(streak,
                                               key=f"respawn.{w.wid}")
                self._respawn_log.append({
                    "worker": w.wid, "attempt": self._respawns,
                    "streak": streak, "delay_s": delay})
                if delay <= 0:
                    self._workers[w.wid] = self._spawn_locked(w.wid)
                else:
                    # the monitor performs the spawn once the backoff
                    # elapses; until then the dead handle holds the slot
                    self._respawn_due[w.wid] = time.time() + delay
            self._cond.notify_all()
        if w.proc.poll() is None:
            w.proc.terminate()
        try:
            w.proc.wait(timeout=2.0)
        except subprocess.TimeoutExpired:
            w.proc.kill()
            w.proc.wait()  # noqa: TMO001 — SIGKILL'd: reap cannot hang
        if chan is not None:
            chan.close()
        if job is not None:
            self._resolve(job, exc=WorkerCrashed(w.wid, pid, job.label,
                                                 reason))

    def _max_respawns(self) -> int:
        return 16 if self.capacity is None else max(16, 4 * self.capacity)

    def _monitor_loop(self) -> None:
        while True:
            with self._cond:
                if self._closed:
                    return
                now = time.time()
                for wid, due in list(self._respawn_due.items()):
                    if due <= now:  # backoff elapsed: perform the respawn
                        del self._respawn_due[wid]
                        self._workers[wid] = self._spawn_locked(wid)
                        self._cond.notify_all()
                workers = [(w, w.job) for w in self._workers.values()]
            for w, job in workers:
                if w.state == "dead":
                    continue
                if w.proc.poll() is not None:
                    # fast path: process exit (crash/SIGKILL) — detected at
                    # poll cadence, without waiting out a heartbeat timeout
                    self._worker_lost(
                        w, f"process exited with code {w.proc.returncode}")
                elif (w.chan is not None
                      and now - w.last_seen > self._heartbeat_timeout_s):
                    self._worker_lost(
                        w, f"no heartbeat for "
                           f"{now - w.last_seen:.1f}s (hung?)")
                elif (w.state == "busy" and job is not None
                      and job.deadline is not None and now > job.deadline):
                    # per-attempt deadline (FailurePolicy.attempt_timeout_s):
                    # also the recovery path for a dropped result reply
                    self._worker_lost(
                        w, f"attempt exceeded its "
                           f"{job.attempt_timeout_s:.1f}s deadline")
                elif (w.chan is None
                      and now - w.spawned_at > self._start_timeout_s):
                    self._worker_lost(w, "never connected (start timeout)")
            time.sleep(self._poll_s)

    def _resolve(self, job: _Job, value: Any = None,
                 exc: Optional[BaseException] = None) -> None:
        try:
            if exc is not None:
                job.future.set_exception(exc)
            else:
                job.future.set_result(value)
        except Exception:  # noqa: BLE001 — future already cancelled/resolved
            pass
        if job.on_done is not None:
            try:
                job.on_done(job.future)
            except Exception:  # noqa: BLE001 — callbacks must not kill the pool
                import traceback
                traceback.print_exc()

    # -- service bridge --------------------------------------------------------

    def _bridge_loop(self, job: _Job, w: _WorkerHandle) -> None:
        """Pump the caller-held ServiceControl down to the worker replica
        for the lifetime of one service attempt."""
        control = job.service_control
        sent_stop = sent_drain = sent_preempt = False
        while not job.future.done():
            entries = control.take_requests()
            for entry in entries:
                req = getattr(entry, "request", entry)
                rid = getattr(req, "rid", None)
                if rid is not None:
                    with self._stream_lock:
                        self._streams[rid] = req
                try:
                    w.chan.send({"type": "control", "op": "submit_request",
                                 "data": protocol.dumps(entry)})
                except (protocol.ConnectionClosed, OSError):
                    return
            try:
                if control.stop_requested() and not sent_stop:
                    sent_stop = True
                    w.chan.send({"type": "control", "op": "stop"})
                if control.drain_requested() and not sent_drain:
                    sent_drain = True
                    w.chan.send({"type": "control", "op": "drain"})
                if control.preempt_requested() and not sent_preempt:
                    sent_preempt = True
                    w.chan.send({"type": "control", "op": "preempt"})
            except (protocol.ConnectionClosed, OSError):
                return
            time.sleep(0.005)

    # -- stream application ----------------------------------------------------

    def _apply_stream(self, msg: Dict[str, Any]) -> None:
        with self._stream_lock:
            req = self._streams.get(msg.get("rid"))
        if req is None:
            return
        try:
            from repro.serve.request import RequestState
        except ImportError:
            return
        if req.admitted_at is None and msg.get("admitted_at") is not None:
            req.admitted_at = msg["admitted_at"]
        if req.first_token_at is None and msg.get("first_token_at") is not None:
            req.first_token_at = msg["first_token_at"]
        if req.state == RequestState.QUEUED:
            req.state = RequestState.RUNNING
        req.tokens.extend(msg.get("tokens", ()))
        req.token_times.extend(msg.get("times", ()))

    def _apply_finish(self, msg: Dict[str, Any]) -> None:
        with self._stream_lock:
            req = self._streams.pop(msg.get("rid"), None)
        if req is None:
            return
        try:
            from repro.serve.request import RequestState
        except ImportError:
            return
        req._finish(RequestState[msg["state"]], msg.get("error"))
        if msg.get("finished_at") is not None:
            req.finished_at = msg["finished_at"]


class JaxDistributedTransport(SubprocessTransport):
    """Cross-node flavour of the subprocess pool.

    The single-host build carries the multi-host coordinates through to
    the workers' ``jax.distributed.initialize`` hook
    (``repro.core.exec.worker --jax-coordinator ...``), but there is no
    fabric behind them in this container — so requesting real multi-host
    init raises a specific error instead of hanging on a coordinator
    that will never answer.  Constructed with no coordinates it behaves
    exactly like :class:`SubprocessTransport` (process-isolated workers
    on this host).
    """

    name = "jax-distributed"

    def __init__(self, coordinator: Optional[str] = None,
                 num_processes: Optional[int] = None,
                 process_id: Optional[int] = None, **kwargs):
        multi_host = (coordinator is not None
                      or (num_processes or 1) > 1
                      or (process_id or 0) != 0)
        if multi_host:
            raise NotImplementedError(
                "cross-node multi-host init requested "
                f"(coordinator={coordinator!r}, num_processes={num_processes}"
                f", process_id={process_id}) but no multi-host fabric exists "
                "in this build. The worker daemon already accepts "
                "--jax-coordinator/--jax-num-processes/--jax-process-id "
                "(repro.core.exec.worker) and calls "
                "jax.distributed.initialize with them — point the pool at "
                "real hosts to enable it. For process-isolated workers on "
                "this host, construct without coordinates (or use "
                "SubprocessTransport).")
        super().__init__(**kwargs)
        self._jax_coordinator = coordinator
        self._jax_num_processes = num_processes
        self._jax_process_id = process_id
