"""Worker daemon: one long-lived process of a ``SubprocessTransport`` pool.

Run as ``python -m repro.core.exec.worker --host H --port P
--worker-id N``.  The parent sets the environment before spawn —
notably ``XLA_FLAGS=--xla_force_host_platform_device_count=<k>`` so the
worker owns an isolated emulated device pool, and ``PYTHONPATH`` so
task fns pickled by reference resolve here.  With
``--jax-coordinator/--jax-num-processes/--jax-process-id`` the worker
instead joins a real multi-host fabric via
``jax.distributed.initialize`` before touching devices (the hook pinned
for multi-host deployments; unused under emulation).

Threads:

- **main**: blocking RPC read loop (task / control / shutdown frames);
- **heartbeat**: periodic liveness frames — if a send ever fails the
  parent is gone and the worker exits rather than orphan itself;
- **runner**: executes the current task (one at a time per worker);
- **streamer**: while a service task runs, polls its worker-side
  Request replicas and forwards token deltas / terminal transitions to
  the parent, which applies them to the client-held originals.
"""
from __future__ import annotations

import argparse
import os
import sys
import threading
import time
import traceback
from socket import create_connection
from typing import Any, Dict, Optional

from repro.core.exec import protocol

_STREAM_POLL_S = 0.005


def _error_payload(e: BaseException) -> Dict[str, str]:
    """Exceptions cross the wire as typed dicts, never pickled objects —
    custom ``__init__`` signatures (e.g. DeviceFailure) reconstruct
    wrongly under default exception pickling."""
    return {"etype": type(e).__name__,
            "message": str(e),
            "traceback": traceback.format_exc()[-2000:]}


class _Streamer:
    """Tracks live Request replicas for the running service task and
    mirrors their progress to the parent."""

    def __init__(self, chan: protocol.Channel, task_id: int):
        self._chan = chan
        self._task_id = task_id
        self._lock = threading.Lock()
        #: rid -> [request, tokens_already_sent, finish_sent]
        self._reqs: Dict[str, list] = {}  # guarded-by: _lock
        self._done = threading.Event()
        self._thread = threading.Thread(target=self._loop,
                                        name="rc-exec-streamer", daemon=True)
        self._thread.start()

    def register(self, req, sent: int = 0) -> None:
        with self._lock:
            self._reqs.setdefault(req.rid, [req, sent, False])

    def register_tree(self, obj: Any, _depth: int = 0,
                      _seen: Optional[set] = None) -> None:
        """Find Request instances anywhere inside a resume-state pytree
        (engine checkpoints embed them in slots/queue/outbox) and track
        them as already-streamed up to their current token count."""
        try:
            from repro.serve.request import Request
        except ImportError:  # serve layer absent: nothing to stream
            return
        seen = _seen if _seen is not None else set()
        if _depth > 8 or id(obj) in seen:
            return
        seen.add(id(obj))
        if isinstance(obj, Request):
            self.register(obj, sent=len(obj.tokens))
            return
        if isinstance(obj, dict):
            children = obj.values()
        elif isinstance(obj, (list, tuple, set)):
            children = obj
        elif hasattr(obj, "__dict__") and type(obj).__module__.startswith("repro."):
            children = vars(obj).values()
        else:
            return
        for c in children:
            self.register_tree(c, _depth + 1, seen)

    def _loop(self) -> None:
        while not self._done.wait(_STREAM_POLL_S):
            self.sweep()

    def sweep(self) -> None:
        """Forward any unsent tokens / terminal transitions.  Called from
        the poll loop and synchronously by the runner right before a
        preempted/final result, so parent and worker agree on the token
        count at every checkpoint boundary."""
        with self._lock:
            entries = list(self._reqs.values())
        for entry in entries:
            req, sent, finished_sent = entry
            n = len(req.tokens)
            try:
                if n > sent:
                    self._chan.send({
                        "type": "stream", "task_id": self._task_id,
                        "rid": req.rid,
                        "tokens": [int(t) for t in req.tokens[sent:n]],
                        "times": [float(t) for t in req.token_times[sent:n]],
                        "admitted_at": req.admitted_at,
                        "first_token_at": req.first_token_at,
                    })
                    entry[1] = n
                if req.done() and not finished_sent:
                    self._chan.send({
                        "type": "finish", "task_id": self._task_id,
                        "rid": req.rid, "state": req.state.name,
                        "error": req.error,
                        "finished_at": req.finished_at,
                    })
                    entry[2] = True
            except protocol.ConnectionClosed:
                self._done.set()
                return

    def close(self) -> None:
        self._done.set()
        self._thread.join(timeout=1.0)
        self.sweep()


class _TaskRun:
    """State for the (single) in-flight task on this worker."""

    def __init__(self, chan: protocol.Channel, msg: Dict[str, Any]):
        self.chan = chan
        self.task_id = msg["task_id"]
        self.payload = protocol.loads(msg["payload"])
        #: set just before the result frame goes out.  The busy check
        #: reads this, NOT thread.is_alive(): the parent marks the worker
        #: idle the instant the result frame lands, so a fast next
        #: dispatch can beat the runner thread's teardown.
        self.done = False
        self.control = None
        self.streamer: Optional[_Streamer] = None
        if self.payload.get("service"):
            from repro.core.task import ServiceControl
            self.control = ServiceControl()
            self.streamer = _Streamer(chan, self.task_id)
        self.thread = threading.Thread(target=self._run,
                                       name="rc-exec-runner", daemon=True)

    def handle_control(self, msg: Dict[str, Any]) -> None:
        op = msg["op"]
        if self.control is None:
            return  # stale control frame for a non-service task
        if op == "submit_request":
            entry = protocol.loads(msg["data"])
            req = getattr(entry, "request", entry)  # KVHandoff carries one
            if self.streamer is not None and hasattr(req, "rid"):
                self.streamer.register(req)
            try:
                self.control.submit_request(entry)
            except RuntimeError as e:
                # raced a stop/drain the parent had not seen yet: fail the
                # replica so the streamer reports a terminal state instead
                # of the client-held original hanging forever
                if hasattr(req, "_finish"):
                    from repro.serve.request import RequestState
                    req._finish(RequestState.FAILED, str(e))
        elif op == "stop":
            self.control.stop()
        elif op == "drain":
            self.control.drain()
        elif op == "preempt":
            self.control.request_preempt()

    def _run(self) -> None:
        from repro.core.task import ServicePreempted
        fn = self.payload["fn"]
        args = self.payload["args"]
        kwargs = dict(self.payload["kwargs"])
        if self.control is not None:
            kwargs["control"] = self.control
            if self.streamer is not None:
                self.streamer.register_tree(kwargs.get("resume_state"))
        t0 = time.time()
        try:
            value = fn(*args, **kwargs)
            result = {"type": "result", "task_id": self.task_id,
                      "status": "ok", "value": value,
                      "elapsed": time.time() - t0}
        except ServicePreempted as e:
            result = {"type": "result", "task_id": self.task_id,
                      "status": "preempted", "state": e.state,
                      "elapsed": time.time() - t0}
        except BaseException as e:  # noqa: BLE001 — worker isolation boundary
            result = {"type": "result", "task_id": self.task_id,
                      "status": "error", "error": _error_payload(e),
                      "elapsed": time.time() - t0}
        if self.streamer is not None:
            # final sweep BEFORE the result frame: the parent must hold
            # every token the checkpointed state accounts for by the time
            # the preemption/completion lands
            self.streamer.close()
        self.done = True
        try:
            self.chan.send(result)
        except protocol.ConnectionClosed:
            pass  # parent gone; heartbeat thread will exit the process
        except Exception as e:  # noqa: BLE001 — any pickle failure lands here
            # unpicklable task *result* — report instead of dying silently
            try:
                self.chan.send({"type": "result", "task_id": self.task_id,
                                "status": "error",
                                "error": {"etype": "TypeError",
                                          "message": f"task result failed to "
                                                     f"pickle: {e}",
                                          "traceback": ""},
                                "elapsed": time.time() - t0})
            except protocol.ConnectionClosed:
                pass


#: chaos: heartbeats are suppressed until this wall-clock time — set by
#: a ``stall`` frame so the parent's heartbeat-timeout backstop can be
#: exercised deterministically against a live, task-running worker.
_STALL_UNTIL = [0.0]


def _heartbeat_loop(chan: protocol.Channel, period_s: float) -> None:
    while True:
        time.sleep(period_s)
        if time.time() < _STALL_UNTIL[0]:
            continue  # stalled: alive but silent
        try:
            chan.send({"type": "heartbeat", "t": time.time()})
        except (protocol.ConnectionClosed, OSError):
            # the parent is gone: never linger as an orphan
            os._exit(0)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--worker-id", type=int, required=True)
    ap.add_argument("--heartbeat-s", type=float, default=0.2)
    # multi-host hook: point at a real fabric and the worker joins it
    ap.add_argument("--jax-coordinator", default=None)
    ap.add_argument("--jax-num-processes", type=int, default=None)
    ap.add_argument("--jax-process-id", type=int, default=None)
    args = ap.parse_args(argv)

    chan = protocol.Channel(create_connection((args.host, args.port),
                                              timeout=10))
    chan.send({"type": "hello", "worker_id": args.worker_id,
               "pid": os.getpid()})
    threading.Thread(target=_heartbeat_loop, args=(chan, args.heartbeat_s),
                     name="rc-exec-heartbeat", daemon=True).start()

    if args.jax_coordinator is not None:
        import jax
        jax.distributed.initialize(
            coordinator_address=args.jax_coordinator,
            num_processes=args.jax_num_processes,
            process_id=args.jax_process_id)
    if os.environ.get("REPRO_FAULT_PLAN"):
        # worker-side chaos: the parent (a bench/test) shipped a fault
        # plan through the transport's env hook — sites that fire inside
        # the worker (e.g. checkpoint.save tears) arm here
        from repro.core.resilience import faults as _faults
        _faults.install_from_env()

    # warm the runtime off the task path and tell the parent the pool size
    import jax
    chan.send({"type": "ready", "worker_id": args.worker_id,
               "devices": len(jax.devices())})

    current: Optional[_TaskRun] = None
    while True:
        try:
            msg = chan.recv()  # noqa: TMO001 — main RPC loop; heartbeat thread exits on a dead parent
        except protocol.ConnectionClosed:
            return 0  # parent closed the channel: clean exit
        mtype = msg.get("type")
        if mtype == "task":
            if current is not None and not current.done:
                chan.send({"type": "result", "task_id": msg["task_id"],
                           "status": "error",
                           "error": {"etype": "RuntimeError",
                                     "message": "worker is busy (protocol "
                                                "violation: one task per "
                                                "worker)",
                                     "traceback": ""}})
                continue
            current = _TaskRun(chan, msg)
            current.thread.start()
        elif mtype == "control":
            if current is not None:
                current.handle_control(msg)
        elif mtype == "die":
            # injected crash (FaultPlan.crash_worker): exit hard,
            # mid-task — the parent sees a real process death
            os._exit(3)
        elif mtype == "stall":
            # injected heartbeat stall: stay alive, go silent for a while
            _STALL_UNTIL[0] = time.time() + float(msg.get("for_s", 1.0))
        elif mtype == "shutdown":
            if current is not None and current.control is not None:
                current.control.stop()
            if current is not None:
                current.thread.join(timeout=5.0)
            return 0


if __name__ == "__main__":
    sys.exit(main())
