"""Module-level task adapter shipped to subprocess workers.

The agent's in-process execution path is a bound method
(``RemoteAgent._run_one``) holding the pilot, locks, and scheduling
state — none of which can or should cross a process boundary.  For a
remote transport the agent instead ships :func:`run_task_body`, which
reproduces the *execution* half of ``_run_one`` inside the worker: carve
a communicator over the worker's own (emulated or real) device pool,
then call the task fn under the checkpoint/service kwarg contract.  All
*scheduling* state (attempts, leases, quotas, retry decisions) stays
with the dispatcher in the parent process — the single-master contract.

The worker's device pool is whatever its ``XLA_FLAGS`` host-device
emulation (or a real ``jax.distributed`` fabric) provides; the leased
device count from the parent is a *width request* that degrades to the
local pool size, the same elastic contract the in-process path applies
on device failure.  A ``DeviceFailure`` raised by the task fn inside a
worker is reported as a plain task failure (worker-local device ids do
not map onto the parent pilot's inventory); the real fault-detection
path for remote execution is process death, which the transport turns
into ``WorkerCrashed``.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= x
    return out


def run_task_body(fn,
                  args: Sequence[Any],
                  kwargs: Mapping[str, Any],
                  num_devices: int,
                  mesh_shape: Optional[Tuple[int, ...]],
                  mesh_axes: Tuple[str, ...],
                  control=None) -> Dict[str, Any]:
    """Run one task attempt inside a worker process.

    Returns ``{"result": <fn's return>, "overhead": {...}}`` so the
    parent-side agent can merge the worker's communicator-build timing
    into the task's overhead decomposition.  ``ServicePreempted`` (and
    any other exception) propagates to the worker daemon, which reports
    it as a typed result message.
    """
    import jax

    from repro.core.communicator import build_communicator

    t0 = time.time()
    pool = list(jax.devices())
    n = max(1, min(int(num_devices), len(pool)))
    devices = pool[:n]
    shape = (tuple(mesh_shape)
             if mesh_shape and len(devices) == _prod(mesh_shape)
             else (len(devices),))
    axes = (tuple(mesh_axes) if len(shape) == len(mesh_axes) else ("data",))
    comm = build_communicator(devices, shape, axes)
    overhead = {"communicator": time.time() - t0}
    call_kwargs = dict(kwargs)
    if control is not None:
        # service contract: the worker daemon injects its ServiceControl
        # replica; the task fn drives it exactly like the in-process one
        call_kwargs["control"] = control
    result = fn(comm, *args, **call_kwargs)
    return {"result": result, "overhead": overhead}
