"""Length-prefixed pickle framing for the worker RPC channel.

Every message on the wire is ``4-byte big-endian length || pickle
payload``.  Messages are plain dicts with a ``"type"`` key; the framing
layer knows nothing about their meaning.

Device arrays never cross the wire as device arrays: the pickler
coerces any ``jax.Array`` leaf to numpy at serialisation time (the
receiving process has its own XLA runtime and its own devices — a
pickled device buffer from another process is at best a silent
host-round-trip, at worst refers to donated storage).  Numpy arrays
round-trip bitwise, which is what the KV-handoff path relies on.
"""
from __future__ import annotations

import io
import pickle
import socket
import struct
import sys
import threading
import types
from typing import Any, Optional

_LEN = struct.Struct(">I")
#: refuse absurd frames (corrupt length prefix) rather than allocating.
MAX_FRAME = 1 << 31


class ConnectionClosed(ConnectionError):
    """Peer closed the socket (EOF mid-frame or between frames)."""


class _WireDump(pickle.Pickler):
    """Pickler that lowers jax.Array leaves to numpy.

    Looks jax up through ``sys.modules`` so this module stays importable
    (and usable for pure-python messages) without forcing a jax import.
    """

    def reducer_override(self, obj: Any):
        if (isinstance(obj, types.FunctionType)
                and obj.__module__ == "__main__"
                and "<locals>" not in obj.__qualname__):
            # A fn from a ``python -m pkg.mod`` entry module pickles by
            # reference as ``__main__.name`` — which in the worker is the
            # worker daemon, not the caller's script.  runpy records the
            # real module name in __main__.__spec__; ship that instead.
            real = main_module_name()
            if real is not None:
                return (import_fn, (real, obj.__qualname__))
        jax = sys.modules.get("jax")
        if jax is not None and isinstance(obj, jax.Array):
            import numpy as np
            host = np.asarray(obj)
            return (_as_numpy, (host,))
        return NotImplemented


def _as_numpy(a):
    return a


def main_module_name() -> Optional[str]:
    """The importable name behind ``__main__`` (``python -m pkg.mod``
    runs), or None for plain-script / REPL mains that workers cannot
    re-import."""
    spec = getattr(sys.modules.get("__main__"), "__spec__", None)
    return getattr(spec, "name", None)


def import_fn(module: str, qualname: str):
    """Worker-side loader for a ``__main__``-remapped function: walk the
    qualname in the re-imported module, unwrapping a decorator object
    (e.g. a StageSpec) that holds the raw fn under ``.fn``."""
    import importlib
    obj: Any = importlib.import_module(module)
    for part in qualname.split("."):
        obj = getattr(obj, part)
    if not isinstance(obj, types.FunctionType):
        inner = getattr(obj, "fn", None)
        if isinstance(inner, types.FunctionType):
            return inner
    return obj


def dumps(obj: Any) -> bytes:
    """Pickle ``obj`` for the wire (jax.Array leaves become numpy)."""
    buf = io.BytesIO()
    _WireDump(buf, protocol=pickle.HIGHEST_PROTOCOL).dump(obj)
    return buf.getvalue()


def loads(data: bytes) -> Any:
    return pickle.loads(data)


class Channel:
    """A framed, thread-safe-for-send message channel over a socket.

    Sends may come from several threads (heartbeat + task runner on the
    worker side; dispatcher + service bridges on the parent side), so
    each frame is written under a lock.  Receives are single-threaded by
    construction (one reader thread per channel) and unlocked.
    """

    #: optional chaos hook (repro.core.resilience.faults): called with
    #: each decoded inbound message; may return ``{"action": "drop"}``
    #: to swallow the frame or ``{"action": "delay", "for_s": T}`` to
    #: hold it — simulating a lost / late RPC reply on a live socket.
    fault_filter = None

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._send_lock = threading.Lock()  # guards frame writes on _sock
        self._recv_buf = b""

    def fileno(self) -> int:
        return self._sock.fileno()

    def send(self, msg: Any) -> None:
        payload = dumps(msg)
        frame = _LEN.pack(len(payload)) + payload
        with self._send_lock:
            self._sock.sendall(frame)

    def recv(self, timeout: Optional[float] = None) -> Any:
        """Block for the next message; raise ConnectionClosed on EOF,
        socket.timeout on ``timeout`` expiry.  A timeout mid-frame keeps
        the partial bytes buffered, so the next recv resumes cleanly."""
        while True:
            self._sock.settimeout(timeout)
            header = self._recv_exact(_LEN.size)
            (n,) = _LEN.unpack(header)
            if n > MAX_FRAME:
                raise ConnectionClosed(f"corrupt frame length {n}")
            try:
                payload = self._recv_exact(_LEN.size + n)[_LEN.size:]
            except socket.timeout:
                raise
            self._recv_buf = b""
            msg = loads(payload)
            ff = self.fault_filter
            if ff is not None:
                act = ff(msg)
                if act is not None:
                    if act.get("action") == "drop":
                        continue  # the frame never "arrived"
                    if act.get("action") == "delay":
                        import time as _time
                        _time.sleep(float(act.get("for_s", 0.0)))
            return msg

    def _recv_exact(self, n: int) -> bytes:
        """Grow the resume buffer to ``n`` bytes total and return it."""
        while len(self._recv_buf) < n:
            try:
                chunk = self._sock.recv(min(n - len(self._recv_buf), 1 << 20))
            except socket.timeout:
                raise
            except OSError as e:
                raise ConnectionClosed(str(e)) from e
            if not chunk:
                raise ConnectionClosed("peer closed the channel")
            self._recv_buf += chunk
        return self._recv_buf

    def close(self) -> None:
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
