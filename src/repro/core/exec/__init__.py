"""Cross-process task execution: subprocess-per-worker transport.

This package is the process boundary of the runtime.  The layering
contract from ``repro.core.transport`` is unchanged — the RemoteAgent
dispatcher is the single master; a transport only *executes* — but here
the execution happens in a pool of long-lived worker daemon processes
(`repro.core.exec.worker`), each with its own isolated JAX runtime,
speaking a length-prefixed pickle RPC over localhost sockets
(`repro.core.exec.protocol`) with heartbeat-based liveness
(`repro.core.exec.transport`).

Public surface:

- ``SubprocessTransport`` — the pool.  ``submit(fn, *args, **kwargs)``
  pickles the call, ships it to an idle worker, and returns a Future
  that resolves with the worker's result, raises a reconstructed
  ``RemoteTaskError`` on a remote exception, or raises
  ``WorkerCrashed`` when the worker process dies mid-task (detected by
  process exit or missed heartbeats — never a hang).
- ``JaxDistributedTransport`` — thin subclass carrying the multi-host
  coordinates (coordinator / num_processes / process_id) through to the
  workers' ``jax.distributed.initialize`` hook; raises a specific
  "no multi-host fabric in this build" error when real multi-host init
  is requested.
- ``WorkerCrashed`` / ``RemoteTaskError`` — the two failure shapes.
- ``ensure_picklable`` — submit-time contract check producing a clear
  ``TypeError`` naming the offending closure/capture, instead of a
  worker-side pickle traceback.
- ``run_task_body`` — the module-level adapter the agent ships instead
  of its (unpicklable) bound ``_run_one``: carves a local communicator
  inside the worker and runs the task fn under it.
"""
from repro.core.exec.pickling import ensure_picklable
from repro.core.exec.remote import run_task_body
from repro.core.exec.transport import (JaxDistributedTransport,
                                       RemoteTaskError, SubprocessTransport,
                                       WorkerCrashed)

__all__ = [
    "SubprocessTransport",
    "JaxDistributedTransport",
    "WorkerCrashed",
    "RemoteTaskError",
    "ensure_picklable",
    "run_task_body",
]
