"""Submit-time picklability contract for cross-process transports.

A subprocess worker receives the task call by pickle, which means the
task fn travels *by reference* (module + qualname) and every argument
travels *by value*.  Anything that breaks that — a lambda, a nested
function, a closure over live runtime objects, an argument holding a
lock or a socket — would otherwise surface as an opaque pickle
traceback from deep inside the transport.  ``ensure_picklable`` runs
the same checks at ``submit`` time and raises a ``TypeError`` that
names the offending function, capture, or argument.
"""
from __future__ import annotations

import inspect
import pickle
from typing import Any, Callable, Mapping, Optional, Sequence, Tuple

from repro.core.exec import protocol


def _describe_fn(fn: Callable) -> str:
    name = getattr(fn, "__qualname__", getattr(fn, "__name__", repr(fn)))
    mod = getattr(fn, "__module__", None)
    return f"{mod}.{name}" if mod else str(name)


def _fn_problem(fn: Callable) -> Optional[str]:
    """Why ``fn`` cannot travel by reference to a worker, or None."""
    if not callable(fn):
        return f"{fn!r} is not callable"
    if inspect.ismethod(fn):
        owner = type(fn.__self__).__name__
        return (f"{_describe_fn(fn)} is a bound method of a live "
                f"{owner} instance; workers cannot receive the instance — "
                "pass a module-level function instead")
    code = getattr(fn, "__code__", None)
    name = getattr(fn, "__name__", "")
    qualname = getattr(fn, "__qualname__", name)
    if name == "<lambda>":
        return (f"lambda defined at {code.co_filename}:{code.co_firstlineno} "
                "cannot be pickled; workers import task fns by qualified "
                "name — define it as a module-level function"
                if code else "lambda cannot be pickled")
    if code is not None and "<locals>" in qualname:
        captures = ", ".join(code.co_freevars) or "its enclosing frame"
        return (f"{_describe_fn(fn)} is a nested function (captures: "
                f"{captures}); workers import task fns by qualified name — "
                "move it to module level and pass captured values as "
                "arguments")
    if code is not None and code.co_freevars:
        return (f"{_describe_fn(fn)} captures free variables "
                f"{code.co_freevars} from an enclosing scope; pass them as "
                "arguments instead")
    if getattr(fn, "__module__", None) == "__main__":
        real = protocol.main_module_name()
        if real is None:
            return (f"{_describe_fn(fn)} is defined in a __main__ script "
                    "with no importable module spec; workers import task "
                    "fns by qualified name — run the script with `python "
                    "-m pkg.mod`, or move the function into a module")
        try:
            protocol.import_fn(real, qualname)
        except Exception as e:  # noqa: BLE001 — reshaped into the TypeError
            return (f"{_describe_fn(fn)} does not resolve as "
                    f"{real}.{qualname} when the entry module is "
                    f"re-imported in a worker ({type(e).__name__}: {e})")
    return None


def _first_unpicklable(obj: Any, path: str) -> Optional[str]:
    """Locate the deepest unpicklable piece of ``obj``; None if clean."""
    try:
        protocol.dumps(obj)
        return None
    except Exception:  # noqa: BLE001 — any pickle failure means "explain it"
        pass
    # drill into common containers so the message points at the leaf
    if isinstance(obj, Mapping):
        for k, v in obj.items():
            found = _first_unpicklable(v, f"{path}[{k!r}]")
            if found:
                return found
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            found = _first_unpicklable(v, f"{path}[{i}]")
            if found:
                return found
    return f"{path} holds an unpicklable {type(obj).__name__}: {obj!r:.120}"


def ensure_picklable(fn: Callable,
                     args: Sequence[Any] = (),
                     kwargs: Optional[Mapping[str, Any]] = None,
                     *,
                     transport: str = "subprocess") -> None:
    """Raise TypeError naming the offending closure/capture/argument if
    ``fn(*args, **kwargs)`` cannot be shipped to a worker process."""
    problem = _fn_problem(fn)
    if problem is None:
        try:
            protocol.dumps(fn)
        except Exception as e:  # noqa: BLE001 — reshaped into the TypeError
            problem = (f"{_describe_fn(fn)} failed to pickle by reference "
                       f"({type(e).__name__}: {e}); it must resolve as "
                       "module.qualname in the worker process")
    if problem is None:
        for i, a in enumerate(args):
            problem = _first_unpicklable(a, f"args[{i}]")
            if problem:
                break
    if problem is None and kwargs:
        for k, v in kwargs.items():
            problem = _first_unpicklable(v, f"kwargs[{k!r}]")
            if problem:
                break
    if problem is not None:
        raise TypeError(
            f"task fn for {transport} transport violates the picklable-task "
            f"contract: {problem}")


def check_roundtrip(obj: Any) -> Any:
    """Pickle and unpickle ``obj`` (test helper for wire fidelity)."""
    return pickle.loads(protocol.dumps(obj))


def format_payload(fn: Callable, args: Tuple, kwargs: Mapping[str, Any],
                   service: bool) -> bytes:
    """Serialise a task call, converting pickle errors into the contract
    TypeError (callers that skipped ensure_picklable still get the
    readable message, not a worker-side traceback)."""
    try:
        return protocol.dumps({"fn": fn, "args": tuple(args),
                               "kwargs": dict(kwargs), "service": service})
    except TypeError:
        raise
    except Exception as e:  # noqa: BLE001 — reshaped into the TypeError
        ensure_picklable(fn, args, kwargs)
        raise TypeError(f"task payload failed to pickle: {e}") from e
