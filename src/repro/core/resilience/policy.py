"""Unified failure policy: retry budgets, backoff, deadlines, breakers.

Before this module every layer answered "a fault happened — now what?"
with its own ad-hoc constant: the agent had a bare ``max_retries``
counter, ``SubprocessTransport`` respawned crashed workers immediately
(a crash-looping worker burned its lifetime respawn cap in seconds),
and the fleet router kept routing to an engine that died on every
request.  ``FailurePolicy`` is the one answer all three consult:

* **retry budget** — how many attempts a unit of work gets;
* **exponential backoff + deterministic jitter** — how long to wait
  between attempts (jitter is a pure function of ``(seed, key,
  attempt)`` so a replayed schedule is bit-identical — no
  ``random.random()`` flakes in tests);
* **per-attempt timeout** — how long a single attempt may run before
  the runtime declares it hung (the transport's monitor enforces it);
* **end-to-end deadline** — how long the whole unit of work may take
  across all attempts before it fails *cleanly* (devices released,
  quotas balanced) instead of retrying forever.

``CircuitBreaker`` layers fleet semantics on top: after
``eject_after`` consecutive faults a member is ejected (``open``), sits
out a probationary window, then a single probe request decides whether
it is re-admitted (``half_open`` → ``closed``) or re-ejected.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Optional

__all__ = ["FailurePolicy", "CircuitBreaker"]


def _unit_hash(*parts) -> float:
    """Deterministic float in [0, 1) from the given parts."""
    h = hashlib.sha256("|".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:8], "big") / 2 ** 64


@dataclasses.dataclass(frozen=True)
class FailurePolicy:
    """How a unit of work (task attempt, worker respawn, engine) retries.

    The default mirrors the legacy ``TaskDescription.max_retries = 2``
    behaviour with zero backoff, so installing a policy nowhere changes
    scheduling until a caller opts into backoff/deadlines.
    """

    #: retry budget: total attempts allowed = max_retries + 1
    max_retries: int = 2
    #: first backoff delay; 0 disables backoff entirely
    backoff_base_s: float = 0.0
    #: multiplier applied per further attempt
    backoff_factor: float = 2.0
    #: ceiling on any single backoff delay
    backoff_max_s: float = 30.0
    #: extra delay as a fraction of the backoff, in [0, jitter)
    jitter: float = 0.1
    #: how long one attempt may run before it is declared hung
    attempt_timeout_s: Optional[float] = None
    #: wall-clock budget for the whole unit of work across attempts
    deadline_s: Optional[float] = None
    #: fleet routing: consecutive faults before an engine is ejected
    #: (its CircuitBreaker opens and traffic re-routes to siblings)
    eject_after: int = 3
    #: fleet routing: seconds an ejected engine sits out before a
    #: single probe request decides its re-admission
    probation_s: float = 1.0
    #: seeds the deterministic jitter
    seed: int = 0

    def allow_retry(self, attempts: int) -> bool:
        """True if another attempt fits the budget (attempts so far)."""
        return attempts <= self.max_retries

    def backoff_s(self, attempt: int, key: str = "") -> float:
        """Delay before attempt ``attempt + 1`` (attempt counts from 1).

        Deterministic: the jitter term is a hash of ``(seed, key,
        attempt)``, so the same schedule replays identically while
        distinct keys (task uids, worker ids) still decorrelate.
        """
        if self.backoff_base_s <= 0:
            return 0.0
        base = self.backoff_base_s * self.backoff_factor ** max(0, attempt - 1)
        base = min(base, self.backoff_max_s)
        if self.jitter > 0:
            base *= 1.0 + self.jitter * _unit_hash(self.seed, key, attempt)
        return min(base, self.backoff_max_s * (1.0 + self.jitter))

    def deadline_at(self, start: float) -> Optional[float]:
        """Absolute deadline for work that started at ``start``."""
        if self.deadline_s is None:
            return None
        return start + self.deadline_s

    @classmethod
    def from_retries(cls, max_retries: int) -> "FailurePolicy":
        """Legacy adapter: bare retry counter, no backoff, no deadline."""
        return cls(max_retries=max_retries)


class CircuitBreaker:
    """Consecutive-fault ejection with probationary re-admission.

    States: ``closed`` (healthy) → ``open`` (ejected after
    ``eject_after`` consecutive faults; sits out ``probation_s``) →
    ``half_open`` (one probe admitted) → ``closed`` on probe success or
    back to ``open`` on probe failure.  Thread-safe; every transition
    is appended to ``transitions`` for tests and stats.
    """

    def __init__(self, eject_after: int = 3, probation_s: float = 1.0,
                 clock=time.monotonic):
        self.eject_after = max(1, int(eject_after))
        self.probation_s = float(probation_s)
        self._clock = clock
        self._lock = threading.Lock()
        # guarded-by: _lock
        self._state = "closed"
        self._faults = 0          # consecutive faults while closed
        self._opened_at = 0.0
        self._probe_inflight = False
        self.transitions = []     # [(state, at)] — appended under _lock

    # -- state transitions -------------------------------------------------
    def _set_locked(self, state: str) -> None:
        if state != self._state:
            self._state = state
            self.transitions.append((state, self._clock()))

    def record_fault(self) -> bool:
        """Count one fault.  Returns True if this fault ejected (opened)."""
        with self._lock:
            if self._state == "half_open":
                # probe failed: back to open, restart probation
                self._probe_inflight = False
                self._opened_at = self._clock()
                self._set_locked("open")
                return True
            self._faults += 1
            if self._state == "closed" and self._faults >= self.eject_after:
                self._opened_at = self._clock()
                self._set_locked("open")
                return True
            return False

    def record_success(self) -> None:
        """Count one success: closes a half-open probe, clears the streak."""
        with self._lock:
            self._faults = 0
            if self._state == "half_open":
                self._probe_inflight = False
                self._set_locked("closed")

    # -- admission ---------------------------------------------------------
    def admit(self) -> bool:
        """May this member take a request right now?

        ``closed`` → yes.  ``open`` → no until probation elapses, at
        which point the breaker moves to ``half_open`` and admits
        exactly one probe; further calls return False until the probe
        resolves via record_success/record_fault.
        """
        with self._lock:
            if self._state == "closed":
                return True
            if self._state == "open":
                if self._clock() - self._opened_at >= self.probation_s:
                    self._set_locked("half_open")
                    self._probe_inflight = True
                    return True
                return False
            # half_open: only the single in-flight probe
            if not self._probe_inflight:
                self._probe_inflight = True
                return True
            return False

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "state": self._state,
                "consecutive_faults": self._faults,
                "probe_inflight": self._probe_inflight,
                "transitions": list(self.transitions),
            }
