"""Resilience layer: deterministic fault injection + unified failure policy.

Two halves, used together by the chaos suite and separately by the
runtime:

* :mod:`repro.core.resilience.faults` — seeded :class:`FaultPlan` /
  :class:`FaultInjector` with injection sites threaded through the
  transport, worker, RPC protocol, checkpoint store, and fleet router,
  so every failure mode the runtime claims to survive is reproducible
  in-process from a single seed.
* :mod:`repro.core.resilience.policy` — :class:`FailurePolicy`
  (exponential backoff + deterministic jitter, retry budgets,
  per-attempt timeouts, end-to-end deadlines) honored by the agent's
  retry loop, worker respawn in ``SubprocessTransport``, and the
  router's per-engine :class:`CircuitBreaker`.
"""
from repro.core.resilience.faults import (
    FaultInjector,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    active,
    inject,
    install_from_env,
    set_fault_injector,
)
from repro.core.resilience.policy import CircuitBreaker, FailurePolicy

__all__ = [
    "CircuitBreaker", "FailurePolicy", "FaultInjector", "FaultPlan",
    "FaultSpec", "InjectedFault", "active", "inject", "install_from_env",
    "set_fault_injector",
]
