"""Deterministic fault injection: seeded plans, logical event traces.

Chaos testing a runtime with ``kill -9`` at "about the right moment" is
how flaky CI is made: the interesting failure windows (mid-dispatch,
mid-checkpoint-rename, mid-KV-handoff) are microseconds wide and move
with machine load.  This module replaces wall-clock racing with
*logical* triggers: a ``FaultPlan`` is a list of ``FaultSpec``s, each
naming an **injection site** (a stable string the runtime consults at
the exact vulnerable point), a **match** on the event's coordinates
(worker id, engine uid, checkpoint step), and an **nth** occurrence
counter.  The Nth matching event at that site fires the fault — every
run of the same plan over the same workload fires at the same logical
point, so the recorded event ``trace()`` is reproducible bit-for-bit
from the seed and plan alone.

Sites threaded through the runtime:

==================== ====================================================
``transport.dispatch``  a task frame was sent to a subprocess worker —
                        actions ``crash_worker`` (worker ``os._exit``\\ s
                        mid-task) and ``stall_heartbeat`` (worker stops
                        heartbeating for ``for_s`` seconds)
``protocol.recv``       a frame arrived on a :class:`~repro.core.exec.
                        protocol.Channel` — actions ``drop`` (swallow
                        the frame) and ``delay`` (hold it ``for_s``)
``checkpoint.save``     a checkpoint step finished its atomic rename —
                        action ``tear`` truncates a leaf (or the
                        manifest) at byte offset ``at_byte``, the
                        post-crash torn state fsync exists to prevent
``handoff.deliver``     a KV-page handoff is being bound on a decode
                        engine — action ``fail`` aborts the delivery
``engine.step``         a ServeEngine is about to run one decode step —
                        action ``crash`` raises :class:`InjectedFault`
==================== ====================================================

Hooks are module-global (``set_fault_injector`` / ``active()``) so the
runtime pays one ``is None`` check per site when chaos is off.  Plans
serialize to JSON (``to_json``/``from_json``) so a parent process can
arm faults inside subprocess workers through the transport's ``env=``
hook (``REPRO_FAULT_PLAN``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import threading
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "InjectedFault", "FaultSpec", "FaultPlan", "FaultInjector",
    "set_fault_injector", "active", "inject", "install_from_env",
    "PLAN_ENV",
]

#: env var carrying a JSON FaultPlan into subprocess workers
PLAN_ENV = "REPRO_FAULT_PLAN"


class InjectedFault(RuntimeError):
    """Raised (or delivered) by a fired fault — always deliberate."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned fault: fire ``action`` at the ``nth`` event at
    ``site`` whose coordinates equal every entry of ``match``."""

    site: str
    action: str
    nth: int = 1
    match: Tuple[Tuple[str, Any], ...] = ()
    params: Tuple[Tuple[str, Any], ...] = ()

    @classmethod
    def make(cls, site: str, action: str, nth: int = 1,
             match: Optional[Dict[str, Any]] = None,
             params: Optional[Dict[str, Any]] = None) -> "FaultSpec":
        return cls(site=site, action=action, nth=max(1, int(nth)),
                   match=tuple(sorted((match or {}).items())),
                   params=tuple(sorted((params or {}).items())))


class FaultPlan:
    """Builder for a seeded, declarative fault schedule."""

    def __init__(self, seed: int = 0,
                 specs: Optional[List[FaultSpec]] = None):
        self.seed = int(seed)
        self.specs: List[FaultSpec] = list(specs or [])

    # -- declarative builders (chainable) ----------------------------------
    def _add(self, site, action, nth=1, match=None, params=None):
        self.specs.append(FaultSpec.make(site, action, nth, match, params))
        return self

    def crash_worker(self, worker: Optional[int] = None,
                     at_task: int = 1) -> "FaultPlan":
        """Kill worker ``worker`` (any, if None) right after it is handed
        its ``at_task``-th matching task — the worker exits mid-task."""
        m = {} if worker is None else {"worker": worker}
        return self._add("transport.dispatch", "crash_worker",
                         nth=at_task, match=m)

    def stall_heartbeat(self, for_s: float, worker: Optional[int] = None,
                        at_task: int = 1) -> "FaultPlan":
        """Suppress a worker's heartbeats for ``for_s`` seconds starting
        at its ``at_task``-th dispatch (task keeps running)."""
        m = {} if worker is None else {"worker": worker}
        return self._add("transport.dispatch", "stall_heartbeat",
                         nth=at_task, match=m, params={"for_s": for_s})

    def drop_reply(self, nth: int = 1,
                   worker: Optional[int] = None) -> "FaultPlan":
        """Swallow the ``nth`` task-result frame on the parent channel."""
        m = {"mtype": "result"}
        if worker is not None:
            m["worker"] = worker
        return self._add("protocol.recv", "drop", nth=nth, match=m)

    def delay_reply(self, for_s: float, nth: int = 1) -> "FaultPlan":
        """Hold the ``nth`` task-result frame for ``for_s`` seconds."""
        return self._add("protocol.recv", "delay", nth=nth,
                         match={"mtype": "result"},
                         params={"for_s": for_s})

    def tear_checkpoint(self, at_byte: int, step: Optional[int] = None,
                        leaf: int = 0, nth: int = 1) -> "FaultPlan":
        """Truncate leaf file ``leaf`` (or the manifest if ``leaf < 0``)
        of checkpoint ``step`` at ``at_byte`` right after the rename —
        the on-disk state a crash between rename and data sync leaves."""
        m = {} if step is None else {"step": step}
        return self._add("checkpoint.save", "tear", nth=nth, match=m,
                         params={"at_byte": at_byte, "leaf": leaf})

    def fail_handoff(self, nth: int = 1) -> "FaultPlan":
        """Abort the ``nth`` KV-page handoff delivery."""
        return self._add("handoff.deliver", "fail", nth=nth)

    def crash_engine(self, engine: Optional[str] = None,
                     at_step: int = 1) -> "FaultPlan":
        """Raise InjectedFault out of the engine's ``at_step``-th step."""
        m = {} if engine is None else {"engine": engine}
        return self._add("engine.step", "crash", nth=at_step, match=m)

    # -- serialization (env-var propagation into workers) ------------------
    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "specs": [{"site": s.site, "action": s.action, "nth": s.nth,
                       "match": dict(s.match), "params": dict(s.params)}
                      for s in self.specs],
        }, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        raw = json.loads(text)
        return cls(seed=raw.get("seed", 0),
                   specs=[FaultSpec.make(s["site"], s["action"],
                                         s.get("nth", 1), s.get("match"),
                                         s.get("params"))
                          for s in raw.get("specs", [])])

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)


class FaultInjector:
    """Counts site events against a plan and fires each spec once.

    ``fire(site, **coords)`` is the single runtime entry point: it bumps
    the per-spec counter of every spec whose site and match agree with
    the event and, when a counter reaches its ``nth``, returns the
    action record ``{"action": ..., **params}`` (one spec per event —
    first match wins).  Every fired fault is appended to the logical
    event trace; ``trace()`` contains ordinals and coordinates only (no
    wall-clock times), so identical plans over identical workloads
    produce identical traces.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        # guarded-by: _lock
        self._counts = [0] * len(plan.specs)
        self._fired = [False] * len(plan.specs)
        self._events: List[Tuple[int, str, str, Tuple[Tuple[str, Any], ...]]]
        self._events = []

    def fire(self, site: str, **coords) -> Optional[Dict[str, Any]]:
        with self._lock:
            for i, spec in enumerate(self.plan.specs):
                if spec.site != site:
                    continue
                if any(coords.get(k) != v for k, v in spec.match):
                    continue
                self._counts[i] += 1
                if not self._fired[i] and self._counts[i] >= spec.nth:
                    self._fired[i] = True
                    self._events.append((
                        len(self._events), site, spec.action,
                        tuple(sorted(coords.items())),
                    ))
                    return {"action": spec.action, **dict(spec.params)}
        return None

    def trace(self) -> List[Tuple]:
        """Logical fault trace: [(ordinal, site, action, coords), ...]."""
        with self._lock:
            return list(self._events)

    def all_fired(self) -> bool:
        with self._lock:
            return all(self._fired)

    def pending(self) -> List[FaultSpec]:
        """Specs that have not fired yet (useful for bench assertions)."""
        with self._lock:
            return [s for s, f in zip(self.plan.specs, self._fired)
                    if not f]


# -- process-global hook ---------------------------------------------------
_active: Optional[FaultInjector] = None


def set_fault_injector(inj: Optional[FaultInjector]) -> None:
    """Install (or clear, with None) the process-wide injector."""
    global _active
    _active = inj


def active() -> Optional[FaultInjector]:
    """The installed injector, or None — sites check this per event."""
    return _active


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Context manager: arm ``plan`` for the block, yield its injector."""
    inj = plan.injector()
    set_fault_injector(inj)
    try:
        yield inj
    finally:
        set_fault_injector(None)


def install_from_env(environ=os.environ) -> Optional[FaultInjector]:
    """Arm the injector from ``REPRO_FAULT_PLAN`` if set (worker-side)."""
    text = environ.get(PLAN_ENV)
    if not text:
        return None
    inj = FaultPlan.from_json(text).injector()
    set_fault_injector(inj)
    return inj
