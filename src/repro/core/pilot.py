"""PilotManager / Pilot: resource acquisition (RADICAL-Pilot analogue).

A Pilot owns a pool of accelerator devices acquired once; tasks are
multiplexed onto slices of the pool without re-acquisition (the pilot
model's core idea).  Device failure marks devices dead; subsequent carves
come from survivors (elastic degradation).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import List, Optional, Sequence, Tuple

import jax

from repro.core.communicator import Communicator, build_communicator


@dataclasses.dataclass
class PilotDescription:
    num_devices: int = -1  # -1 = all available
    name: str = "pilot"


class Pilot:
    def __init__(self, uid: str, devices: Sequence):
        self.uid = uid
        self._devices = list(devices)
        self._failed: set = set()
        self._leased: dict = {}  # device index -> task uid
        self._lock = threading.Lock()
        self._listeners: list = []  # called (no args) when capacity frees/changes
        self.created_at = time.time()

    # -- capacity-change notification ----------------------------------------

    def add_capacity_listener(self, cb) -> None:
        """Register ``cb()`` to run whenever devices are released or marked
        failed.  Listeners are invoked OUTSIDE the pilot lock so they may
        take their own locks (e.g. an agent's scheduling condition)."""
        with self._lock:
            self._listeners.append(cb)

    def remove_capacity_listener(self, cb) -> None:
        with self._lock:
            if cb in self._listeners:
                self._listeners.remove(cb)

    def _notify(self) -> None:
        for cb in list(self._listeners):
            cb()

    # -- capacity ------------------------------------------------------------

    @property
    def size(self) -> int:
        return len(self._devices)

    def alive_devices(self) -> List:
        return [d for i, d in enumerate(self._devices) if i not in self._failed]

    def free_count(self) -> int:
        with self._lock:
            return sum(
                1 for i in range(len(self._devices))
                if i not in self._failed and i not in self._leased
            )

    # -- failure handling ----------------------------------------------------

    def mark_failed(self, device_ids: Sequence[int]) -> None:
        with self._lock:
            for d in device_ids:
                for i, dev in enumerate(self._devices):
                    if dev.id == d:
                        self._failed.add(i)
                        self._leased.pop(i, None)
        self._notify()

    # -- leasing -------------------------------------------------------------

    def lease(self, n: int, task_uid: str) -> Optional[List]:
        """Reserve n alive+free devices for a task (None if unavailable)."""
        with self._lock:
            free = [
                i for i in range(len(self._devices))
                if i not in self._failed and i not in self._leased
            ]
            if len(free) < n:
                return None
            take = free[:n]
            for i in take:
                self._leased[i] = task_uid
            return [self._devices[i] for i in take]

    def release(self, task_uid: str) -> int:
        """Return the lease held under ``task_uid``; returns #devices freed."""
        freed = 0
        with self._lock:
            for i in [i for i, u in self._leased.items() if u == task_uid]:
                del self._leased[i]
                freed += 1
        if freed:
            self._notify()
        return freed

    def carve(self, devices: Sequence, mesh_shape=None,
              mesh_axes: Tuple[str, ...] = ("data",)) -> Communicator:
        return build_communicator(devices, mesh_shape, mesh_axes)


class PilotManager:
    """Acquires pilots (cf. radical.pilot.PilotManager)."""

    _uid = itertools.count()

    def __init__(self):
        self.pilots: List[Pilot] = []

    def submit_pilot(self, desc: PilotDescription) -> Pilot:
        devices = jax.devices()
        n = desc.num_devices if desc.num_devices > 0 else len(devices)
        if n > len(devices):
            raise RuntimeError(f"requested {n} devices, have {len(devices)}")
        pilot = Pilot(f"{desc.name}.{next(self._uid):04d}", devices[:n])
        self.pilots.append(pilot)
        return pilot
