"""PilotManager / Pilot: resource acquisition + placement (RADICAL-Pilot
analogue).

A Pilot owns a pool of accelerator devices acquired once; tasks are
multiplexed onto slices of the pool without re-acquisition (the pilot
model's core idea).  Device failure marks devices dead; subsequent carves
come from survivors (elastic degradation).

The PilotManager is the layer above: it owns the machine's device
inventory and hands out **disjoint** pools — two pilots never share a
device, and submitting a pilot the machine cannot back raises instead of
silently aliasing (`devices[:n]` overlap was a seed bug).  It is also the
placement scheduler for the multi-pilot mode (paper Table 4 across
per-pod pools): ``place`` picks the pilot with the most effective free
capacity among those that admit a task kind and still satisfy a mesh
requirement.  Orchestration on top of ``place`` lives one layer up:
per-STAGE placement/migration in :class:`repro.core.session.Session`
(the user-facing facade), whole-pipeline placement in
:class:`repro.core.pipeline.MultiPilotScheduler`.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax

from repro.core.communicator import Communicator, build_communicator


@dataclasses.dataclass
class PilotDescription:
    num_devices: int = -1  # -1 = all devices still free in the manager
    name: str = "pilot"
    # task kinds this pilot admits; () = any kind.  Placement only puts
    # work on a pilot whose kinds cover the work's kinds (e.g. a
    # CPU-worker pod that only takes "data_engineering" stages).
    task_kinds: Tuple[str, ...] = ()
    # where this pilot's agent executes attempts: None = the Session
    # default, "in-process" = thread pool in this process, "subprocess" =
    # process-per-worker pool (repro.core.exec), "jax-distributed" = the
    # multi-host flavour.  Resolved by Session._ensure.
    transport: Optional[str] = None


class Pilot:
    def __init__(self, uid: str, devices: Sequence,
                 task_kinds: Tuple[str, ...] = ()):
        self.uid = uid
        self.task_kinds = tuple(task_kinds)
        # _devices is append-never after construction; only the index sets
        # below change, so they carry the lock discipline.
        self._devices = list(devices)
        self._failed: set = set()  # guarded-by: _lock
        self._leased: dict = {}  # guarded-by: _lock  (device index -> task uid)
        self._lock = threading.Lock()
        # called (no args) when capacity frees/changes
        self._listeners: list = []  # guarded-by: _lock
        self.created_at = time.time()

    # -- capacity-change notification ----------------------------------------

    def add_capacity_listener(self, cb) -> None:
        """Register ``cb()`` to run whenever devices are released or marked
        failed.  Listeners are invoked OUTSIDE the pilot lock so they may
        take their own locks (e.g. an agent's scheduling condition)."""
        with self._lock:
            self._listeners.append(cb)

    def remove_capacity_listener(self, cb) -> None:
        with self._lock:
            if cb in self._listeners:
                self._listeners.remove(cb)

    def _notify(self) -> None:
        with self._lock:
            listeners = list(self._listeners)
        for cb in listeners:  # outside the lock: callbacks take their own locks
            cb()

    # -- capacity ------------------------------------------------------------

    @property
    def size(self) -> int:
        return len(self._devices)

    def alive_devices(self) -> List:
        with self._lock:
            return self._alive_devices_locked()

    def _alive_devices_locked(self) -> List:
        return [d for i, d in enumerate(self._devices) if i not in self._failed]

    def alive_count(self) -> int:
        with self._lock:
            return len(self._devices) - len(self._failed)

    def free_count(self) -> int:
        with self._lock:
            return sum(
                1 for i in range(len(self._devices))
                if i not in self._failed and i not in self._leased
            )

    def admits(self, kinds: Iterable[str]) -> bool:
        """True if this pilot accepts every task kind in ``kinds``."""
        if not self.task_kinds:
            return True
        return set(kinds) <= set(self.task_kinds)

    # -- failure handling ----------------------------------------------------

    def mark_failed(self, device_ids: Sequence[int]) -> None:
        with self._lock:
            for d in device_ids:
                for i, dev in enumerate(self._devices):
                    if dev.id == d:
                        self._failed.add(i)
                        self._leased.pop(i, None)
        self._notify()

    # -- leasing -------------------------------------------------------------

    def lease(self, n: int, task_uid: str) -> Optional[List]:
        """Reserve n alive+free devices for a task (None if unavailable)."""
        with self._lock:
            free = [
                i for i in range(len(self._devices))
                if i not in self._failed and i not in self._leased
            ]
            if len(free) < n:
                return None
            take = free[:n]
            for i in take:
                self._leased[i] = task_uid
            return [self._devices[i] for i in take]

    def release(self, task_uid: str) -> int:
        """Return the lease held under ``task_uid``; returns #devices freed."""
        freed = 0
        with self._lock:
            for i in [i for i, u in self._leased.items() if u == task_uid]:
                del self._leased[i]
                freed += 1
        if freed:
            self._notify()
        return freed

    def carve(self, devices: Sequence, mesh_shape=None,
              mesh_axes: Tuple[str, ...] = ("data",)) -> Communicator:
        return build_communicator(devices, mesh_shape, mesh_axes,
                                  pilot_uid=self.uid)


class PilotManager:
    """Acquires disjoint pilots and places work on them.

    ``devices`` defaults to ``jax.devices()`` (resolved lazily so fake
    device pools can be injected in tests).  Every ``submit_pilot`` carves
    its pool out of the manager's remaining free devices; when the machine
    is exhausted the submit **raises** rather than handing out an
    overlapping slice.  ``cancel_pilot`` returns a pilot's surviving
    devices to the free pool (failed devices stay retired).
    """

    _uid = itertools.count()

    def __init__(self, devices: Optional[Sequence] = None,
                 pilot_factory=Pilot):
        self.pilots: List[Pilot] = []  # guarded-by: _lock
        self._pilot_factory = pilot_factory
        self._devices = list(devices) if devices is not None else None  # guarded-by: _lock
        self._free: Optional[List] = None  # guarded-by: _lock  (resolved with _devices)
        self._lock = threading.Lock()

    def _ensure_pool_locked(self) -> None:
        if self._devices is None:
            self._devices = list(jax.devices())
        if self._free is None:
            self._free = list(self._devices)

    @property
    def total_devices(self) -> int:
        with self._lock:
            self._ensure_pool_locked()
            return len(self._devices)

    def free_devices(self) -> int:
        with self._lock:
            self._ensure_pool_locked()
            return len(self._free)

    # -- pilot lifecycle -----------------------------------------------------

    def submit_pilot(self, desc: PilotDescription) -> Pilot:
        with self._lock:
            self._ensure_pool_locked()
            n = desc.num_devices if desc.num_devices > 0 else len(self._free)
            if n <= 0 or n > len(self._free):
                raise RuntimeError(
                    f"pilot {desc.name!r} requested {desc.num_devices} devices "
                    f"but only {len(self._free)}/{len(self._devices)} are free "
                    f"({len(self.pilots)} pilots already hold the rest)")
            take, self._free = self._free[:n], self._free[n:]
            pilot = self._pilot_factory(
                f"{desc.name}.{next(self._uid):04d}", take,
                task_kinds=desc.task_kinds)
            self.pilots.append(pilot)
            return pilot

    def submit_pilots(self, descs: Sequence[PilotDescription]) -> List[Pilot]:
        return [self.submit_pilot(d) for d in descs]

    def cancel_pilot(self, pilot: Pilot) -> int:
        """Tear a pilot down; its alive devices rejoin the free pool.
        Returns the number of devices recovered.  Refuses while any lease
        is outstanding — recycling a device another agent is still
        running on would re-create exactly the overlapping-pools bug the
        manager exists to prevent (close the pilot's agents first)."""
        with self._lock:
            if pilot not in self.pilots:
                raise ValueError(f"pilot {pilot.uid} is not managed here")
            leased = pilot.alive_count() - pilot.free_count()
            if leased:
                raise RuntimeError(
                    f"pilot {pilot.uid} still has {leased} leased device(s); "
                    "close its agent(s) before cancel_pilot")
            self.pilots.remove(pilot)
            recovered = pilot.alive_devices()
            self._free.extend(recovered)
            return len(recovered)

    # -- placement -----------------------------------------------------------

    def place(self, num_devices: int = 1, kinds: Iterable[str] = (),
              *, pilots: Optional[Sequence[Pilot]] = None,
              load: Optional[Dict[str, int]] = None,
              exclude: Sequence[Pilot] = ()) -> Optional[Pilot]:
        """Pick the pilot for a unit of work needing ``num_devices`` alive
        devices and admitting all of ``kinds``.

        Chooses by **effective free capacity**: current free devices minus
        the caller's already-assigned-but-not-yet-leased weight (``load``,
        a ``{pilot uid: device weight}`` overlay maintained by e.g.
        MultiPilotScheduler so a burst of placements spreads out instead
        of all landing on the momentarily-emptiest pilot).  Returns None
        when no pilot qualifies — the caller decides whether that is an
        error or a reason to wait.
        """
        need = max(num_devices, 1)
        if pilots is None:
            with self._lock:
                pilots = list(self.pilots)
        best, best_score = None, None
        for p in pilots:
            if p in exclude or not p.admits(kinds):
                continue
            if p.alive_count() < need:
                continue
            effective_free = p.free_count() - (load or {}).get(p.uid, 0)
            score = (effective_free, p.alive_count())
            if best_score is None or score > best_score:
                best, best_score = p, score
        return best
