# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# The user-facing entry point is the Session facade + stage-graph DSL;
# the layered runtime underneath (PilotManager -> Pilot -> RemoteAgent
# -> Transport) stays importable from its own modules.
from repro.core.session import (KindAwarePlacement, PlacementPolicy,
                                ServiceHandle, Session, StageContext,
                                StageGraph, StageSpec, stage)

__all__ = [
    "Session", "ServiceHandle", "stage", "StageContext", "StageSpec",
    "StageGraph", "PlacementPolicy", "KindAwarePlacement",
]
