"""Unified Session API: ONE facade over the whole Deep RC execution stack
(paper Fig. 2/3 — data engineering, DL training, and serving composing
over pilots), plus the composable stage-graph DSL.

Before this layer, callers juggled ``PilotManager`` / ``RemoteAgent`` /
``Pipeline`` / ``PipelineScheduler`` / ``MultiPilotScheduler`` plus raw
``fn(comm, upstream, *args)`` callables, and placement was per *pipeline*
only — a DAG that wanted its data-engineering stage on the data pod and
its train stage on the DL pod had to be split into two pipelines with a
blocking handoff.  The Session owns the :class:`PilotManager`, lazily
materializes pods (shared / ``pods=N`` / kind-specialised descriptions)
with one :class:`RemoteAgent` per pilot, and resolves every stage's agent
individually through a :class:`PlacementPolicy` — so one graph's stages
span pilots with real dependency edges crossing agents, and a degraded
pod migrates only the affected *stage*'s placement.

DSL::

    from repro.core import Session, stage

    @stage(kind="data_engineering")
    def preprocess(ctx):
        return make_table()

    @stage(kind="train", checkpoint="results/ckpt/run0")
    def train(ctx):                       # ctx.resume_step on retries
        return fit(ctx.upstream["preprocess"])

    @stage(kind="inference")
    def report(ctx):
        return evaluate(ctx.upstream["train"])

    with Session(pods=2) as session:      # 2 disjoint pods, lazy pilots
        out = session.run(preprocess >> train >> report)

``>>`` chains (every sink of the left feeds every source of the right),
``|`` runs in parallel, and ``.after(...)`` adds explicit edges; graphs
compile down to :class:`repro.core.pipeline.Pipeline`, so the
event-driven readiness model (stages submitted the moment their deps
complete) is unchanged.  ``session.start`` is the non-blocking variant,
``session.serve`` runs a service stage and returns its control handle,
and ``close()`` / context-manager exit recycles every agent AND pilot on
every exit path.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import (Any, Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Tuple, Union)

from repro.core.agent import RemoteAgent
from repro.core.pilot import Pilot, PilotDescription, PilotManager
from repro.core.pipeline import Pipeline, Stage, aggregate_metrics
from repro.core.task import ServiceControl, Task

__all__ = [
    "Session", "ServiceHandle", "StageContext", "StageSpec", "StageGraph",
    "stage", "PlacementPolicy", "KindAwarePlacement",
]


# ---------------------------------------------------------------------------
# Stage DSL
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StageContext:
    """What a DSL stage body receives — replaces the positional
    ``fn(comm, upstream, *args, **kw)`` contract of raw ``Stage`` fns.

    * ``comm`` — the communicator carved for this stage (mesh slice);
    * ``upstream`` — ``{dep stage name: its result}``;
    * ``resume_step`` — last completed checkpoint step, set by the agent
      on retried attempts of a ``checkpoint=...`` stage (else None);
    * ``control`` / ``resume_state`` — the :class:`ServiceControl` handle
      and checkpointed state of a ``service=True`` stage (else None).
    """

    comm: Any
    upstream: Mapping[str, Any]
    resume_step: Optional[int] = None
    control: Optional[ServiceControl] = None
    resume_state: Any = None

    def dep(self, name: Optional[str] = None) -> Any:
        """Result of the named dependency (or the single dependency)."""
        if name is None:
            if len(self.upstream) != 1:
                raise KeyError(
                    f"ctx.dep() needs a name with {len(self.upstream)} deps")
            return next(iter(self.upstream.values()))
        return self.upstream[name]


@dataclasses.dataclass(frozen=True, eq=False)
class StageSpec:
    """A typed, composable stage description produced by :func:`stage`.

    Immutable — every modifier (``after``/``named``/``options``/``bind``)
    returns a clone, so one decorated function can appear in many graphs
    with different wiring.  Composition operators lift the spec into a
    :class:`StageGraph`:  ``a >> b`` (b depends on a), ``a | b``
    (parallel).  Calling the spec invokes the raw body (handy in unit
    tests): ``spec(ctx)``.
    """

    fn: Callable[..., Any]
    name: str
    kind: str = "generic"
    num_devices: int = 1
    mesh_axes: Tuple[str, ...] = ("data",)
    mesh_shape: Optional[Tuple[int, ...]] = None
    deps: Tuple[str, ...] = ()
    priority: int = 0
    max_retries: int = 2
    checkpoint: Optional[str] = None
    service: bool = False
    bound_args: Tuple = ()
    bound_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- modifiers (all return clones) --------------------------------------

    def _clone(self, **over) -> "StageSpec":
        return dataclasses.replace(self, **over)

    def after(self, *deps: Union[str, "StageSpec"]) -> "StageSpec":
        """Add explicit dependency edges (by spec or by stage name)."""
        names = tuple(d.name if isinstance(d, StageSpec) else d for d in deps)
        merged = self.deps + tuple(n for n in names if n not in self.deps)
        return self._clone(deps=merged)

    def named(self, name: str) -> "StageSpec":
        """Rename — required to use one decorated fn twice in a graph."""
        return self._clone(name=name)

    def options(self, **over) -> "StageSpec":
        """Override any spec field (kind, num_devices, checkpoint, ...)."""
        return self._clone(**over)

    def bind(self, *args, **kwargs) -> "StageSpec":
        """Partially apply extra arguments: the body runs as
        ``fn(ctx, *args, **kwargs)``."""
        return self._clone(bound_args=self.bound_args + args,
                           bound_kwargs={**self.bound_kwargs, **kwargs})

    # -- composition ---------------------------------------------------------

    def __rshift__(self, other) -> "StageGraph":
        return StageGraph([self]) >> other

    def __rrshift__(self, other) -> "StageGraph":
        return StageGraph._lift(other) >> self

    def __or__(self, other) -> "StageGraph":
        return StageGraph([self]) | other

    def __ror__(self, other) -> "StageGraph":
        return StageGraph._lift(other) | self

    # -- execution -----------------------------------------------------------

    def __call__(self, ctx: StageContext, *args, **kwargs) -> Any:
        return self.fn(ctx, *self.bound_args, *args,
                       **{**self.bound_kwargs, **kwargs})

    # -- pickling ------------------------------------------------------------
    # ``@stage`` rebinds the module attribute from the raw fn to this
    # spec, so the fn can no longer pickle by reference (``module.name``
    # resolves to the spec, not the function).  For the subprocess
    # transport the fn travels as a _SpecFnRef instead and is recovered
    # *through* the module-level spec on the worker side.

    def __getstate__(self):
        state = {f.name: getattr(self, f.name)
                 for f in dataclasses.fields(self)}
        fn = state["fn"]
        mod = getattr(fn, "__module__", None)
        qn = getattr(fn, "__qualname__", None)
        if mod == "__main__":
            # a ``python -m pkg.mod`` entry module: the worker's __main__
            # is the worker daemon, so reference the importable name
            from repro.core.exec.protocol import main_module_name
            mod = main_module_name() or mod
        if mod is not None and qn is not None and "<locals>" not in qn:
            try:
                owner = _resolve_qualname(mod, qn)
            except (ImportError, AttributeError):
                owner = None
            # identity for same-module resolution; qualname match for the
            # __main__ remap (the re-imported module re-decorates, so its
            # spec wraps an equal-but-distinct function object)
            if isinstance(owner, StageSpec) and (
                    owner.fn is fn
                    or getattr(owner.fn, "__qualname__", None) == qn):
                state["fn"] = _SpecFnRef(mod, qn)
        return state

    def __setstate__(self, state):
        fn = state.get("fn")
        if isinstance(fn, _SpecFnRef):
            state["fn"] = fn.resolve()
        for k, v in state.items():
            object.__setattr__(self, k, v)

    def to_stage(self) -> Stage:
        """Compile to the runtime :class:`Stage` — the adapter builds a
        :class:`StageContext` from the raw ``(comm, upstream, **kw)``
        contract, so the agent-side plumbing (checkpoint resume, service
        control) is untouched."""
        runner = _StageRunner(self)
        return Stage(
            name=self.name, fn=runner, kind=self.kind,
            num_devices=self.num_devices, mesh_axes=self.mesh_axes,
            mesh_shape=self.mesh_shape, deps=self.deps,
            priority=self.priority, max_retries=self.max_retries,
            checkpoint_dir=self.checkpoint, service=self.service)


def _resolve_qualname(module: str, qualname: str) -> Any:
    import importlib
    obj: Any = importlib.import_module(module)
    for part in qualname.split("."):
        obj = getattr(obj, part)
    return obj


class _SpecFnRef:
    """Pickle stand-in for a ``@stage``-decorated function: resolves the
    module attribute (the StageSpec) and returns its raw fn."""

    __slots__ = ("module", "qualname")

    def __init__(self, module: str, qualname: str):
        self.module = module
        self.qualname = qualname

    def resolve(self) -> Callable:
        obj = _resolve_qualname(self.module, self.qualname)
        return obj.fn if isinstance(obj, StageSpec) else obj


class _StageRunner:
    """Picklable adapter from the raw ``(comm, upstream, **kw)`` stage
    contract to :class:`StageContext`.  A module-level class instead of a
    closure so DSL stages cross the subprocess transport's pickle
    boundary whenever the decorated fn and its bound args do."""

    __slots__ = ("spec",)

    def __init__(self, spec: StageSpec):
        self.spec = spec

    @property
    def __name__(self) -> str:
        return f"stage:{self.spec.name}"


    def __call__(self, comm, upstream, **kw):
        ctx = StageContext(
            comm=comm, upstream=upstream,
            resume_step=kw.pop("resume_step", None),
            control=kw.pop("control", None),
            resume_state=kw.pop("resume_state", None))
        return self.spec.fn(ctx, *self.spec.bound_args,
                            **self.spec.bound_kwargs)


def stage(fn: Optional[Callable] = None, *, name: Optional[str] = None,
          kind: str = "generic", num_devices: int = 1,
          mesh_axes: Tuple[str, ...] = ("data",),
          mesh_shape: Optional[Tuple[int, ...]] = None, priority: int = 0,
          max_retries: int = 2, checkpoint: Optional[str] = None,
          service: bool = False):
    """Decorator producing a :class:`StageSpec`.

    ``@stage`` bare or ``@stage(kind="train", num_devices=4,
    checkpoint=dir)``; the decorated function receives a
    :class:`StageContext`.  ``checkpoint`` opts the stage into the
    agent's checkpoint-aware retry (``ctx.resume_step``); ``service=True``
    marks a long-running stage driven through ``ctx.control``.
    """
    def wrap(f: Callable) -> StageSpec:
        return StageSpec(
            fn=f, name=name or f.__name__, kind=kind,
            num_devices=num_devices, mesh_axes=tuple(mesh_axes),
            mesh_shape=mesh_shape, priority=priority,
            max_retries=max_retries, checkpoint=checkpoint, service=service)

    return wrap(fn) if fn is not None else wrap


class StageGraph:
    """An immutable DAG of :class:`StageSpec`\\ s built by composition.

    * ``a >> b`` — every *sink* of ``a`` becomes a dependency of every
      *source* of ``b`` (sinks/sources derived from the dep structure;
      service stages are excluded from sinks — they never complete);
    * ``a | b`` — disjoint union (parallel);
    * ``StageGraph([s1, s2.after(s1), ...])`` — explicit edges.

    ``compile(name)`` lowers to a runtime :class:`Pipeline`.
    """

    def __init__(self, specs: Iterable[Union[StageSpec, "StageGraph"]] = ()):
        self._specs: Dict[str, StageSpec] = {}
        for item in specs:
            for s in ([item] if isinstance(item, StageSpec) else list(item)):
                if s.name in self._specs:
                    raise ValueError(
                        f"duplicate stage name {s.name!r} in graph "
                        "(use .named() to reuse a decorated fn)")
                self._specs[s.name] = s

    # -- structure -----------------------------------------------------------

    def __iter__(self):
        return iter(self._specs.values())

    def __len__(self) -> int:
        return len(self._specs)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(self._specs)

    def sources(self) -> Tuple[str, ...]:
        """Stages with no in-graph dependencies."""
        return tuple(n for n, s in self._specs.items()
                     if not (set(s.deps) & set(self._specs)))

    def sinks(self) -> Tuple[str, ...]:
        """Non-service stages nothing else depends on (the join points a
        chained graph hangs its edges off)."""
        depended = {d for s in self._specs.values() for d in s.deps}
        return tuple(n for n, s in self._specs.items()
                     if n not in depended and not s.service)

    @classmethod
    def _lift(cls, x) -> "StageGraph":
        if isinstance(x, StageGraph):
            return x
        if isinstance(x, StageSpec):
            return cls([x])
        if isinstance(x, (list, tuple)):
            return cls(x)
        raise TypeError(f"cannot compose {type(x).__name__} into a StageGraph")

    # -- composition ---------------------------------------------------------

    def __rshift__(self, other) -> "StageGraph":
        other = StageGraph._lift(other)
        joins = self.sinks()
        if not joins and len(self):
            raise ValueError(
                "left side of >> has no completing (non-service) sink "
                "stage to hang the dependency edge on")
        out = StageGraph()
        out._specs = dict(self._specs)
        for name, s in other._specs.items():
            if name in out._specs:
                raise ValueError(f"duplicate stage name {name!r} across >>")
            if name in other.sources():
                s = s.after(*joins)
            out._specs[name] = s
        return out

    def __or__(self, other) -> "StageGraph":
        other = StageGraph._lift(other)
        return StageGraph([self, other])

    def __ror__(self, other) -> "StageGraph":
        return StageGraph._lift(other) | self

    # -- lowering ------------------------------------------------------------

    def compile(self, name: str, *, quota: Optional[int] = None,
                placement: Optional[Callable[[Stage],
                                             Optional[RemoteAgent]]] = None,
                ) -> Pipeline:
        return Pipeline(name, [s.to_stage() for s in self._specs.values()],
                        quota=quota, placement=placement)


# ---------------------------------------------------------------------------
# Placement policy
# ---------------------------------------------------------------------------


class PlacementPolicy:
    """Resolves which pilot hosts a single stage.

    Called once per stage the moment the stage becomes ready (deps done),
    NOT once per pipeline — this is what lets one DAG span pods and what
    makes migration per-stage: a stage re-resolves at submit time, so a
    pod that degraded since planning is simply no longer chosen.
    """

    def place_stage(self, stg: Stage, *, manager: PilotManager,
                    pilots: Sequence[Pilot],
                    load: Optional[Dict[str, int]] = None) -> Optional[Pilot]:
        raise NotImplementedError


class KindAwarePlacement(PlacementPolicy):
    """Default policy: most effective free capacity among pilots that
    admit the stage's kind and still have ``num_devices`` alive devices
    (reuses :meth:`PilotManager.place`; ``load`` is the session's
    promised-but-not-yet-leased overlay so placement bursts spread)."""

    def place_stage(self, stg: Stage, *, manager: PilotManager,
                    pilots: Sequence[Pilot],
                    load: Optional[Dict[str, int]] = None) -> Optional[Pilot]:
        return manager.place(num_devices=stg.num_devices, kinds={stg.kind},
                             pilots=pilots, load=load)


# ---------------------------------------------------------------------------
# Session
# ---------------------------------------------------------------------------


class ServiceHandle:
    """Returned by :meth:`Session.serve` — the caller-side face of one
    long-running service stage."""

    def __init__(self, pipeline: Pipeline, stage_name: str):
        self.pipeline = pipeline
        self.stage_name = stage_name

    @property
    def control(self) -> ServiceControl:
        return self.pipeline.control(self.stage_name)

    @property
    def task(self) -> Optional[Task]:
        return self.pipeline.tasks.get(self.stage_name)

    @property
    def result(self) -> Any:
        return self.pipeline.results.get(self.stage_name)

    def submit_request(self, request: Any) -> Any:
        return self.control.submit_request(request)

    def stop(self, drain: bool = True, timeout: Optional[float] = None) -> bool:
        """Drain (default) or hard-stop the service and wait for its task
        to finalize; False on timeout."""
        return self.pipeline.stop_services(drain=drain, timeout=timeout)


GraphLike = Union[StageGraph, StageSpec, Pipeline]


class Session:
    """One object that owns pilots, agents, and per-stage placement.

    * ``Session()`` — one shared pod over every device (lazy);
    * ``Session(pods=N)`` — N disjoint even pods;
    * ``Session(pods=[PilotDescription(...), ...])`` — explicit pods,
      e.g. kind-specialised (a ``task_kinds=("data_engineering",)`` pod
      beside a ``("train", "inference")`` pod);
    * ``Session(manager=pm)`` — adopt an existing manager; pilots it
      already holds are reused (and NOT canceled by ``close``).

    Pilots and agents materialize lazily on the first ``run`` / ``start``
    / ``serve``.  Every started pipeline resolves each stage's agent
    through ``placement`` (default :class:`KindAwarePlacement`), so a
    preprocess -> train DAG lands its stages on different pods with the
    dependency edge crossing agents; results flow through the pipeline's
    completion callbacks exactly as before.  ``close()`` (also run by the
    context manager, on every exit path) stops services, closes agents,
    and cancels every session-owned pilot so devices are recycled.
    """

    _uid = itertools.count()

    def __init__(self, *, manager: Optional[PilotManager] = None,
                 devices: Optional[Sequence] = None,
                 pods: Union[None, int, Sequence[PilotDescription]] = None,
                 placement: Optional[PlacementPolicy] = None,
                 max_workers_per_pilot: Optional[int] = None,
                 transport=None,
                 transport_options: Optional[Dict] = None):
        if manager is not None and devices is not None:
            raise ValueError("pass manager= or devices=, not both")
        self.manager = manager if manager is not None \
            else PilotManager(devices=devices)
        self.placement = placement or KindAwarePlacement()
        self._pods_spec = pods
        self._max_workers = max_workers_per_pilot
        # transport may be a Transport instance (shared, caller-owned) or
        # a spec string ("in-process" / "subprocess" / "jax-distributed")
        # resolved per pilot; PilotDescription(transport=...) overrides it
        # per pod.  transport_options are kwargs for spec-built transports
        # (e.g. worker_devices= for subprocess pools).
        self._transport = transport
        self._transport_options = dict(transport_options or {})
        self._owned_transports: List = []  # guarded-by: _lock
        self._lock = threading.Lock()
        self._pilots: List[Pilot] = []  # guarded-by: _lock
        self._owned_pilots: List[Pilot] = []  # guarded-by: _lock
        self._agents: Dict[str, RemoteAgent] = {}  # guarded-by: _lock  (pilot uid -> agent)
        # promised-not-yet-leased devices
        self._assigned: Dict[str, int] = {}  # guarded-by: _lock
        self._stage_pilot: Dict[Tuple[str, str], str] = {}  # guarded-by: _lock
        self._pipelines: List[Pipeline] = []  # guarded-by: _lock
        self._closed = False  # guarded-by: _lock
        # appended only by the single thread that wins the _closed
        # test-and-set in close(), so it needs no lock of its own
        self.close_errors: List[str] = []

    # -- lifecycle -----------------------------------------------------------

    @property
    def pilots(self) -> List[Pilot]:
        with self._lock:
            return list(self._pilots)

    def agent_for(self, pilot: Pilot) -> RemoteAgent:
        with self._lock:
            return self._agents[pilot.uid]

    def _ensure(self) -> None:
        with self._lock:
            if self._closed:
                raise RuntimeError("Session is closed")
            if self._agents:
                return
            adopted = list(self.manager.pilots)
            desc_by_pilot: Dict[str, PilotDescription] = {}
            if adopted and self._pods_spec is None:
                pilots, owned = adopted, []
            else:
                descs = self._pod_descriptions()
                pilots = self.manager.submit_pilots(descs)
                owned = list(pilots)
                desc_by_pilot = {p.uid: d for p, d in zip(pilots, descs)}
            agents = {}
            for p in pilots:
                mw = self._max_workers if self._max_workers is not None \
                    else max(2, p.size)
                desc = desc_by_pilot.get(p.uid)
                spec = desc.transport if desc is not None and \
                    desc.transport is not None else self._transport
                tr, session_owned = self._resolve_transport(spec, mw)
                if session_owned:
                    self._owned_transports.append(tr)
                agents[p.uid] = RemoteAgent(p, max_workers=mw, transport=tr)
            self._pilots = list(pilots)
            self._owned_pilots = owned
            self._agents = agents
            self._assigned = {p.uid: 0 for p in pilots}

    def _resolve_transport(self, spec, max_workers: int):
        """Resolve a transport spec for one pilot's agent.  Returns
        ``(transport_or_None, session_owned)``: spec strings build a
        transport the session owns (and shuts down in close); a Transport
        instance passes through caller-owned; None keeps the agent's
        default in-process pool."""
        if spec is None:
            return None, False
        if not isinstance(spec, str):
            return spec, False  # a live Transport instance, caller-owned
        if spec == "in-process":
            return None, False  # the agent's own default thread pool
        if spec in ("subprocess", "jax-distributed"):
            from repro.core.exec import (JaxDistributedTransport,
                                         SubprocessTransport)
            opts = dict(self._transport_options)
            # subprocess workers each carry a JAX runtime: default the
            # pool small instead of one process per device slot
            opts.setdefault("max_workers", min(max_workers, 2))
            cls = (SubprocessTransport if spec == "subprocess"
                   else JaxDistributedTransport)
            return cls(**opts), True
        raise ValueError(
            f"unknown transport spec {spec!r}: expected 'in-process', "
            "'subprocess', 'jax-distributed', or a Transport instance")

    def _pod_descriptions(self) -> List[PilotDescription]:
        pods = self._pods_spec
        if pods is None:
            return [PilotDescription(name="pod")]
        if isinstance(pods, int):
            total = self.manager.free_devices()
            n = max(1, min(pods, total))
            per, extra = divmod(total, n)
            return [PilotDescription(num_devices=per + (1 if i < extra else 0),
                                     name=f"pod{i}") for i in range(n)]
        return list(pods)

    def close(self, timeout: Optional[float] = None) -> None:
        """Stop services, close every agent, and cancel every owned pilot
        (devices recycled into the manager's free pool).  Idempotent;
        failures are collected in ``close_errors`` instead of masking the
        exception that triggered the close."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            pipelines = list(self._pipelines)
            agents = list(self._agents.values())
            owned = list(self._owned_pilots)
            owned_transports = list(self._owned_transports)
        for p in pipelines:
            for ctl in p.service_controls.values():
                ctl.stop()
        for a in agents:
            try:
                a.close(timeout)
            except Exception as e:  # noqa: BLE001 — keep closing the rest
                self.close_errors.append(f"agent {a.pilot.uid}: {e}")
        for tr in owned_transports:
            try:
                tr.shutdown(wait=timeout is None or timeout > 0)
            except Exception as e:  # noqa: BLE001 — keep closing the rest
                self.close_errors.append(f"transport {tr.name}: {e}")
        for pilot in owned:
            try:
                self.manager.cancel_pilot(pilot)
            except (RuntimeError, ValueError) as e:
                self.close_errors.append(f"pilot {pilot.uid}: {e}")

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- graph lowering + placement wiring ------------------------------------

    def _as_pipeline(self, graph: GraphLike, name: Optional[str],
                     quota: Optional[int]) -> Pipeline:
        if isinstance(graph, Pipeline):
            if name is not None and name != graph.name:
                raise ValueError(
                    f"pipeline already named {graph.name!r}; drop name=")
            if quota is not None:
                graph.quota = quota
            return graph
        if isinstance(graph, StageSpec):
            graph = StageGraph([graph])
        if not isinstance(graph, StageGraph):
            raise TypeError(
                f"expected StageGraph/StageSpec/Pipeline, got "
                f"{type(graph).__name__}")
        return graph.compile(name or f"session-pipe{next(self._uid)}",
                             quota=quota)

    def _prepare(self, pipe: Pipeline) -> bool:
        """Wire per-stage placement into a pipeline.  Returns False (after
        aborting the pipeline) when some stage could not run on ANY pod —
        kind admitted nowhere or wider than every pool."""
        with self._lock:
            pilots = list(self._pilots)
        for s in pipe.stages:
            if not any(p.admits({s.kind}) and p.alive_count() >= s.num_devices
                       for p in pilots):
                pipe.abort(
                    f"unplaceable: no pilot admits kind={s.kind!r} with >= "
                    f"{s.num_devices} alive devices (stage {s.name})")
                return False
        # the plan is advisory: resolution re-runs at submit time, and a
        # divergence caused by a degraded pod is recorded as a per-stage
        # migration (only the affected stage moves — in-flight siblings
        # and already-completed stages are untouched)
        plan: Dict[str, str] = {}
        by_uid = {p.uid: p for p in pilots}
        for s in pipe.stages:
            planned = self.placement.place_stage(
                s, manager=self.manager, pilots=pilots)
            if planned is not None:
                plan[s.name] = planned.uid
        # quota semantics: the device cap is enforced per agent, so a
        # quota'd pipeline whose stages spread over K pods could hold
        # quota*K devices.  Keep quota'd pipelines STICKY to their first
        # pod whenever it can host the stage — the cap then stays
        # pipeline-wide; only a kind/degradation mismatch forces a second
        # pod (where the cap applies per pod, documented on Pipeline).
        home: Dict[str, str] = {}

        def resolve(stg: Stage) -> Optional[RemoteAgent]:
            with self._lock:
                if self._closed:
                    return None
                load = dict(self._assigned)
            pilot = None
            if pipe.quota is not None and home.get("uid") is not None:
                hp = by_uid.get(home["uid"])
                if (hp is not None and hp.admits({stg.kind})
                        and hp.alive_count() >= stg.num_devices):
                    pilot = hp
            if pilot is None:
                pilot = self.placement.place_stage(
                    stg, manager=self.manager, pilots=pilots, load=load)
            if pilot is None:
                return None
            if pipe.quota is not None:
                home.setdefault("uid", pilot.uid)
            planned_uid = plan.get(stg.name)
            if planned_uid is not None and planned_uid != pilot.uid:
                planned_pilot = by_uid.get(planned_uid)
                if (planned_pilot is None
                        or planned_pilot.alive_count() < stg.num_devices
                        or not planned_pilot.admits({stg.kind})):
                    pipe.migrations.append({
                        "t": time.time(), "stage": stg.name,
                        "from": planned_uid, "to": pilot.uid,
                        "reason": f"pilot {planned_uid} degraded below "
                                  f"{stg.num_devices} alive devices",
                    })
            with self._lock:
                self._assigned[pilot.uid] = (
                    self._assigned.get(pilot.uid, 0) + stg.num_devices)
                self._stage_pilot[(pipe.name, stg.name)] = pilot.uid
                return self._agents[pilot.uid]

        def release(p: Pipeline, stg: Stage, task: Task) -> None:
            with self._lock:
                uid = self._stage_pilot.pop((p.name, stg.name), None)
                if uid is not None:
                    self._assigned[uid] = (
                        self._assigned.get(uid, 0) - stg.num_devices)

        pipe.placement = resolve
        pipe.add_stage_observer(release)
        return True

    # -- execution -----------------------------------------------------------

    def start(self, graph: GraphLike, *, name: Optional[str] = None,
              quota: Optional[int] = None,
              on_finish: Optional[Callable[[Pipeline], None]] = None,
              ) -> Pipeline:
        """Non-blocking: compile, place, and start the graph; returns the
        live :class:`Pipeline` handle (``wait()`` / ``results`` /
        ``tasks`` / ``stage_placements()``)."""
        self._ensure()
        pipe = self._as_pipeline(graph, name, quota)
        with self._lock:
            self._pipelines.append(pipe)
        if self._prepare(pipe):
            pipe.start(None, on_finish=on_finish)
        elif on_finish is not None:
            on_finish(pipe)
        return pipe

    def run(self, graph: GraphLike, *, name: Optional[str] = None,
            quota: Optional[int] = None,
            timeout: Optional[float] = None) -> Dict[str, Any]:
        """Blocking: run the graph to completion; raises on stage failure;
        returns ``{stage name: result}``."""
        pipe = self.start(graph, name=name, quota=quota)
        if not pipe.wait(timeout):
            raise TimeoutError(
                f"pipeline {pipe.name} did not finish within {timeout}s")
        if pipe.error is not None:
            raise RuntimeError(f"pipeline {pipe.name} {pipe.error}")
        return pipe.results

    def run_all(self, graphs: Sequence[GraphLike], *,
                quota: Optional[int] = None,
                timeout: Optional[float] = None) -> Dict[str, Dict[str, Any]]:
        """Run N graphs/pipelines concurrently (the Table-4 batch mode).

        Per-pipeline fault isolation: failures land in that pipeline's
        result dict (``_error`` / ``_failed_stage``), never raise.
        ``_meta`` carries the Table-2/4 decomposition plus the per-STAGE
        placement map, migrations, per-agent group peaks, and quota
        violations."""
        t0 = time.time()
        pipes = [self.start(g, quota=quota) for g in graphs]
        deadline = None if timeout is None else t0 + timeout
        for p in pipes:
            remaining = None if deadline is None \
                else max(0.0, deadline - time.time())
            if not p.wait(remaining):
                raise TimeoutError(
                    f"pipeline {p.name} did not finish within {timeout}s")
        wall = time.time() - t0
        out: Dict[str, Dict[str, Any]] = {p.name: p.result_dict()
                                          for p in pipes}
        meta = aggregate_metrics(pipes, wall)
        meta["pilots"] = [p.uid for p in self.pilots]
        meta["placement"] = {p.name: p.stage_placements() for p in pipes}
        meta["migrations"] = [dict(m, pipeline=p.name)
                              for p in pipes for m in p.migrations]
        with self._lock:
            agents = dict(self._agents)
        meta["group_peaks"] = {uid: a.group_peaks()
                               for uid, a in agents.items()}
        meta["quota_violations"] = {
            uid: v for uid, a in agents.items() if (v := a.quota_violations())}
        out["_meta"] = meta
        return out

    def serve(self, graph: GraphLike, *, name: Optional[str] = None,
              quota: Optional[int] = None) -> ServiceHandle:
        """Start a graph containing exactly one ``service=True`` stage and
        return its :class:`ServiceHandle` (submit_request / stop).  The
        service holds its lease until stopped/drained; ``close()`` stops
        it on every exit path."""
        pipe = self._as_pipeline(graph, name, quota)
        services = [s.name for s in pipe.stages if s.service]
        if len(services) != 1:
            # validated BEFORE start: an invalid graph must not execute
            # (or leave an unreachable service holding its lease)
            raise ValueError(
                f"serve() expects exactly one service stage, got {services} "
                f"in pipeline {pipe.name}")
        self.start(pipe)
        return ServiceHandle(pipe, services[0])
