"""Task transports: where a leased task attempt actually executes.

The execution stack is layered ``pipeline -> PilotManager -> Pilot ->
Transport``: the PilotManager places pipelines on pilots, the pilot's
RemoteAgent decides *when* a task runs (condition-variable dispatcher,
quotas, retries, speculation), and the Transport decides *where* the
attempt's body runs.  The dispatcher stays the single master: a transport
never schedules, it only executes what the dispatcher hands it and
reports completion through the returned Future.

``InProcessTransport`` is the default (a thread pool in the agent's
process — the right answer for a single-host jax device pool, where every
worker shares one jax runtime).  ``submit`` takes a callable and returns
a ``concurrent.futures.Future``, and ``capacity`` bounds how many
attempts the dispatcher keeps in flight.

The cross-process implementations live in :mod:`repro.core.exec`:
``SubprocessTransport`` runs a pool of worker daemon processes (isolated
JAX runtimes, heartbeat fault detection), and ``JaxDistributedTransport``
is its multi-host flavour carrying ``jax.distributed.initialize``
coordinates to the workers.  Both are re-exported here lazily; they
additionally require picklable task functions (``remote = True``), a
contract enforced at submit time with a clear ``TypeError``.
"""
from __future__ import annotations

import abc
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Optional


class Transport(abc.ABC):
    """Executes task attempts on behalf of a RemoteAgent dispatcher."""

    name: str = "abstract"
    #: max attempts the transport can run concurrently (None = unbounded);
    #: the agent clamps its in-flight window to this.
    capacity: Optional[int] = None
    #: True when submit crosses a process boundary.  The agent then ships
    #: a picklable module-level task body (repro.core.exec.remote) instead
    #: of its bound in-process worker, and enforces the picklable-task-fn
    #: contract at enqueue time.
    remote: bool = False

    @abc.abstractmethod
    def submit(self, fn: Callable, *args) -> Future:
        """Run ``fn(*args)`` somewhere; resolve the Future when it returns.
        Must never raise synchronously for an execution error — errors
        travel through the Future (the agent's isolation boundary is
        inside ``fn`` itself)."""

    @abc.abstractmethod
    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting work and (optionally) drain in-flight attempts."""


class InProcessTransport(Transport):
    """Thread-pool execution inside the agent's process (single-host)."""

    name = "in-process"

    def __init__(self, max_workers: int = 4,
                 thread_name_prefix: str = "rc-worker"):
        self.capacity = max_workers
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix=thread_name_prefix)

    def submit(self, fn: Callable, *args) -> Future:
        return self._pool.submit(fn, *args)

    def shutdown(self, wait: bool = True) -> None:
        self._pool.shutdown(wait=wait)


def __getattr__(name: str):
    # Lazy re-exports of the cross-process implementations: the exec
    # package imports Transport from here, so a module-level import the
    # other way would be a cycle.  ``from repro.core.transport import
    # SubprocessTransport`` (and the retired stub's old import path for
    # JaxDistributedTransport) keep working.
    if name in ("SubprocessTransport", "JaxDistributedTransport",
                "WorkerCrashed", "RemoteTaskError"):
        from repro.core.exec import transport as _exec_transport
        return getattr(_exec_transport, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
