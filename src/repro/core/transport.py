"""Task transports: where a leased task attempt actually executes.

The execution stack is layered ``pipeline -> PilotManager -> Pilot ->
Transport``: the PilotManager places pipelines on pilots, the pilot's
RemoteAgent decides *when* a task runs (condition-variable dispatcher,
quotas, retries, speculation), and the Transport decides *where* the
attempt's body runs.  The dispatcher stays the single master: a transport
never schedules, it only executes what the dispatcher hands it and
reports completion through the returned Future.

``InProcessTransport`` is the default (a thread pool in the agent's
process — the right answer for a single-host jax device pool, where every
worker shares one jax runtime).  The interface is deliberately shaped so
a cross-node transport can slot in later: ``submit`` takes a callable and
returns a ``concurrent.futures.Future``, and ``capacity`` bounds how many
attempts the dispatcher keeps in flight.  A subprocess / jax-distributed
transport must additionally require picklable task functions; that
constraint lives here, not in the agent.
"""
from __future__ import annotations

import abc
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Optional


class Transport(abc.ABC):
    """Executes task attempts on behalf of a RemoteAgent dispatcher."""

    name: str = "abstract"
    #: max attempts the transport can run concurrently (None = unbounded);
    #: the agent clamps its in-flight window to this.
    capacity: Optional[int] = None

    @abc.abstractmethod
    def submit(self, fn: Callable, *args) -> Future:
        """Run ``fn(*args)`` somewhere; resolve the Future when it returns.
        Must never raise synchronously for an execution error — errors
        travel through the Future (the agent's isolation boundary is
        inside ``fn`` itself)."""

    @abc.abstractmethod
    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting work and (optionally) drain in-flight attempts."""


class InProcessTransport(Transport):
    """Thread-pool execution inside the agent's process (single-host)."""

    name = "in-process"

    def __init__(self, max_workers: int = 4,
                 thread_name_prefix: str = "rc-worker"):
        self.capacity = max_workers
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix=thread_name_prefix)

    def submit(self, fn: Callable, *args) -> Future:
        return self._pool.submit(fn, *args)

    def shutdown(self, wait: bool = True) -> None:
        self._pool.shutdown(wait=wait)


class JaxDistributedTransport(Transport):
    """Placeholder for cross-node dispatch (one jax-distributed worker per
    remote host).  Not implemented yet — the container image has no
    multi-host fabric to run it against; the class exists so the shape of
    the contract (picklable fns, per-worker jax.distributed.initialize)
    is pinned down where it belongs."""

    name = "jax-distributed"

    def __init__(self, *a, **kw):
        raise NotImplementedError(
            "cross-node transport is not available in this build; use "
            "InProcessTransport (see ROADMAP: cross-node dispatch)")

    def submit(self, fn: Callable, *args) -> Future:  # pragma: no cover
        raise NotImplementedError(
            "JaxDistributedTransport.submit: cross-node dispatch needs a "
            "picklable task fn shipped to a remote worker that has run "
            "jax.distributed.initialize(coordinator, num_processes, "
            "process_id) — the single-process thread-pool contract of "
            "InProcessTransport does not transfer; see ROADMAP "
            "'cross-node dispatch'")

    def shutdown(self, wait: bool = True) -> None:  # pragma: no cover
        raise NotImplementedError(
            "JaxDistributedTransport.shutdown: would need to drain remote "
            "workers and tear down the jax.distributed coordinator; no "
            "multi-host fabric exists in this build (see ROADMAP "
            "'cross-node dispatch')")
