"""Task model: the RADICAL-Pilot ``TaskDescription`` analogue.

A task is a Python callable plus a resource request (device count / mesh
shape).  The RemoteAgent carves a Communicator (mesh slice) matching the
request and calls ``fn(comm, *args)``.  Tasks carry retry/straggler policy
— the paper's fault-isolation claim (§2.3) is enforced at this boundary:
a task failure never propagates outside its Task record.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import threading
import time
from typing import (TYPE_CHECKING, Any, Callable, Deque, Dict, List,
                    Optional, Tuple)

if TYPE_CHECKING:  # annotation only — keeps this module import-light
    from repro.core.resilience.policy import FailurePolicy


class TaskState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    CANCELED = "canceled"
    # a service task that yielded its devices to higher-priority work; the
    # agent re-queues it (with its checkpointed state) — transient, like a
    # FAILED task awaiting retry, and never consumes retry budget
    PREEMPTED = "preempted"


class ServicePreempted(Exception):
    """Raised by a service task body to yield its devices.

    ``state`` is the service's checkpoint (whatever its ``resume_state``
    contract accepts); the agent stashes it on the TaskDescription and
    re-invokes the task with ``resume_state=state`` once devices free up.
    Preemption is cooperative: the agent requests it through the task's
    :class:`ServiceControl`, and the service raises between work units.
    """

    def __init__(self, state: Any = None):
        super().__init__("service preempted")
        self.state = state


class ServiceControl:
    """Control handle for a ``service=True`` task (a long-running stage).

    The submitting side holds this object and uses ``submit_request`` /
    ``drain`` / ``stop``; the service body polls ``take_requests`` /
    ``preempt_requested`` / ``stop_requested`` between work units.  The
    handle lives on the TaskDescription, so it survives preemption and
    retries — requests queued while the service is yielded are delivered
    when it resumes.
    """

    def __init__(self):
        self._cond = threading.Condition()
        self._inbox: Deque[Any] = collections.deque()  # guarded-by: _cond
        self._stop = False  # guarded-by: _cond
        self._drain = False  # guarded-by: _cond
        self._preempt = False  # guarded-by: _cond
        self.accepted = 0  # guarded-by: _cond

    # -- submitting side -----------------------------------------------------

    def submit_request(self, request: Any) -> Any:
        """Queue a request for the service; returns the request."""
        with self._cond:
            if self._stop or self._drain:
                raise RuntimeError(
                    "service is stopping/draining; not accepting requests")
            self._inbox.append(request)
            self.accepted += 1
            self._cond.notify_all()
        return request

    def drain(self) -> None:
        """Stop admitting new requests; the service exits once every
        accepted request has finished."""
        with self._cond:
            self._drain = True
            self._cond.notify_all()

    def stop(self) -> None:
        """Ask the service to exit as soon as possible (accepted requests
        may be abandoned; use ``drain`` first for a graceful stop)."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()

    # -- agent side ----------------------------------------------------------

    def request_preempt(self) -> None:
        with self._cond:
            self._preempt = True
            self._cond.notify_all()

    def _clear_preempt(self) -> None:
        with self._cond:
            self._preempt = False

    # -- service body --------------------------------------------------------

    def take_requests(self, max_n: Optional[int] = None) -> List[Any]:
        """Pop up to ``max_n`` queued requests (all of them by default)."""
        with self._cond:
            n = len(self._inbox) if max_n is None else min(max_n, len(self._inbox))
            return [self._inbox.popleft() for _ in range(n)]

    def pending_requests(self) -> int:
        with self._cond:
            return len(self._inbox)

    def stop_requested(self) -> bool:
        with self._cond:
            return self._stop

    def drain_requested(self) -> bool:
        with self._cond:
            return self._drain

    def preempt_requested(self) -> bool:
        with self._cond:
            return self._preempt

    def wait_for_work(self, timeout: Optional[float] = None) -> bool:
        """Idle-wait until a request arrives or a control flag flips."""
        with self._cond:
            if self._inbox or self._stop or self._drain or self._preempt:
                return True
            return self._cond.wait(timeout)


@dataclasses.dataclass
class TaskDescription:
    """What the user submits (cf. radical.pilot.TaskDescription)."""

    name: str
    fn: Callable  # fn(comm, *args) -> result
    args: Tuple = ()
    kind: str = "generic"  # data_engineering | train | inference | generic
    # resource request
    num_devices: int = 1
    mesh_axes: Tuple[str, ...] = ("data",)
    mesh_shape: Optional[Tuple[int, ...]] = None  # default: (num_devices,)
    # policy.  ``max_retries`` is the legacy knob; setting ``policy``
    # (repro.core.resilience.FailurePolicy) supersedes it and adds
    # exponential backoff between attempts, a per-attempt timeout, and
    # an end-to-end deadline across all attempts.
    max_retries: int = 2
    policy: Optional["FailurePolicy"] = None
    priority: int = 0
    timeout_s: Optional[float] = None
    speculative: bool = True  # eligible for straggler duplicate execution
    tags: Dict[str, str] = dataclasses.field(default_factory=dict)
    # scheduling group (typically the owning pipeline's name).  Grouped
    # tasks share the agent's per-group device quota and appear in its
    # lease trace; ungrouped tasks are unconstrained.
    group: Optional[str] = None
    # checkpoint-aware retry: when set, the agent calls
    # ``fn(comm, *args, resume_step=<last completed step>)`` — None on the
    # first attempt, and the latest step found under ``checkpoint_dir`` on
    # every retry, so the task fn resumes instead of rediscovering it.
    checkpoint_dir: Optional[str] = None
    resume_step: Optional[int] = None  # written by the agent, not the user
    # service mode: a long-running stage (e.g. a continuous-batching
    # inference engine) that holds its lease until told to stop.  The
    # agent calls ``fn(comm, *args, control=<ServiceControl>,
    # resume_state=None)``; the fn may raise :class:`ServicePreempted`
    # (carrying its checkpoint) when ``control.preempt_requested()`` —
    # the agent releases the lease and re-queues the task, and the next
    # attempt receives ``resume_state=<checkpoint>``.  Preemption never
    # consumes retry budget.
    service: bool = False
    control: Optional[ServiceControl] = None
    resume_state: Any = None  # written by the agent, not the user

    def __post_init__(self):
        if self.service:
            if self.control is None:
                self.control = ServiceControl()
            # a duplicate engine racing the primary would double-serve
            # requests — service tasks are never speculated
            self.speculative = False


@dataclasses.dataclass
class Task:
    uid: str
    description: TaskDescription
    state: TaskState = TaskState.PENDING
    result: Any = None
    error: Optional[str] = None
    attempts: int = 0
    preemptions: int = 0  # times a service attempt yielded to higher priority
    # failure-policy scheduling state (written by the agent): a retry
    # backoff parks the task until ``not_before``; ``deadline`` is the
    # absolute end-to-end cutoff derived from ``policy.deadline_s``
    not_before: float = 0.0
    deadline: Optional[float] = None
    submitted_at: float = dataclasses.field(default_factory=time.time)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    # overhead decomposition (the paper's Table 2 metric)
    overhead_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    # -- async completion machinery (set by the agent) ----------------------
    # ``finalized`` flips exactly once, when the agent decides no further
    # attempts will run (success, exhausted retries, or cancellation); only
    # then do callbacks fire and ``wait`` return.  A FAILED state alone is
    # not terminal — the task may still be retried.
    finalized: bool = dataclasses.field(default=False, repr=False, compare=False)
    _finished: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)
    _callbacks: List[Callable[["Task"], None]] = dataclasses.field(  # guarded-by: _cb_lock
        default_factory=list, repr=False, compare=False)
    _cb_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    @property
    def duration_s(self) -> Optional[float]:
        if self.started_at is None or self.finished_at is None:
            return None
        return self.finished_at - self.started_at

    def done(self) -> bool:
        return self.state in (TaskState.DONE, TaskState.FAILED, TaskState.CANCELED)

    def add_done_callback(self, cb: Callable[["Task"], None]) -> None:
        """Register ``cb(task)`` to run when the task reaches a terminal
        state (after all retries).  Fires immediately if already terminal.
        The lock closes the check-then-append race against the agent
        draining callbacks at finalization."""
        with self._cb_lock:
            if not self._finished.is_set():
                self._callbacks.append(cb)
                return
        cb(self)

    def _drain_callbacks(self) -> List[Callable[["Task"], None]]:
        """Agent-side: atomically mark finished and take the callbacks."""
        with self._cb_lock:
            self._finished.set()
            callbacks, self._callbacks = self._callbacks, []
        return callbacks

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the task is terminal; True if it finished in time."""
        return self._finished.wait(timeout)


class DeviceFailure(RuntimeError):
    """Simulated node/device loss (tests + chaos benchmarks inject this)."""

    def __init__(self, device_ids, msg="device failure"):
        super().__init__(msg)
        self.device_ids = tuple(device_ids)
