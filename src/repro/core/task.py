"""Task model: the RADICAL-Pilot ``TaskDescription`` analogue.

A task is a Python callable plus a resource request (device count / mesh
shape).  The RemoteAgent carves a Communicator (mesh slice) matching the
request and calls ``fn(comm, *args)``.  Tasks carry retry/straggler policy
— the paper's fault-isolation claim (§2.3) is enforced at this boundary:
a task failure never propagates outside its Task record.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple


class TaskState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    CANCELED = "canceled"


@dataclasses.dataclass
class TaskDescription:
    """What the user submits (cf. radical.pilot.TaskDescription)."""

    name: str
    fn: Callable  # fn(comm, *args) -> result
    args: Tuple = ()
    kind: str = "generic"  # data_engineering | train | inference | generic
    # resource request
    num_devices: int = 1
    mesh_axes: Tuple[str, ...] = ("data",)
    mesh_shape: Optional[Tuple[int, ...]] = None  # default: (num_devices,)
    # policy
    max_retries: int = 2
    priority: int = 0
    timeout_s: Optional[float] = None
    speculative: bool = True  # eligible for straggler duplicate execution
    tags: Dict[str, str] = dataclasses.field(default_factory=dict)
    # scheduling group (typically the owning pipeline's name).  Grouped
    # tasks share the agent's per-group device quota and appear in its
    # lease trace; ungrouped tasks are unconstrained.
    group: Optional[str] = None
    # checkpoint-aware retry: when set, the agent calls
    # ``fn(comm, *args, resume_step=<last completed step>)`` — None on the
    # first attempt, and the latest step found under ``checkpoint_dir`` on
    # every retry, so the task fn resumes instead of rediscovering it.
    checkpoint_dir: Optional[str] = None
    resume_step: Optional[int] = None  # written by the agent, not the user


@dataclasses.dataclass
class Task:
    uid: str
    description: TaskDescription
    state: TaskState = TaskState.PENDING
    result: Any = None
    error: Optional[str] = None
    attempts: int = 0
    submitted_at: float = dataclasses.field(default_factory=time.time)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    # overhead decomposition (the paper's Table 2 metric)
    overhead_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    # -- async completion machinery (set by the agent) ----------------------
    # ``finalized`` flips exactly once, when the agent decides no further
    # attempts will run (success, exhausted retries, or cancellation); only
    # then do callbacks fire and ``wait`` return.  A FAILED state alone is
    # not terminal — the task may still be retried.
    finalized: bool = dataclasses.field(default=False, repr=False, compare=False)
    _finished: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)
    _callbacks: List[Callable[["Task"], None]] = dataclasses.field(
        default_factory=list, repr=False, compare=False)
    _cb_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    @property
    def duration_s(self) -> Optional[float]:
        if self.started_at is None or self.finished_at is None:
            return None
        return self.finished_at - self.started_at

    def done(self) -> bool:
        return self.state in (TaskState.DONE, TaskState.FAILED, TaskState.CANCELED)

    def add_done_callback(self, cb: Callable[["Task"], None]) -> None:
        """Register ``cb(task)`` to run when the task reaches a terminal
        state (after all retries).  Fires immediately if already terminal.
        The lock closes the check-then-append race against the agent
        draining callbacks at finalization."""
        with self._cb_lock:
            if not self._finished.is_set():
                self._callbacks.append(cb)
                return
        cb(self)

    def _drain_callbacks(self) -> List[Callable[["Task"], None]]:
        """Agent-side: atomically mark finished and take the callbacks."""
        with self._cb_lock:
            self._finished.set()
            callbacks, self._callbacks = self._callbacks, []
        return callbacks

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the task is terminal; True if it finished in time."""
        return self._finished.wait(timeout)


class DeviceFailure(RuntimeError):
    """Simulated node/device loss (tests + chaos benchmarks inject this)."""

    def __init__(self, device_ids, msg="device failure"):
        super().__init__(msg)
        self.device_ids = tuple(device_ids)
