"""Task model: the RADICAL-Pilot ``TaskDescription`` analogue.

A task is a Python callable plus a resource request (device count / mesh
shape).  The RemoteAgent carves a Communicator (mesh slice) matching the
request and calls ``fn(comm, *args)``.  Tasks carry retry/straggler policy
— the paper's fault-isolation claim (§2.3) is enforced at this boundary:
a task failure never propagates outside its Task record.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Any, Callable, Dict, Optional, Tuple


class TaskState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    CANCELED = "canceled"


@dataclasses.dataclass
class TaskDescription:
    """What the user submits (cf. radical.pilot.TaskDescription)."""

    name: str
    fn: Callable  # fn(comm, *args) -> result
    args: Tuple = ()
    kind: str = "generic"  # data_engineering | train | inference | generic
    # resource request
    num_devices: int = 1
    mesh_axes: Tuple[str, ...] = ("data",)
    mesh_shape: Optional[Tuple[int, ...]] = None  # default: (num_devices,)
    # policy
    max_retries: int = 2
    priority: int = 0
    timeout_s: Optional[float] = None
    speculative: bool = True  # eligible for straggler duplicate execution
    tags: Dict[str, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Task:
    uid: str
    description: TaskDescription
    state: TaskState = TaskState.PENDING
    result: Any = None
    error: Optional[str] = None
    attempts: int = 0
    submitted_at: float = dataclasses.field(default_factory=time.time)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    # overhead decomposition (the paper's Table 2 metric)
    overhead_s: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> Optional[float]:
        if self.started_at is None or self.finished_at is None:
            return None
        return self.finished_at - self.started_at

    def done(self) -> bool:
        return self.state in (TaskState.DONE, TaskState.FAILED, TaskState.CANCELED)


class DeviceFailure(RuntimeError):
    """Simulated node/device loss (tests + chaos benchmarks inject this)."""

    def __init__(self, device_ids, msg="device failure"):
        super().__init__(msg)
        self.device_ids = tuple(device_ids)
