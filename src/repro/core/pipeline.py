"""Deep RC pipelines: preprocess -> train/infer -> postprocess DAGs over
the pilot runtime (paper Fig. 2/3), plus the multi-pipeline batching mode
of Table 4 (N pipelines under one pilot)."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.agent import RemoteAgent
from repro.core.pilot import Pilot, PilotDescription, PilotManager
from repro.core.task import Task, TaskDescription, TaskState


@dataclasses.dataclass
class Stage:
    name: str
    fn: Callable  # fn(comm, upstream_results, *args)
    args: tuple = ()
    kind: str = "generic"
    num_devices: int = 1
    mesh_axes: tuple = ("data",)
    mesh_shape: Optional[tuple] = None
    deps: Sequence[str] = ()


class Pipeline:
    """A small DAG of stages executed on one RemoteAgent."""

    def __init__(self, name: str, stages: Sequence[Stage]):
        self.name = name
        self.stages = list(stages)
        self.results: Dict[str, Any] = {}
        self.tasks: Dict[str, Task] = {}

    def run(self, agent: RemoteAgent) -> Dict[str, Any]:
        done: Dict[str, Any] = {}
        remaining = list(self.stages)
        while remaining:
            ready = [s for s in remaining if all(d in done for d in s.deps)]
            if not ready:
                raise RuntimeError(f"pipeline {self.name}: dependency cycle")
            descs = []
            for s in ready:
                upstream = {d: done[d] for d in s.deps}

                def wrap(fn, upstream, args):
                    return lambda comm: fn(comm, upstream, *args)

                descs.append(TaskDescription(
                    name=f"{self.name}/{s.name}",
                    fn=wrap(s.fn, upstream, s.args),
                    kind=s.kind, num_devices=s.num_devices,
                    mesh_axes=s.mesh_axes, mesh_shape=s.mesh_shape,
                ))
            tasks = agent.submit(descs)
            for s, t in zip(ready, tasks):
                self.tasks[s.name] = t
                if t.state != TaskState.DONE:
                    raise RuntimeError(
                        f"pipeline {self.name} stage {s.name} failed: {t.error}"
                    )
                done[s.name] = t.result
            remaining = [s for s in remaining if s not in ready]
        self.results = done
        return done


def run_pipelines(
    pipelines: Sequence[Pipeline],
    *,
    pilot: Optional[Pilot] = None,
    max_workers: int = 8,
) -> Dict[str, Dict[str, Any]]:
    """Table-4 mode: N pipelines share one pilot/agent (vs N bare-metal
    runs re-acquiring resources per pipeline)."""
    own = False
    if pilot is None:
        pilot = PilotManager().submit_pilot(PilotDescription())
        own = True
    agent = RemoteAgent(pilot, max_workers=max_workers)
    t0 = time.time()
    out = {}
    for p in pipelines:  # stages overlap across pipelines via the agent pool
        out[p.name] = p.run(agent)
    wall = time.time() - t0
    out["_meta"] = {"wall_s": wall, "pilot": pilot.uid, "owned": own}
    return out
