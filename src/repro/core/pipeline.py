"""Deep RC pipelines: preprocess -> train/infer -> postprocess DAGs over
the pilot runtime (paper Fig. 2/3), plus the multi-pipeline batching mode
of Table 4 (N pipelines under one pilot).

Stage readiness is **event-driven**: each stage is submitted the moment
its dependencies complete (a task-completion callback fires the next
wave), so independent stages of *different* pipelines overlap freely on
the shared device pool — the property Table 4 measures.  There is no
lock-step "submit a batch, wait for the whole batch" barrier.

``PipelineScheduler`` runs N pipelines concurrently under one agent with
per-pipeline fault isolation: a pipeline whose stage exhausts its retries
records the failure in its own result dict (``_error`` / ``_failed_stage``)
without poisoning sibling pipelines.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.agent import RemoteAgent
from repro.core.pilot import Pilot, PilotDescription, PilotManager
from repro.core.task import Task, TaskDescription, TaskState


@dataclasses.dataclass
class Stage:
    name: str
    fn: Callable  # fn(comm, upstream_results, *args)
    args: tuple = ()
    kind: str = "generic"
    num_devices: int = 1
    mesh_axes: tuple = ("data",)
    mesh_shape: Optional[tuple] = None
    deps: Sequence[str] = ()
    priority: int = 0
    max_retries: int = 2


class Pipeline:
    """A small DAG of stages executed on one RemoteAgent.

    Two entry points:

    * ``run(agent)`` — blocking; raises on stage failure (single-pipeline
      ergonomics, unchanged from the batch-mode predecessor);
    * ``start(agent, on_finish)`` — non-blocking; submits ready stages and
      returns.  Completion callbacks drive the DAG forward; failures are
      recorded on the pipeline (``error`` / ``failed_stage``), never raised
      into the caller.  Used by :class:`PipelineScheduler`.
    """

    def __init__(self, name: str, stages: Sequence[Stage]):
        self.name = name
        self.stages = list(stages)
        self.results: Dict[str, Any] = {}
        self.tasks: Dict[str, Task] = {}
        self.error: Optional[str] = None
        self.failed_stage: Optional[str] = None
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self._lock = threading.Lock()
        self._submitted: set = set()
        self._agent: Optional[RemoteAgent] = None
        self._on_finish: Optional[Callable[["Pipeline"], None]] = None
        self._finished_evt = threading.Event()

    # -- public ----------------------------------------------------------------

    @property
    def wall_s(self) -> Optional[float]:
        if self.started_at is None or self.finished_at is None:
            return None
        return self.finished_at - self.started_at

    def start(self, agent: RemoteAgent,
              on_finish: Optional[Callable[["Pipeline"], None]] = None) -> None:
        """Submit all currently-ready stages and return immediately."""
        self._validate_dag()
        self._agent = agent
        self._on_finish = on_finish
        self.started_at = time.time()
        if not self.stages:
            self._finish()
            return
        self._submit_ready()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._finished_evt.wait(timeout)

    def run(self, agent: RemoteAgent) -> Dict[str, Any]:
        """Blocking single-pipeline execution; raises on stage failure."""
        self.start(agent)
        self.wait()
        if self.error is not None:
            raise RuntimeError(f"pipeline {self.name} {self.error}")
        return self.results

    # -- internals -------------------------------------------------------------

    def _validate_dag(self) -> None:
        names = {s.name for s in self.stages}
        if len(names) != len(self.stages):  # results are keyed by name; a
            # duplicate would make completion counting hang, not overwrite
            raise RuntimeError(
                f"pipeline {self.name}: duplicate stage names")
        done: set = set()
        remaining = list(self.stages)
        while remaining:
            ready = [s for s in remaining
                     if all(d in done and d in names for d in s.deps)]
            if not ready:
                raise RuntimeError(f"pipeline {self.name}: dependency cycle")
            done.update(s.name for s in ready)
            remaining = [s for s in remaining if s not in ready]

    def _submit_ready(self) -> None:
        with self._lock:
            if self.error is not None:
                return
            ready = [
                s for s in self.stages
                if s.name not in self._submitted
                and all(d in self.results for d in s.deps)
            ]
            self._submitted.update(s.name for s in ready)
            upstreams = [{d: self.results[d] for d in s.deps} for s in ready]
        for s, upstream in zip(ready, upstreams):

            def wrap(fn, upstream, args):
                return lambda comm: fn(comm, upstream, *args)

            self._agent.submit_async(
                [TaskDescription(
                    name=f"{self.name}/{s.name}",
                    fn=wrap(s.fn, upstream, s.args),
                    kind=s.kind, num_devices=s.num_devices,
                    mesh_axes=s.mesh_axes, mesh_shape=s.mesh_shape,
                    priority=s.priority, max_retries=s.max_retries,
                )],
                on_complete=lambda task, s=s: self._stage_done(s, task),
            )

    def _stage_done(self, stage: Stage, task: Task) -> None:
        with self._lock:
            self.tasks[stage.name] = task
            if task.state == TaskState.DONE:
                self.results[stage.name] = task.result
            elif self.error is None:
                self.error = f"stage {stage.name} failed: {task.error}"
                self.failed_stage = stage.name
            finished = self._is_finished_locked()
        if finished:
            self._finish()
        elif self.error is None:
            self._submit_ready()

    def _is_finished_locked(self) -> bool:
        if len(self.results) == len(self.stages):
            return True
        if self.error is not None:
            # finished once every in-flight task has reported back
            return len(self.tasks) == len(self._submitted)
        return False

    def _finish(self) -> None:
        self.finished_at = time.time()
        self._finished_evt.set()
        if self._on_finish is not None:
            self._on_finish(self)

    def result_dict(self) -> Dict[str, Any]:
        """Per-pipeline results; failures recorded, not raised (Table-4
        fault-isolation contract)."""
        out = dict(self.results)
        if self.error is not None:
            out["_error"] = self.error
            out["_failed_stage"] = self.failed_stage
        return out


class PipelineScheduler:
    """Run N pipelines concurrently under one RemoteAgent (Table-4 mode).

    All pipelines are started at once; their stages interleave on the
    shared pilot according to device availability and priority.  One
    pipeline failing (stage retries exhausted) is isolated to its own
    result dict and never aborts its siblings.
    """

    def __init__(self, agent: RemoteAgent):
        self.agent = agent

    def run(self, pipelines: Sequence[Pipeline],
            timeout: Optional[float] = None) -> Dict[str, Dict[str, Any]]:
        t0 = time.time()
        for p in pipelines:
            p.start(self.agent)
        deadline = None if timeout is None else t0 + timeout
        for p in pipelines:
            remaining = None if deadline is None else max(0.0, deadline - time.time())
            if not p.wait(remaining):
                raise TimeoutError(
                    f"pipeline {p.name} did not finish within {timeout}s")
        wall = time.time() - t0
        out: Dict[str, Dict[str, Any]] = {
            p.name: p.result_dict() for p in pipelines}
        out["_meta"] = self._metrics(pipelines, wall)
        return out

    def _metrics(self, pipelines: Sequence[Pipeline], wall: float) -> Dict[str, Any]:
        """Table-2/Table-4 decomposition: per-pipeline wall + overheads and
        the aggregate overlap factor (sum of task busy time / batch wall)."""
        per_pipeline: Dict[str, Any] = {}
        agg = {"queue_s": 0.0, "communicator_s": 0.0, "task_busy_s": 0.0,
               "n_tasks": 0, "n_failed": 0}
        for p in pipelines:
            ov = {"queue_s": 0.0, "communicator_s": 0.0, "task_busy_s": 0.0}
            for t in p.tasks.values():
                ov["queue_s"] += t.overhead_s.get("queue", 0.0)
                ov["communicator_s"] += t.overhead_s.get("communicator", 0.0)
                ov["task_busy_s"] += t.duration_s or 0.0
                agg["n_tasks"] += 1
                agg["n_failed"] += int(t.state != TaskState.DONE)
            per_pipeline[p.name] = {
                "wall_s": p.wall_s, "error": p.error, **ov}
            for k in ("queue_s", "communicator_s", "task_busy_s"):
                agg[k] += ov[k]
        return {
            "wall_s": wall,
            "per_pipeline": per_pipeline,
            "overlap_factor": (agg["task_busy_s"] / wall) if wall > 0 else 0.0,
            **agg,
        }


def run_pipelines(
    pipelines: Sequence[Pipeline],
    *,
    pilot: Optional[Pilot] = None,
    max_workers: int = 8,
) -> Dict[str, Dict[str, Any]]:
    """Table-4 mode: N pipelines share one pilot/agent (vs N bare-metal
    runs re-acquiring resources per pipeline).  Thin wrapper over
    :class:`PipelineScheduler`; stages of different pipelines genuinely
    overlap, and ``_meta`` carries the per-pipeline + aggregate wall /
    overhead decomposition."""
    own = False
    if pilot is None:
        pilot = PilotManager().submit_pilot(PilotDescription())
        own = True
    agent = RemoteAgent(pilot, max_workers=max_workers)
    try:
        out = PipelineScheduler(agent).run(pipelines)
    finally:
        agent.close()
    out["_meta"].update({"pilot": pilot.uid, "owned": own})
    return out
