"""Deep RC pipelines: preprocess -> train/infer -> postprocess DAGs over
the pilot runtime (paper Fig. 2/3), plus the multi-pipeline batching mode
of Table 4 — N pipelines under one pilot (``PipelineScheduler``) or
spread across several disjoint pilots (``MultiPilotScheduler``).

The user-facing entry point is :class:`repro.core.session.Session` with
the ``@stage`` graph DSL; it drives pipelines through the per-stage
``placement`` hook below, so one DAG's stages can resolve to *different*
agents (cross-pilot dependency edges).  ``run_pipelines`` /
``run_pipelines_multi`` remain as deprecated shims.

Stage readiness is **event-driven**: each stage is submitted the moment
its dependencies complete (a task-completion callback fires the next
wave), so independent stages of *different* pipelines overlap freely on
the shared device pool — the property Table 4 measures.  There is no
lock-step "submit a batch, wait for the whole batch" barrier.

``PipelineScheduler`` runs N pipelines concurrently under one agent with
per-pipeline fault isolation: a pipeline whose stage exhausts its retries
records the failure in its own result dict (``_error`` / ``_failed_stage``)
without poisoning sibling pipelines.

``MultiPilotScheduler`` is the layer above (the execution stack reads
``pipeline -> PilotManager -> {pilots} -> transport``): each pipeline is
*placed* on one of several disjoint pilots via ``PilotManager.place``
(most effective free capacity among pilots admitting the pipeline's task
kinds), runs there under that pilot's agent, and **migrates** its
remaining stages to a healthier pilot if its pilot degrades below the
pipeline's mesh requirement.  Per-pipeline device quotas (``Pipeline(...,
quota=n)``) are enforced by the agents' dispatchers and audited through
their lease traces.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.agent import RemoteAgent
from repro.core.pilot import Pilot, PilotDescription, PilotManager
from repro.core.task import ServiceControl, Task, TaskDescription, TaskState


@dataclasses.dataclass
class Stage:
    name: str
    fn: Callable  # fn(comm, upstream_results, *args[, resume_step=...])
    args: tuple = ()
    kind: str = "generic"
    num_devices: int = 1
    mesh_axes: tuple = ("data",)
    mesh_shape: Optional[tuple] = None
    deps: Sequence[str] = ()
    priority: int = 0
    max_retries: int = 2
    # checkpoint-aware retry: when set, fn must accept resume_step=None
    # and is handed the last completed step on every retried attempt
    checkpoint_dir: Optional[str] = None
    # service stage: a long-running task (fn must accept control= and
    # resume_state= kwargs) that is EXCLUDED from the pipeline's
    # stage-completion barrier — the pipeline finishes when its ordinary
    # stages do, while the service keeps running until its control handle
    # is told to drain/stop (see Pipeline.control / stop_services).  A
    # service stage may not be a dependency of another stage.
    service: bool = False


class _BoundStage:
    """Picklable binding of a stage fn to its upstream results and static
    args.  Replaces the old per-submit lambda: a module-level class
    crosses the subprocess transport's pickle boundary whenever the
    stage fn and upstream results do.  ``**kw`` forwards the agent's
    ``resume_step`` on checkpointed stages (and ``control`` /
    ``resume_state`` on service stages); plain stages never receive
    extra kwargs."""

    __slots__ = ("fn", "upstream", "args")

    def __init__(self, fn, upstream, args):
        self.fn = fn
        self.upstream = upstream
        self.args = tuple(args)

    @property
    def __name__(self) -> str:
        return getattr(self.fn, "__name__", "stage")


    def __call__(self, comm, **kw):
        return self.fn(comm, self.upstream, *self.args, **kw)


class Pipeline:
    """A small DAG of stages executed on one RemoteAgent.

    Two entry points:

    * ``run(agent)`` — blocking; raises on stage failure (single-pipeline
      ergonomics, unchanged from the batch-mode predecessor);
    * ``start(agent, on_finish)`` — non-blocking; submits ready stages and
      returns.  Completion callbacks drive the DAG forward; failures are
      recorded on the pipeline (``error`` / ``failed_stage``), never raised
      into the caller.  Used by :class:`PipelineScheduler`.

    ``quota`` caps how many devices this pipeline's stages may hold at
    once on an agent (enforced by each agent's dispatcher; see
    ``RemoteAgent.set_quota``).  Enforcement is per agent: under
    per-stage placement the Session keeps quota'd pipelines sticky to
    one pod so the cap stays pipeline-wide; if kind constraints or
    degradation force stages onto K pods, the cap applies per pod
    (global bound quota*K).  ``rebind(agent)`` re-points not-yet-
    submitted stages at a different agent — the migration primitive used
    by :class:`MultiPilotScheduler`.

    Stages with ``service=True`` are long-running (e.g. a continuous-
    batching inference engine): they are excluded from the completion
    barrier — the pipeline finishes when its ordinary stages do — and are
    driven through their :class:`ServiceControl` (``control(name)`` /
    ``stop_services``).  The agent may preempt them for higher-priority
    work; they resume with their checkpointed state.
    """

    def __init__(self, name: str, stages: Sequence[Stage],
                 quota: Optional[int] = None,
                 placement: Optional[Callable[[Stage],
                                              Optional[RemoteAgent]]] = None):
        self.name = name
        self.stages = list(stages)
        self.quota = quota
        self.results: Dict[str, Any] = {}  # guarded-by: _lock
        self.tasks: Dict[str, Task] = {}  # guarded-by: _lock
        self.error: Optional[str] = None  # guarded-by: _lock
        self.failed_stage: Optional[str] = None  # guarded-by: _lock
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.migrations: List[Dict[str, Any]] = []
        # per-stage placement: when set, each stage resolves its OWN agent
        # through this callable the moment it becomes ready (deps done), so
        # one DAG's stages can span several pilots with real dependency
        # edges crossing agents.  ``None`` from the resolver marks the
        # stage unplaceable and fails the pipeline.  ``stage_agents``
        # records where each submitted stage actually ran.
        self.placement = placement
        self.stage_agents: Dict[str, RemoteAgent] = {}  # guarded-by: _lock
        # one control handle per service stage, created eagerly so callers
        # can hold the handle before (and across) the stage's task attempts
        self.service_controls: Dict[str, ServiceControl] = {
            s.name: ServiceControl() for s in self.stages if s.service}
        self._lock = threading.Lock()
        self._submitted: set = set()  # guarded-by: _lock
        self._quota_agents: set = set()  # guarded-by: _lock (agent ids already given our quota)
        self._agent: Optional[RemoteAgent] = None  # guarded-by: _lock
        self._on_finish: Optional[Callable[["Pipeline"], None]] = None  # guarded-by: _lock
        self._stage_observers: List[Callable[["Pipeline", Stage, Task], None]] = []  # guarded-by: _lock
        self._finishing = False  # guarded-by: _lock (test-and-set, see _finish)
        self._finished_evt = threading.Event()

    # -- public ----------------------------------------------------------------

    @property
    def wall_s(self) -> Optional[float]:
        if self.started_at is None or self.finished_at is None:
            return None
        return self.finished_at - self.started_at

    @property
    def mesh_requirement(self) -> int:
        """Widest single-stage device ask — the floor a pilot must keep
        alive for this pipeline to run un-degraded."""
        return max((s.num_devices for s in self.stages), default=1)

    def remaining_mesh_requirement(self) -> int:
        """Widest device ask among stages not yet submitted (0 = nothing
        left to place).  Migration keys off this, not ``mesh_requirement``:
        a completed wide stage must not force a pointless move."""
        with self._lock:
            return max((s.num_devices for s in self.stages
                        if s.name not in self._submitted), default=0)

    def stage_kinds(self) -> set:
        return {s.kind for s in self.stages}

    @property
    def finished(self) -> bool:
        return self._finished_evt.is_set()

    def add_stage_observer(
            self, cb: Callable[["Pipeline", Stage, Task], None]) -> None:
        """Register ``cb(pipeline, stage, task)`` to fire whenever a stage's
        task finalizes (success or failure) — the per-stage hook Session's
        placement load accounting and live monitors key off."""
        with self._lock:
            self._stage_observers.append(cb)

    def start(self, agent: Optional[RemoteAgent] = None,
              on_finish: Optional[Callable[["Pipeline"], None]] = None) -> None:
        """Submit all currently-ready stages and return immediately.

        ``agent`` may be None when a per-stage ``placement`` resolver is
        set — every stage then resolves its own agent individually."""
        self._validate_dag()
        with self._lock:
            # first bind only: a rebind() that raced in between placement
            # and start (pilot degraded immediately) must not be undone
            if self._agent is None:
                self._agent = agent
            effective = self._agent
            self._on_finish = on_finish
        if effective is None and self.placement is None:
            raise RuntimeError(
                f"pipeline {self.name}: start() needs an agent or a "
                "per-stage placement resolver")
        if self.quota is not None and effective is not None:
            effective.set_quota(self.name, self.quota)
        self.started_at = time.time()
        if not self.stages:
            self._finish()
            return
        self._submit_ready()
        with self._lock:
            finished = self._is_finished_locked()
        if finished:
            # all stages are services: the barrier is trivially satisfied
            # the moment they are submitted (they run until drained/stopped)
            self._finish()

    def rebind(self, agent: RemoteAgent, reason: str = "") -> None:
        """Migrate: stages not yet submitted will go to ``agent``.
        In-flight tasks finish on the old agent (their results are still
        delivered through per-task callbacks)."""
        with self._lock:
            old = self._agent
            self._agent = agent
            self.migrations.append({
                "t": time.time(), "reason": reason,
                "from": old.pilot.uid if old is not None else None,
                "to": agent.pilot.uid,
            })
        if self.quota is not None:
            agent.set_quota(self.name, self.quota)

    def abort(self, reason: str) -> None:
        """Mark the pipeline failed without running it (e.g. no pilot can
        satisfy its placement requirements)."""
        with self._lock:
            if self.error is None:  # first error wins, like _stage_done
                self.error = reason
        if self.started_at is None:
            self.started_at = time.time()
        self._finish()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._finished_evt.wait(timeout)

    def control(self, stage_name: str) -> ServiceControl:
        """Control handle of a service stage (submit_request/drain/stop)."""
        return self.service_controls[stage_name]

    def stop_services(self, drain: bool = True,
                      timeout: Optional[float] = None) -> bool:
        """Drain (default) or hard-stop every service stage and wait for
        their tasks to finalize.  Returns False on timeout.  Service
        results land in ``results[<stage>]`` like any other stage — they
        are just never part of the completion barrier."""
        for ctl in self.service_controls.values():
            (ctl.drain if drain else ctl.stop)()
        deadline = None if timeout is None else time.time() + timeout
        for name in self.service_controls:
            with self._lock:
                task = self.tasks.get(name)
            if task is None:
                continue  # never submitted (deps unmet / pipeline aborted)
            remaining = (None if deadline is None
                         else max(0.0, deadline - time.time()))
            if not task.wait(remaining):
                return False
        return True

    def run(self, agent: Optional[RemoteAgent] = None) -> Dict[str, Any]:
        """Blocking single-pipeline execution; raises on stage failure."""
        self.start(agent)
        self.wait()  # noqa: TMO001 — blocking-run API; per-task deadlines bound the stages
        with self._lock:
            error = self.error
            results = self.results
        if error is not None:
            raise RuntimeError(f"pipeline {self.name} {error}")
        return results

    # -- internals -------------------------------------------------------------

    def _validate_dag(self) -> None:
        names = {s.name for s in self.stages}
        if len(names) != len(self.stages):  # results are keyed by name; a
            # duplicate would make completion counting hang, not overwrite
            raise RuntimeError(
                f"pipeline {self.name}: duplicate stage names")
        for s in self.stages:
            missing = [d for d in s.deps if d not in names]
            if missing:  # distinct from a cycle: the stage waits on a name
                # that will never complete because it does not exist
                raise RuntimeError(
                    f"pipeline {self.name}: stage {s.name} depends on "
                    f"unknown stage(s) {sorted(missing)}")
        service_names = {s.name for s in self.stages if s.service}
        for s in self.stages:
            bad = service_names & set(s.deps)
            if bad:  # a service never "completes" in the barrier sense, so
                # a dependent stage would wait forever
                raise RuntimeError(
                    f"pipeline {self.name}: stage {s.name} depends on "
                    f"service stage(s) {sorted(bad)}")
        done: set = set()
        remaining = list(self.stages)
        while remaining:
            ready = [s for s in remaining if all(d in done for d in s.deps)]
            if not ready:
                raise RuntimeError(f"pipeline {self.name}: dependency cycle")
            done.update(s.name for s in ready)
            remaining = [s for s in remaining if s not in ready]

    def _resolve_agent(self, stage: Stage) -> Optional[RemoteAgent]:
        """Per-stage agent resolution (called OUTSIDE the pipeline lock —
        a placement policy takes pilot/session locks of its own)."""
        if self.placement is not None:
            agent = self.placement(stage)
        else:
            with self._lock:
                agent = self._agent  # rebind() may race; read under lock
        if agent is not None and self.quota is not None:
            with self._lock:
                first_touch = id(agent) not in self._quota_agents
                self._quota_agents.add(id(agent))
            if first_touch:
                agent.set_quota(self.name, self.quota)
        return agent

    def _mark_unplaceable(self, stage: Stage) -> None:
        """A ready stage no pilot can host fails the pipeline; the stage is
        un-marked from _submitted so the error barrier count stays exact."""
        with self._lock:
            self._submitted.discard(stage.name)
            if self.error is None:
                self.error = (
                    f"stage {stage.name} unplaceable: no pilot admits "
                    f"kind={stage.kind!r} with >= {stage.num_devices} "
                    "alive devices")
                self.failed_stage = stage.name
            finished = self._is_finished_locked()
        if finished:
            self._finish()

    def _submit_ready(self) -> None:
        with self._lock:
            if self.error is not None:
                return
            ready = [
                s for s in self.stages
                if s.name not in self._submitted
                and all(d in self.results for d in s.deps)
            ]
            self._submitted.update(s.name for s in ready)
            upstreams = [{d: self.results[d] for d in s.deps} for s in ready]
        for s, upstream in zip(ready, upstreams):
            with self._lock:
                failing = self.error is not None
            if failing:  # an earlier stage in this wave was unplaceable:
                # withdraw the rest of the wave and re-check the barrier
                self._mark_unplaceable_noop(s)
                continue
            agent = self._resolve_agent(s)
            if agent is None:
                self._mark_unplaceable(s)
                continue

            with self._lock:
                self.stage_agents[s.name] = agent
            tasks = agent.submit_async(
                [TaskDescription(
                    name=f"{self.name}/{s.name}",
                    fn=_BoundStage(s.fn, upstream, s.args),
                    kind=s.kind, num_devices=s.num_devices,
                    mesh_axes=s.mesh_axes, mesh_shape=s.mesh_shape,
                    priority=s.priority, max_retries=s.max_retries,
                    group=self.name, checkpoint_dir=s.checkpoint_dir,
                    service=s.service,
                    control=self.service_controls.get(s.name),
                )],
                on_complete=lambda task, s=s: self._stage_done(s, task),
            )
            # recorded at SUBMIT time (not completion) so live readers —
            # metrics, migration decisions, stop_services — can see
            # running stages; _stage_done re-records the same object
            with self._lock:
                self.tasks.setdefault(s.name, tasks[0])

    def _mark_unplaceable_noop(self, stage: Stage) -> None:
        """Withdraw a wave-mate of an unplaceable stage without touching
        the (already set) error."""
        with self._lock:
            self._submitted.discard(stage.name)
            finished = self._is_finished_locked()
        if finished:
            self._finish()

    def stage_placements(self) -> Dict[str, str]:
        """Pilot uid each submitted stage resolved to (live view)."""
        with self._lock:
            return {name: a.pilot.uid for name, a in self.stage_agents.items()}

    def _stage_done(self, stage: Stage, task: Task) -> None:
        with self._lock:
            self.tasks[stage.name] = task
            observers = list(self._stage_observers)
            if task.state == TaskState.DONE:
                self.results[stage.name] = task.result
            elif not stage.service and self.error is None:
                self.error = f"stage {stage.name} failed: {task.error}"
                self.failed_stage = stage.name
            elif stage.service:
                # service failure/cancellation is isolated: recorded on
                # the task (and absent from results), never poisons the
                # pipeline's ordinary stages or flips a finished pipeline
                # back into error state
                pass
            finished = self._is_finished_locked()
            error = self.error
        for cb in observers:  # outside the lock: observers take their own
            try:              # locks (e.g. Session's placement accounting)
                cb(self, stage, task)
            except Exception:  # noqa: BLE001 — observers must not poison
                pass           # the DAG driver
        if finished:
            self._finish()
        elif error is None:
            # a stale None here is benign: _submit_ready rechecks under
            # the lock before submitting anything
            self._submit_ready()

    def _barrier_stages(self) -> List[Stage]:
        """Stages that participate in the completion barrier (everything
        except long-running service stages)."""
        return [s for s in self.stages if not s.service]

    def _is_finished_locked(self) -> bool:
        barrier = self._barrier_stages()
        if sum(1 for s in barrier if s.name in self.results) == len(barrier):
            return True
        if self.error is not None:
            # finished once every in-flight barrier task has reported back
            names = {s.name for s in barrier}
            reported = len([n for n in self.tasks
                            if n in names and self.tasks[n].finalized])
            return reported == len(self._submitted & names)
        return False

    def _finish(self) -> None:
        with self._lock:
            # idempotent AND race-free: _finish can arrive concurrently
            # from start()'s all-service recheck (caller thread) and from
            # _stage_done (worker threads) — exactly one may fire
            # on_finish, or scheduler completion counting corrupts
            if self._finishing:
                return
            self._finishing = True
            error = self.error
            on_finish = self._on_finish
        if error is not None:
            # a failed pipeline must not leak its services: nobody is
            # coming back to drain them, and a running service pins its
            # device lease (cancel_pilot would refuse forever)
            for ctl in self.service_controls.values():
                ctl.stop()
        self.finished_at = time.time()
        self._finished_evt.set()
        if on_finish is not None:
            on_finish(self)  # outside the lock: arbitrary user callback

    def result_dict(self) -> Dict[str, Any]:
        """Per-pipeline results; failures recorded, not raised (Table-4
        fault-isolation contract)."""
        with self._lock:
            out = dict(self.results)
            if self.error is not None:
                out["_error"] = self.error
                out["_failed_stage"] = self.failed_stage
        return out


class PipelineScheduler:
    """Run N pipelines concurrently under one RemoteAgent (Table-4 mode).

    All pipelines are started at once; their stages interleave on the
    shared pilot according to device availability and priority.  One
    pipeline failing (stage retries exhausted) is isolated to its own
    result dict and never aborts its siblings.
    """

    def __init__(self, agent: RemoteAgent):
        self.agent = agent

    def run(self, pipelines: Sequence[Pipeline],
            timeout: Optional[float] = None) -> Dict[str, Dict[str, Any]]:
        t0 = time.time()
        for p in pipelines:
            p.start(self.agent)
        deadline = None if timeout is None else t0 + timeout
        for p in pipelines:
            remaining = None if deadline is None else max(0.0, deadline - time.time())
            if not p.wait(remaining):
                raise TimeoutError(
                    f"pipeline {p.name} did not finish within {timeout}s")
        wall = time.time() - t0
        out: Dict[str, Dict[str, Any]] = {
            p.name: p.result_dict() for p in pipelines}
        out["_meta"] = aggregate_metrics(pipelines, wall)
        return out


def aggregate_metrics(pipelines: Sequence[Pipeline], wall: float) -> Dict[str, Any]:
    """Table-2/Table-4 decomposition: per-pipeline wall + overheads and
    the aggregate overlap factor (sum of task busy time / batch wall).

    Safe on LIVE pipelines: tasks are recorded at submit time, so a stage
    still running shows up here — it is listed under the pipeline's
    ``running`` key and counted in the aggregate ``n_running`` instead of
    being invisible until completion."""
    per_pipeline: Dict[str, Any] = {}
    agg = {"queue_s": 0.0, "communicator_s": 0.0, "task_busy_s": 0.0,
           "n_tasks": 0, "n_failed": 0, "n_running": 0}
    for p in pipelines:
        with p._lock:  # snapshot: submit-time recording mutates concurrently
            tasks = dict(p.tasks)
        running = sorted(n for n, t in tasks.items() if not t.finalized)
        ov = {"queue_s": 0.0, "communicator_s": 0.0, "task_busy_s": 0.0}
        for t in tasks.values():
            ov["queue_s"] += t.overhead_s.get("queue", 0.0)
            ov["communicator_s"] += t.overhead_s.get("communicator", 0.0)
            ov["task_busy_s"] += t.duration_s or 0.0
            agg["n_tasks"] += 1
            # a still-running service task is neither done nor failed
            agg["n_failed"] += int(t.finalized and t.state != TaskState.DONE)
        agg["n_running"] += len(running)
        per_pipeline[p.name] = {
            "wall_s": p.wall_s, "error": p.error, "running": running, **ov}
        for k in ("queue_s", "communicator_s", "task_busy_s"):
            agg[k] += ov[k]
    return {
        "wall_s": wall,
        "per_pipeline": per_pipeline,
        "overlap_factor": (agg["task_busy_s"] / wall) if wall > 0 else 0.0,
        **agg,
    }


def run_pipelines(
    pipelines: Sequence[Pipeline],
    *,
    pilot: Optional[Pilot] = None,
    max_workers: int = 8,
    transport=None,
) -> Dict[str, Dict[str, Any]]:
    """DEPRECATED shim — use :class:`repro.core.session.Session` (its
    ``run_all``) instead; kept so pre-Session callers and tests keep
    working unchanged.

    Table-4 mode: N pipelines share one pilot/agent (vs N bare-metal
    runs re-acquiring resources per pipeline).  Thin wrapper over
    :class:`PipelineScheduler`; stages of different pipelines genuinely
    overlap, and ``_meta`` carries the per-pipeline + aggregate wall /
    overhead decomposition."""
    warnings.warn(
        "run_pipelines is deprecated; use repro.core.Session "
        "(session.run_all) instead", DeprecationWarning, stacklevel=2)
    own = False
    if pilot is None:
        pilot = PilotManager().submit_pilot(PilotDescription())
        own = True
    agent = RemoteAgent(pilot, max_workers=max_workers, transport=transport)
    try:
        out = PipelineScheduler(agent).run(pipelines)
    finally:
        agent.close()
    out["_meta"].update({"pilot": pilot.uid, "owned": own})
    return out


class MultiPilotScheduler:
    """Place N pipelines across several disjoint pilots (per-pod pools).

    The full Table-4 stack: ``pipeline -> PilotManager.place -> {pilots}
    -> transport``.  One RemoteAgent runs per pilot; each pipeline is
    placed once up front (by effective free capacity among pilots that
    admit its task kinds and satisfy its mesh requirement) and re-placed
    — **migrated** — if its pilot's alive-device count degrades below the
    pipeline's mesh requirement while it still has unsubmitted stages.
    In-flight tasks drain on the old pilot; only remaining stages move.

    Per-pipeline fault isolation and quota semantics are inherited from
    Pipeline/RemoteAgent; ``run(...)['_meta']`` additionally reports the
    placement map, migrations, per-pilot lease peaks, and any quota
    violations (always ``{}`` unless the enforcement invariant broke).
    """

    def __init__(self, manager: PilotManager,
                 pilots: Optional[Sequence[Pilot]] = None, *,
                 max_workers_per_pilot: int = 4,
                 agent_factory: Callable[..., RemoteAgent] = RemoteAgent):
        self.manager = manager
        self.pilots = list(pilots if pilots is not None else manager.pilots)
        if not self.pilots:
            raise RuntimeError("MultiPilotScheduler needs at least one pilot")
        self.agents: Dict[str, RemoteAgent] = {
            p.uid: agent_factory(p, max_workers=max_workers_per_pilot)
            for p in self.pilots}
        self._lock = threading.Lock()
        self._pipelines: List[Pipeline] = []
        self._placement: Dict[str, Pilot] = {}  # pipeline name -> pilot
        # placement weight already promised to each pilot but possibly not
        # leased yet; keeps a burst of placements spread out.  Released
        # when a pipeline finishes so late migrations rank pilots on live
        # load, not the initial assignment.
        self._assigned: Dict[str, int] = {p.uid: 0 for p in self.pilots}
        self._released: set = set()  # pipeline names whose weight returned
        self._listeners = []
        for p in self.pilots:
            cb = (lambda p=p: self._on_capacity_change(p))
            p.add_capacity_listener(cb)
            self._listeners.append((p, cb))

    # -- placement -------------------------------------------------------------

    @staticmethod
    def _weight(pipe: Pipeline) -> int:
        return pipe.quota if pipe.quota is not None else pipe.mesh_requirement

    def _place_locked(self, pipe: Pipeline, exclude: Sequence[Pilot] = (),
                      num_devices: Optional[int] = None) -> Optional[Pilot]:
        return self.manager.place(
            num_devices=(num_devices if num_devices is not None
                         else pipe.mesh_requirement),
            kinds=pipe.stage_kinds(),
            pilots=self.pilots, load=self._assigned, exclude=exclude)

    def _release_weight(self, pipe: Pipeline) -> None:
        with self._lock:
            if pipe.name in self._released:
                return
            self._released.add(pipe.name)
            pilot = self._placement.get(pipe.name)
            if pilot is not None:
                self._assigned[pilot.uid] -= self._weight(pipe)

    def _on_capacity_change(self, pilot: Pilot) -> None:
        """Migrate pipelines whose pilot degraded below their mesh
        requirement (device failures shrink alive_count; releases never
        do, so this is cheap on the common path)."""
        moves: List[tuple] = []
        with self._lock:
            for pipe in self._pipelines:
                if self._placement.get(pipe.name) is not pilot or pipe.finished:
                    continue
                need = pipe.remaining_mesh_requirement()
                if need == 0 or pilot.alive_count() >= need:
                    continue
                target = self._place_locked(pipe, exclude=(pilot,),
                                            num_devices=need)
                if target is None:
                    continue  # nowhere better: stay and degrade elastically
                w = self._weight(pipe)
                self._assigned[pilot.uid] -= w
                self._assigned[target.uid] += w
                self._placement[pipe.name] = target
                moves.append((pipe, target, need))
        for pipe, target, need in moves:
            pipe.rebind(self.agents[target.uid],
                        reason=f"pilot {pilot.uid} degraded below "
                               f"{need} alive devices")

    # -- run -------------------------------------------------------------------

    def run(self, pipelines: Sequence[Pipeline],
            timeout: Optional[float] = None) -> Dict[str, Dict[str, Any]]:
        t0 = time.time()
        placed: List[tuple] = []
        with self._lock:
            self._pipelines = list(pipelines)
            for pipe in pipelines:
                pilot = self._place_locked(pipe)
                if pilot is not None:
                    self._assigned[pilot.uid] += self._weight(pipe)
                    self._placement[pipe.name] = pilot
                placed.append((pipe, pilot))
        for pipe, pilot in placed:
            if pilot is None:
                pipe.abort(
                    f"unplaceable: no pilot admits kinds={sorted(pipe.stage_kinds())} "
                    f"with >= {pipe.mesh_requirement} alive devices")
            else:
                pipe.start(self.agents[pilot.uid],
                           on_finish=self._release_weight)
        deadline = None if timeout is None else t0 + timeout
        for pipe in pipelines:
            remaining = None if deadline is None else max(0.0, deadline - time.time())
            if not pipe.wait(remaining):
                raise TimeoutError(
                    f"pipeline {pipe.name} did not finish within {timeout}s")
        wall = time.time() - t0
        out: Dict[str, Dict[str, Any]] = {
            p.name: p.result_dict() for p in pipelines}
        meta = aggregate_metrics(pipelines, wall)
        with self._lock:
            meta["placement"] = {name: pilot.uid
                                 for name, pilot in self._placement.items()}
        meta["pilots"] = [p.uid for p in self.pilots]
        meta["migrations"] = [dict(m, pipeline=p.name)
                              for p in pipelines for m in p.migrations]
        meta["group_peaks"] = {uid: a.group_peaks()
                               for uid, a in self.agents.items()}
        meta["quota_violations"] = {
            uid: v for uid, a in self.agents.items()
            if (v := a.quota_violations())}
        out["_meta"] = meta
        return out

    def close(self, timeout: Optional[float] = None) -> None:
        for p, cb in self._listeners:
            p.remove_capacity_listener(cb)
        self._listeners = []
        for a in self.agents.values():
            a.close(timeout)


def run_pipelines_multi(
    pipelines: Sequence[Pipeline],
    *,
    manager: Optional[PilotManager] = None,
    num_pilots: int = 2,
    max_workers_per_pilot: Optional[int] = None,
) -> Dict[str, Dict[str, Any]]:
    """DEPRECATED shim — use :class:`repro.core.session.Session` with
    ``pods=num_pilots`` instead; kept so pre-Session callers and tests
    keep working unchanged.

    Multi-pilot Table-4 mode: split the machine into ``num_pilots``
    disjoint per-pod pools and spread N pipelines across them.  With a
    caller-supplied ``manager`` its existing pilots are used as-is
    (pre-shaped pools, e.g. kind-specialised pods); otherwise the free
    device inventory is split evenly."""
    warnings.warn(
        "run_pipelines_multi is deprecated; use repro.core.Session "
        "(pods=N, session.run_all) instead", DeprecationWarning, stacklevel=2)
    if manager is None:
        manager = PilotManager()
    if not manager.pilots:
        total = manager.free_devices()
        num_pilots = max(1, min(num_pilots, total))
        per, extra = divmod(total, num_pilots)
        manager.submit_pilots([
            PilotDescription(num_devices=per + (1 if i < extra else 0),
                             name=f"pod{i}")
            for i in range(num_pilots)])
    if max_workers_per_pilot is None:
        max_workers_per_pilot = max(
            2, max(p.size for p in manager.pilots))
    sched = MultiPilotScheduler(
        manager, max_workers_per_pilot=max_workers_per_pilot)
    try:
        out = sched.run(pipelines)
    finally:
        sched.close()
    return out
