"""Continuous-batching serving subsystem (slot-based KV cache engine).

``ServeEngine`` + ``Request`` implement the paper's inference task kind as
a long-running *service* on the pilot runtime: batched prefill into a
``[max_slots, max_len]`` cache, one fused decode per step over all
occupied slots, admission between steps, and checkpoint/yield/resume
under priority preemption (see ``core/task.py`` ServiceControl).
"""
from repro.serve.engine import ServeEngine
from repro.serve.request import Request, RequestState

__all__ = ["ServeEngine", "Request", "RequestState"]
