"""Continuous-batching serving subsystem (paged KV cache engine).

``ServeEngine`` + ``Request`` implement the paper's inference task kind as
a long-running *service* on the pilot runtime: batched prefill packed
page-aligned into a shared page pool addressed by per-slot block tables
(``kv_layout="contiguous"`` keeps the PR-3 ``[max_slots, max_len]`` rows
as the benchmark baseline), one fused flash-decode per step over all
occupied slots (``kernels/ops.decode_attention{_paged}``), per-slot
temperature/top-k sampling with seeded PRNG streams, admission between
steps, and checkpoint/yield/resume under priority preemption (see
``core/task.py`` ServiceControl).

``EngineRouter`` (+ ``build_fleet``) is the fleet layer: a shared,
load-aware request queue over N engines with rolling restarts and
prefill/decode disaggregation — finished prompts migrate between
engines as ``KVHandoff`` page blocks through the Transport.
"""
from repro.serve.engine import ServeEngine
from repro.serve.handoff import KVHandoff
from repro.serve.request import Request, RequestState
from repro.serve.router import EngineRouter, build_fleet
from repro.serve.sampling import sample_tokens

__all__ = ["ServeEngine", "Request", "RequestState", "sample_tokens",
           "KVHandoff", "EngineRouter", "build_fleet"]
