"""KV handoff: migrating a prefilled request between paged engines.

Prefill/decode disaggregation ships a finished prompt from a
prefill-specialised :class:`~repro.serve.engine.ServeEngine` to a decode
engine.  Because both engines keep their KV in a shared page pool
addressed by per-slot block tables (PR 5), the migration is a **block
copy + block-table rewrite**, never a cache copy: the exporter gathers
exactly the pages its block-table row points at (``ceil(prompt_len /
page_size)`` of them), and the importer scatters them into freshly
allocated pages of its own pool and writes a new block-table row.  The
bytes that cross the transport are therefore bounded by the pages the
*request* owns — the pool itself never moves (asserted in
tests/test_fleet.py).

A :class:`KVHandoff` rides the normal engine queue: the router delivers
it through a :class:`~repro.core.transport.Transport` into the decode
engine's ``submit``, and the decode engine's own thread performs the
import inside ``_admit`` (all cache mutation stays on the engine
thread, per the engine's ownership contract).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core.resilience import faults as _faults
from repro.serve.request import Request


def maybe_fail_delivery(hand: "KVHandoff") -> None:
    """Chaos hook (``FaultPlan.fail_handoff``): the router consults
    this at the moment a migrated prefill is submitted to its decode
    target.  A fired fault raises
    :class:`~repro.core.resilience.faults.InjectedFault` and the
    router re-queues the handoff for another route — the page blocks
    are intact parent-side, so the request is re-routed, never lost."""
    inj = _faults.active()
    if inj is None:
        return
    act = inj.fire("handoff.deliver", rid=hand.rid, source=hand.source)
    if act is not None and act.get("action") == "fail":
        raise _faults.InjectedFault(
            f"injected handoff-delivery failure ({hand.rid})")


@dataclasses.dataclass
class KVHandoff:
    """A prefilled request plus the page blocks backing its KV.

    ``pages`` mirrors the engine cache tree (``head_layers`` /
    ``unit`` / ``tail_layers``) with every pool leaf reduced to the
    request's own pages: ``[n_pages, page_size, ...]`` for per-layer
    leaves, ``[layers, n_pages, page_size, ...]`` for the scanned unit.
    Page *order* is the block-table row order, so intra-page offsets
    survive the move — a prompt whose tail straddles into a partially
    filled page keeps decoding into that page on the importing side.
    """

    request: Request
    length: int                 # tokens already written into the pages
    last_tok: int               # the first generated token (feeds decode)
    slot_key: np.ndarray        # [2] uint32 sampling PRNG key, post-advance
    temperature: float
    top_k: int
    pages: Any                  # cache-shaped pytree of gathered page blocks
    n_pages: int
    page_size: int
    kv_bytes: int               # total bytes in ``pages`` (transport cost)
    source: str = ""            # exporting engine's uid (stats/debugging)

    @property
    def rid(self) -> str:
        return self.request.rid

    # -- wire serialization ---------------------------------------------------
    # A handoff crosses process boundaries when the exporting and
    # importing engines live in different workers (subprocess transport).
    # Pickling lowers every page leaf to numpy: a device buffer from
    # another process's XLA runtime is meaningless here, and numpy
    # round-trips the page bytes bitwise — which the importer's
    # block-table rewrite depends on (asserted in tests).

    def __getstate__(self):
        state = dict(self.__dict__)
        state["pages"] = _tree_to_numpy(self.pages)
        state["slot_key"] = np.asarray(self.slot_key)
        return state


def _tree_to_numpy(tree: Any) -> Any:
    """Coerce every array leaf of a cache-shaped pytree to host numpy."""
    import jax
    return jax.tree_util.tree_map(np.asarray, tree)
