"""Serving requests: what a client submits to the ServeEngine.

A request is a prompt plus generation limits; the engine fills in the
lifecycle (QUEUED -> RUNNING -> DONE/FAILED), the generated tokens, and
the latency timestamps the serving benchmark reports (time-to-first-token
and end-to-end latency).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import threading
import time
from typing import List, Optional

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"   # occupying a slot
    DONE = "done"
    FAILED = "failed"


_rid = itertools.count()


@dataclasses.dataclass
class Request:
    """One generation request.

    ``prompt`` is a 1-D int32 token array; generation stops at
    ``max_new_tokens``, on ``stop_token``, or when the slot's KV cache is
    full — whichever comes first.  Sampling is greedy by default
    (``temperature=0``); ``temperature > 0`` samples from the
    temperature-scaled distribution, optionally top-k filtered, from a
    per-request stream seeded by ``seed`` (reproducible across engine
    preemption/resume — the engine checkpoints the slot's PRNG key).
    """

    prompt: np.ndarray
    max_new_tokens: int = 16
    stop_token: Optional[int] = None
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    rid: str = dataclasses.field(
        default_factory=lambda: f"req.{next(_rid):06d}")
    state: RequestState = RequestState.QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)  # generated
    error: Optional[str] = None
    # lifecycle timestamps (benchmark latency decomposition)
    submitted_at: float = dataclasses.field(default_factory=time.time)
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    # per-token emission times (parallel to ``tokens``) — the serving
    # benchmark's inter-token latency distribution reads the diffs
    token_times: List[float] = dataclasses.field(default_factory=list)
    _finished: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")

    # Requests cross process boundaries (subprocess transport: service
    # inbox forwarding, engine checkpoints inside ServicePreempted state,
    # KV handoffs).  threading.Event is not picklable, so it travels as
    # its set-ness and is rebuilt on the far side.
    def __getstate__(self):
        state = dict(self.__dict__)
        state["_finished"] = self._finished.is_set()
        return state

    def __setstate__(self, state):
        was_set = state.pop("_finished", False)
        self.__dict__.update(state)
        ev = threading.Event()
        if was_set:
            ev.set()
        self._finished = ev

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (queueing + prefill)."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at

    @property
    def inter_token_s(self) -> List[float]:
        """Gaps between consecutive emitted tokens.  Decode stalls caused
        by other requests' prefills land here — the quantity chunked
        prefill bounds."""
        return [b - a for a, b in
                zip(self.token_times, self.token_times[1:])]

    @property
    def latency_s(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    def done(self) -> bool:
        return self.state in (RequestState.DONE, RequestState.FAILED)

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the request reaches a terminal state."""
        return self._finished.wait(timeout)

    def reset_for_retry(self) -> None:
        """Return a non-terminal request to QUEUED so a router can
        re-route it after an engine crash destroyed its in-pool KV.
        Generated tokens are discarded and regenerated from the prompt
        on the new engine — greedy decoding (the default) regenerates
        them bit-identically, and seeded sampling restarts its
        per-request stream from ``seed``, so the retried output is
        reproducible either way.  Must not be called on a finished
        request (its waiters have already been released)."""
        if self.done():
            raise RuntimeError(f"cannot reset finished request {self.rid}")
        self.state = RequestState.QUEUED
        self.tokens = []
        self.token_times = []
        self.error = None
        self.admitted_at = None
        self.first_token_at = None
        self.finished_at = None

    def _finish(self, state: RequestState, error: Optional[str] = None) -> None:
        self.state = state
        self.error = error
        self.finished_at = time.time()
        self._finished.set()
