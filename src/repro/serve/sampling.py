"""Per-slot token sampling for the ServeEngine.

One jittable function covers every slot in the fused decode batch:
temperature and top-k are *per-row* vectors (each request carries its
own), and randomness comes from per-slot threefry keys that the engine
threads through checkpoint/restore — a preempted-and-resumed engine
replays exactly the stream an uninterrupted one would have produced.

Greedy (``temperature <= 0``, the default) takes the argmax of the raw
logits — bit-identical to the pre-sampling engine, regardless of which
other slots in the batch are sampling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def make_slot_key(seed: int) -> np.ndarray:
    """Fresh per-request threefry key (uint32[2]) from a request seed —
    the same (hi, lo) packing ``jax.random.PRNGKey`` produces, built on
    the host so admission never pays a device round-trip per request."""
    return np.array([(seed >> 32) & 0xFFFFFFFF, seed & 0xFFFFFFFF],
                    np.uint32)


def sample_tokens(logits: jnp.ndarray,      # [B, V]
                  keys: jnp.ndarray,        # [B, 2] uint32 threefry keys
                  temperature: jnp.ndarray,  # [B] f32 (<=0 -> greedy)
                  top_k: jnp.ndarray):      # [B] int32 (0 -> no filter)
    """Returns (tokens [B] int32, advanced keys [B, 2]).

    Every row's key advances every call (whether or not it sampled), so a
    slot's stream depends only on its own seed and step count — never on
    which neighbours happen to share the fused batch.  Top-k keeps all
    logits >= the k-th largest (ties may keep more than k).
    """
    V = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    pairs = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
    carry, use = pairs[:, 0], pairs[:, 1]
    scaled = lf / jnp.maximum(temperature, 1e-6)[:, None]
    kth = jnp.take_along_axis(
        -jnp.sort(-lf, axis=-1),
        jnp.clip(top_k - 1, 0, V - 1)[:, None], axis=-1)
    keep = jnp.where((top_k > 0)[:, None], lf >= kth, True)
    masked = jnp.where(keep, scaled, -jnp.inf)
    gumbel = jax.vmap(lambda k: jax.random.gumbel(k, (V,)))(use)
    sampled = jnp.argmax(masked + gumbel, axis=-1).astype(jnp.int32)
    tokens = jnp.where(temperature > 0, sampled, greedy)
    return tokens, carry.astype(jnp.uint32)
